"""Fused local-training path (``repro.kernels.train``) parity pinning.

Four layers, mirroring ``test_comm_kernels``:

1. **Kernel vs oracle (``kernels`` marker).** The Pallas one-kernel fusion
   SGD step (interpret mode on CPU) must be bit-identical to the
   ``ref.py`` manual-backward oracle over odd shapes, and the oracle must
   match XLA autodiff at ≤1e-5; padded lanes and absent modalities are
   exact no-ops.
2. **Fused round programs vs the per-epoch chain.** ``fused_encoder_round``
   / ``fused_fusion_round`` (all E epochs, one launch) must match E
   chained ``masked_batched_epoch`` / ``masked_fusion_epoch`` calls at
   ≤1e-5 with identical final-epoch losses — and must CONSUME their
   donated param stack (use-after-donate is pinned as deleted, so a future
   refactor cannot silently re-read a donated buffer).
3. **Prediction cache.** One train-split encoder forward per (client,
   round): the second ``_population_predictions`` consumer over a shared
   round cache dispatches zero programs and returns identical blocks.
4. **Full-round fused-vs-reference.** ``train_impl="fused"`` vs
   ``"reference"`` through batched/engine/async (and sharded at D ∈ {1, 8}
   via the ``multidevice`` tier), quantized uplink on: identical uploads,
   ledgers, and accuracies, ≤1e-5 server encoders, and strictly fewer
   local-training dispatches on the ``repro.core.hostsync`` counter.

``REPRO_TRAIN_IMPL`` (fused|reference) selects the config default
exercised by the smoke-round test; CI runs this module once per mode.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hostsync
from repro.core.batched import (PredictionCache, _population_predictions,
                                masked_batched_epoch, masked_fusion_epoch)
from repro.core.encoders import init_encoder
from repro.core.fusion import init_fusion
from repro.core.rounds import MFedMCConfig, build_federation, run_federation
from repro.kernels.ref import fusion_sgd_step_ref
from repro.kernels.train import (_fusion_sgd_step_xla, fused_encoder_round,
                                 fused_fusion_round, fusion_sgd_step,
                                 fusion_sgd_step_pallas)

TOL = 1e-5
LR = 0.1
TRAIN_IMPL = os.environ.get("REPRO_TRAIN_IMPL", "fused")

# odd population/batch/modality/class sizes — nothing tile-aligned
FUSION_SHAPES = ((3, 5, 3, 4), (1, 7, 2, 3), (5, 2, 4, 5))


def _fusion_batch(k, b, m, c, seed=0):
    keys = jax.random.split(jax.random.key(seed), 4)
    params = jax.vmap(lambda kk: init_fusion(kk, m, c))(
        jax.random.split(keys[0], k))
    preds = jax.random.normal(keys[1], (k, b, m, c))
    mask = (jax.random.uniform(keys[2], (k, m)) > 0.3).astype(jnp.float32)
    y = jax.random.randint(keys[3], (k, b), 0, c)
    w = (jax.random.uniform(keys[1], (k, b)) > 0.25).astype(jnp.float32)
    return params, preds, mask, y, w


def _tree_equal(a, b, err=""):
    for ka in a:
        np.testing.assert_array_equal(np.asarray(a[ka]), np.asarray(b[ka]),
                                      err_msg=f"{err}{ka}")


def _tree_close(a, b, atol=TOL, err=""):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves(b)
    for (path, va), vb in zip(la, lb):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                                   atol=atol, rtol=0,
                                   err_msg=f"{err}{jax.tree_util.keystr(path)}")


# ---------------------------------------------------------------------------
# layer 1: Pallas fusion SGD kernel vs oracle vs autodiff
# ---------------------------------------------------------------------------

@pytest.mark.kernels
class TestFusionKernelVsOracle:
    @pytest.mark.parametrize("shape", FUSION_SHAPES)
    def test_kernel_bit_identical_to_oracle(self, shape):
        params, preds, mask, y, w = _fusion_batch(*shape)
        pr, lr_ = fusion_sgd_step_ref(params, preds, mask, y, w, lr=LR)
        pk, lk = fusion_sgd_step_pallas(params, preds, mask, y, w, lr=LR,
                                        interpret=True)
        _tree_equal(pr, pk, err=f"{shape} ")
        np.testing.assert_array_equal(np.asarray(lr_), np.asarray(lk))

    @pytest.mark.parametrize("shape", FUSION_SHAPES)
    def test_oracle_matches_autodiff(self, shape):
        params, preds, mask, y, w = _fusion_batch(*shape)
        pr, lr_ = fusion_sgd_step_ref(params, preds, mask, y, w, lr=LR)
        pa, la = _fusion_sgd_step_xla(params, preds, mask, y, w, LR)
        _tree_close(pr, pa, err=f"{shape} ")
        np.testing.assert_allclose(np.asarray(lr_), np.asarray(la),
                                   atol=TOL, rtol=0)

    def test_fully_padded_client_is_exact_noop(self):
        params, preds, mask, y, w = _fusion_batch(3, 5, 3, 4)
        w = w.at[1].set(0.0)                      # client 1: all padding
        pk, lk = fusion_sgd_step_pallas(params, preds, mask, y, w, lr=LR,
                                        interpret=True)
        for ka in params:
            np.testing.assert_array_equal(np.asarray(pk[ka][1]),
                                          np.asarray(params[ka][1]),
                                          err_msg=ka)
        assert float(lk[1]) == 0.0

    def test_absent_modality_blind_to_its_predictions(self):
        params, preds, mask, y, w = _fusion_batch(3, 5, 3, 4)
        mask = mask.at[:, 2].set(0.0)
        a = fusion_sgd_step_pallas(params, preds, mask, y, w, lr=LR,
                                   interpret=True)
        garbage = preds.at[:, :, 2].set(1e6)
        b = fusion_sgd_step_pallas(params, garbage, mask, y, w, lr=LR,
                                   interpret=True)
        _tree_equal(a[0], b[0])
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))

    def test_dispatch_wrapper_both_routes_agree(self):
        params, preds, mask, y, w = _fusion_batch(3, 5, 3, 4)
        pk, lk = fusion_sgd_step(params, preds, mask, y, w, lr=LR,
                                 use_kernel=True)
        px, lx = fusion_sgd_step(params, preds, mask, y, w, lr=LR,
                                 use_kernel=False)
        _tree_close(pk, px)
        np.testing.assert_allclose(np.asarray(lk), np.asarray(lx),
                                   atol=TOL, rtol=0)


# ---------------------------------------------------------------------------
# layer 2: fused round programs vs the per-epoch chain + donation
# ---------------------------------------------------------------------------

def _enc_stack(k, feat=(6, 5), classes=3):
    return jax.vmap(lambda kk: init_encoder(kk, feat, classes))(
        jax.random.split(jax.random.key(7), k))


def _enc_schedule(k=3, e=3, s=2, b=4, feat=(6, 5), classes=3, seed=1):
    keys = jax.random.split(jax.random.key(seed), 3)
    xs = jax.random.normal(keys[0], (k, e, s, b) + feat)
    ys = jax.random.randint(keys[1], (k, e, s, b), 0, classes)
    ws = (jax.random.uniform(keys[2], (k, e, s, b)) > 0.2).astype(
        jnp.float32)
    ws = ws.at[0, :, -1].set(0.0)          # client 0: fully-padded tail step
    return xs, ys, ws


class TestFusedRoundPrograms:
    def test_encoder_round_matches_epoch_chain(self):
        k, e = 3, 3
        xs, ys, ws = _enc_schedule(k=k, e=e)
        p_ref = _enc_stack(k)
        for ei in range(e):
            p_ref, losses_ref = masked_batched_epoch(
                p_ref, xs[:, ei], ys[:, ei], ws[:, ei], LR)
        p_fused, losses = fused_encoder_round(_enc_stack(k), xs, ys, ws, LR)
        _tree_close(p_fused, p_ref)
        np.testing.assert_allclose(np.asarray(losses),
                                   np.asarray(losses_ref), atol=TOL, rtol=0)

    def test_fusion_round_matches_epoch_chain(self):
        k, e, s, b, m, c = 3, 2, 2, 5, 3, 4
        params, _, mask, _, _ = _fusion_batch(k, b, m, c)
        keys = jax.random.split(jax.random.key(9), 3)
        preds = jax.random.normal(keys[0], (k, e, s, b, m, c))
        ys = jax.random.randint(keys[1], (k, e, s, b), 0, c)
        ws = (jax.random.uniform(keys[2], (k, e, s, b)) > 0.2).astype(
            jnp.float32)
        p_ref = params
        for ei in range(e):
            p_ref, losses_ref = masked_fusion_epoch(
                p_ref, preds[:, ei], mask, ys[:, ei], ws[:, ei], LR)
        p_fused, losses = fused_fusion_round(
            jax.tree.map(jnp.copy, params), preds, mask, ys, ws, LR)
        _tree_close(p_fused, p_ref)
        np.testing.assert_allclose(np.asarray(losses),
                                   np.asarray(losses_ref), atol=TOL, rtol=0)

    def test_donated_stack_is_consumed(self):
        """Use-after-donate safety: the fused programs take ownership of
        the resident stack — the caller's buffers are DELETED, so any
        code path still holding the input must fail loudly, not read
        stale memory."""
        xs, ys, ws = _enc_schedule()
        stack = _enc_stack(3)
        fused_encoder_round(stack, xs, ys, ws, LR)
        assert all(l.is_deleted() for l in jax.tree_util.tree_leaves(stack))

    def test_reference_epoch_does_not_consume_its_input(self):
        xs, ys, ws = _enc_schedule(e=1)
        stack = _enc_stack(3)
        masked_batched_epoch(stack, xs[:, 0], ys[:, 0], ws[:, 0], LR)
        assert not any(l.is_deleted()
                       for l in jax.tree_util.tree_leaves(stack))


# ---------------------------------------------------------------------------
# layers 3+4: prediction cache + full-round parity through real backends
# ---------------------------------------------------------------------------

def _run(backend, train_impl, bits=4, **cfg_kw):
    base = dict(rounds=1, local_epochs=2, batch_size=8, seed=0,
                modality_strategy="random", gamma=1, quantize_bits=bits,
                train_impl=train_impl, background_size=12, eval_size=12)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                     samples_per_client=16)
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _assert_server_match(se_a, se_b, atol=TOL):
    assert set(se_a) == set(se_b)
    for m in se_a:
        for k in se_a[m]:
            np.testing.assert_allclose(np.asarray(se_b[m][k]),
                                       np.asarray(se_a[m][k]),
                                       atol=atol, rtol=0,
                                       err_msg=f"{m}/{k}")


class TestPredictionCache:
    def test_second_consumer_dispatches_zero_forwards(self):
        """Stage-#1 fusion fills the round cache; the Shapley enumeration
        re-reads the SAME train split — one encoder forward per (client,
        round), not two."""
        cfg = MFedMCConfig(rounds=1, seed=0)
        clients, _ = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                      samples_per_client=16)
        datas = [c.train for c in clients]
        cache = PredictionCache()
        hostsync.reset()
        first = _population_predictions(clients, datas, cache=cache)
        assert hostsync.dispatches() > 0
        assert len(cache) == len(clients)
        hostsync.reset()
        second = _population_predictions(clients, datas, cache=cache)
        assert hostsync.dispatches() == 0, \
            "cached train-split predictions must cost zero forwards"
        np.testing.assert_array_equal(first, second)

    def test_fused_round_dispatches_strictly_fewer_programs(self):
        with hostsync.measuring() as m_f:
            _run("batched", "fused")
        with hostsync.measuring() as m_r:
            _run("batched", "reference")
        assert 0 < m_f.dispatches < m_r.dispatches
        assert m_f.syncs == m_r.syncs


class TestFullRoundTrainParity:
    @pytest.mark.parametrize("backend", ("batched", "engine", "async"))
    def test_fused_matches_reference(self, backend):
        se_f, h_f, _ = _run(backend, "fused")
        se_r, h_r, _ = _run(backend, "reference")
        _assert_server_match(se_r, se_f)
        assert h_f.records[0].uploads == h_r.records[0].uploads
        assert h_f.records[0].accuracy == h_r.records[0].accuracy
        assert h_f.records[0].comm_mb == h_r.records[0].comm_mb

    def test_fused_matches_reference_full_precision(self):
        se_f, h_f, _ = _run("batched", "fused", bits=32, rounds=2)
        se_r, h_r, _ = _run("batched", "reference", bits=32, rounds=2)
        _assert_server_match(se_r, se_f)
        for rec_f, rec_r in zip(h_f.records, h_r.records):
            assert rec_f.uploads == rec_r.uploads
            assert rec_f.accuracy == rec_r.accuracy

    def test_invalid_train_impl_rejected(self):
        with pytest.raises(ValueError, match="train_impl"):
            _run("batched", "fussed")

    def test_env_selected_impl_smokes(self):
        """CI runs this module under both REPRO_TRAIN_IMPL values; whatever
        mode is selected must complete a round and count its training
        dispatches."""
        with hostsync.measuring() as m:
            _, hist, _ = _run("batched", TRAIN_IMPL)
        assert hist.records and hist.records[0].uploads
        assert m.dispatches > 0


class TestShardedTrainParity:
    def test_sharded_d1_fused_matches_reference(self):
        se_f, h_f, _ = _run("sharded", "fused", mesh_clients=1)
        se_r, h_r, _ = _run("sharded", "reference", mesh_clients=1)
        _assert_server_match(se_r, se_f)
        assert h_f.records[0].uploads == h_r.records[0].uploads
        assert h_f.records[0].accuracy == h_r.records[0].accuracy

    @pytest.mark.multidevice
    def test_sharded_d8_fused_matches_reference(self):
        se_f, h_f, _ = _run("sharded", "fused", mesh_clients=8)
        se_r, h_r, _ = _run("sharded", "reference", mesh_clients=8)
        _assert_server_match(se_r, se_f)
        assert h_f.records[0].uploads == h_r.records[0].uploads

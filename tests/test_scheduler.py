"""Virtual-time async runtime: event ordering, availability traces, timing
models, the degenerate reduction-to-sync oracle, and the async-only
semantics (deadline drops, buffered staleness-discounted flushes)."""
import dataclasses

import numpy as np
import pytest

from repro.core.aggregation import IOT_UPLINK
from repro.core.rounds import MFedMCConfig, build_federation, run_federation
from repro.core.scheduler import (Event, EventHeap, EventKind,
                                  nominal_cycle_seconds)
from repro.core.timing import (BernoulliTrace, ComputeModel, MarkovTrace,
                               make_trace, resolve_trace,
                               sample_straggler_multipliers)

TOL = 1e-5


# ---------------------------------------------------------------------------
# event heap
# ---------------------------------------------------------------------------

class TestEventHeap:
    def test_pops_in_time_order(self):
        h = EventHeap()
        h.push(3.0, EventKind.UPLOAD_DONE, 1)
        h.push(1.0, EventKind.DISPATCH, 2)
        h.push(2.0, EventKind.LOCAL_DONE, 0)
        times = [h.pop().time for _ in range(3)]
        assert times == [1.0, 2.0, 3.0]

    def test_tie_break_time_then_kind_then_client(self):
        # equal times: DISPATCH < LOCAL_DONE < UPLOAD_DONE, then client id
        h = EventHeap()
        h.push(1.0, EventKind.UPLOAD_DONE, 0)
        h.push(1.0, EventKind.DISPATCH, 9)
        h.push(1.0, EventKind.LOCAL_DONE, 5)
        h.push(1.0, EventKind.LOCAL_DONE, 2)
        got = [(e.kind, e.client_id) for e in
               (h.pop(), h.pop(), h.pop(), h.pop())]
        assert got == [(EventKind.DISPATCH, 9), (EventKind.LOCAL_DONE, 2),
                       (EventKind.LOCAL_DONE, 5),
                       (EventKind.UPLOAD_DONE, 0)]

    def test_deterministic_across_insert_orders(self):
        events = [(2.0, EventKind.UPLOAD_DONE, 3),
                  (2.0, EventKind.UPLOAD_DONE, 1),
                  (1.0, EventKind.LOCAL_DONE, 7),
                  (2.0, EventKind.LOCAL_DONE, 1)]
        rng = np.random.default_rng(0)
        ref = None
        for _ in range(5):
            order = rng.permutation(len(events))
            h = EventHeap()
            for i in order:
                h.push(*events[i])
            got = [h.pop().sort_key for _ in range(len(events))]
            if ref is None:
                ref = got
            assert got == ref

    def test_len_and_bool(self):
        h = EventHeap()
        assert not h and len(h) == 0
        h.push(0.0, EventKind.DISPATCH, 0)
        assert h and len(h) == 1
        h.pop()
        assert not h

    def test_event_sort_key(self):
        e = Event(2.5, EventKind.LOCAL_DONE, 4)
        assert e.sort_key == (2.5, 1, 4)


# ---------------------------------------------------------------------------
# availability traces
# ---------------------------------------------------------------------------

class TestTraces:
    def test_bernoulli_matches_inline_coin_flip_draws(self):
        # the historical §4.9 code drew one scalar per client sequentially;
        # the trace must consume the generator identically (parity contract)
        r1, r2 = np.random.default_rng(3), np.random.default_rng(3)
        got = BernoulliTrace(0.4).step(r1, 10)
        ref = np.array([r2.random() < 0.4 for _ in range(10)])
        np.testing.assert_array_equal(got, ref)
        assert r1.random() == r2.random()   # same stream position after

    def test_bernoulli_full_rate_consumes_no_draws(self):
        r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
        np.testing.assert_array_equal(BernoulliTrace(1.0).step(r1, 6),
                                      np.ones(6, bool))
        assert r1.random() == r2.random()   # generator untouched

    def test_markov_cold_start_all_on(self):
        t = MarkovTrace(0.5, 0.5)
        rng = np.random.default_rng(0)
        np.testing.assert_array_equal(t.step(rng, 8), np.ones(8, bool))

    def test_markov_transitions(self):
        # p_drop=1, p_join=1 -> strict alternation per client
        t = MarkovTrace(1.0, 1.0)
        rng = np.random.default_rng(0)
        assert t.step(rng, 4).all()
        assert not t.step(rng, 4).any()
        assert t.step(rng, 4).all()

    def test_markov_stationary_availability(self):
        t = MarkovTrace(0.3, 0.3)          # stationary 0.5
        rng = np.random.default_rng(7)
        rates = [t.step(rng, 200).mean() for _ in range(300)]
        assert abs(np.mean(rates[50:]) - 0.5) < 0.05

    def test_make_trace_specs(self):
        assert isinstance(make_trace(None), BernoulliTrace)
        assert make_trace(0.25).rate == 0.25
        assert make_trace("bernoulli:0.5").rate == 0.5
        assert make_trace("always").rate == 1.0
        m = make_trace("markov:0.2,0.6")
        assert (m.p_drop, m.p_join) == (0.2, 0.6)
        # trace objects contribute parameters only: a fresh cold-start
        # trace comes back, so cfg-held traces can't leak state across runs
        obj = MarkovTrace(0.1, 0.9)
        obj.step(np.random.default_rng(0), 4)
        obj.step(np.random.default_rng(0), 4)
        fresh = make_trace(obj)
        assert fresh is not obj
        assert (fresh.p_drop, fresh.p_join) == (0.1, 0.9)
        assert fresh.state is None          # cold start restored
        with pytest.raises(ValueError):
            make_trace("poisson:3")
        with pytest.raises(ValueError):
            make_trace("markov:0.2")
        with pytest.raises(TypeError):
            make_trace([0.5])

    def test_resolve_trace_prefers_explicit_trace(self):
        cfg = MFedMCConfig(availability=0.5,
                           availability_trace="markov:0.2,0.6")
        assert isinstance(resolve_trace(cfg), MarkovTrace)
        assert resolve_trace(MFedMCConfig(availability=0.5)).rate == 0.5


# ---------------------------------------------------------------------------
# timing models
# ---------------------------------------------------------------------------

class TestTimingModels:
    def test_compute_scales_with_feature_volume_and_steps(self):
        cfg = MFedMCConfig(rounds=1, local_epochs=2, batch_size=10, seed=0)
        clients, _ = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                      samples_per_client=24)
        cm = ComputeModel(sec_per_step=1e-3)
        c = clients[0]
        base = cm.local_seconds(c, epochs=2, batch_size=10)
        assert base > 0
        # double epochs -> double time; straggler multiplier is linear
        assert cm.local_seconds(c, epochs=4, batch_size=10) == \
            pytest.approx(2 * base)
        assert cm.local_seconds(c, epochs=2, batch_size=10,
                                multiplier=10.0) == pytest.approx(10 * base)

    def test_straggler_multipliers(self):
        rng = np.random.default_rng(0)
        m = sample_straggler_multipliers(rng, 20, 0.25, 10.0)
        assert (m == 10.0).sum() == 5 and (m == 1.0).sum() == 15
        np.testing.assert_array_equal(
            sample_straggler_multipliers(rng, 8, 0.0), np.ones(8))

    def test_sample_links_mean_preserving_lognormal(self):
        rng = np.random.default_rng(0)
        links = IOT_UPLINK.sample_links(rng, 4000, sigma=0.5)
        bw = np.array([l.bandwidth_bps for l in links])
        assert abs(bw.mean() / IOT_UPLINK.bandwidth_bps - 1.0) < 0.05
        assert bw.std() > 0
        for l in links[:3]:   # overheads shared with the preset
            assert l.protocol_overhead == IOT_UPLINK.protocol_overhead
            assert l.fec_overhead == IOT_UPLINK.fec_overhead

    def test_nominal_cycle_seconds_positive_and_straggler_free(self):
        cfg = MFedMCConfig(rounds=1, local_epochs=1, batch_size=10, seed=0,
                           straggler_fraction=0.5, straggler_factor=10.0)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=24)
        nom = nominal_cycle_seconds(clients, spec, cfg)
        assert nom > 0
        # nominal ignores stragglers: same value without them
        cfg2 = dataclasses.replace(cfg, straggler_fraction=0.0)
        assert nominal_cycle_seconds(clients, spec, cfg2) == nom


# ---------------------------------------------------------------------------
# full-run semantics
# ---------------------------------------------------------------------------

def _run(backend, n=24, dataset="ucihar", scenario="iid", **cfg_kw):
    base = dict(rounds=2, local_epochs=1, batch_size=10, seed=0,
                background_size=12, eval_size=12)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = build_federation(dataset, scenario, cfg=cfg, seed=0,
                                     samples_per_client=n)
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _assert_exact_decisions(h_ref, h):
    for r_ref, r in zip(h_ref.records, h.records):
        assert r.uploads == r_ref.uploads
        assert r.comm_mb == r_ref.comm_mb


def _assert_encoders_close(se_ref, se_new):
    assert set(se_ref) == set(se_new)
    for m in se_ref:
        for k in se_ref[m]:
            np.testing.assert_allclose(np.asarray(se_new[m][k]),
                                       np.asarray(se_ref[m][k]),
                                       atol=TOL, rtol=0, err_msg=f"{m}/{k}")


class TestSyncReductionOracle:
    """deadline=∞ + one flush + no staleness discount == backend="engine"
    exactly on uploads/ledger/selection, ≤1e-5 on encoders."""

    def test_degenerate_async_matches_engine(self):
        se_e, h_e, _ = _run("engine")
        se_a, h_a, _ = _run("async")
        _assert_exact_decisions(h_e, h_a)
        _assert_encoders_close(se_e, se_a)
        assert h_a.makespan_s > 0 and h_e.makespan_s == 0.0
        for r in h_a.records:
            assert r.flushes == 1 and r.dropped == []

    def test_degenerate_async_matches_engine_ragged(self):
        kw = dict(dataset="actionsense", scenario="natural", n=20,
                  batch_size=8)
        se_e, h_e, _ = _run("engine", **kw)
        se_a, h_a, _ = _run("async", **kw)
        _assert_exact_decisions(h_e, h_a)
        _assert_encoders_close(se_e, se_a)

    def test_degenerate_async_matches_engine_quantized(self):
        kw = dict(quantize_bits=8)
        se_e, h_e, _ = _run("engine", **kw)
        se_a, h_a, _ = _run("async", **kw)
        _assert_exact_decisions(h_e, h_a)
        _assert_encoders_close(se_e, se_a)

    def test_explicit_buffer_k_is_still_degenerate(self):
        # buffer_size >= #arrivals -> one final flush, same as None
        se_e, h_e, _ = _run("engine")
        se_a, h_a, _ = _run("async", buffer_size=10 ** 6)
        _assert_exact_decisions(h_e, h_a)
        _assert_encoders_close(se_e, se_a)

    def test_timing_knobs_never_change_math(self):
        # heterogeneous links + stragglers reshuffle *when* uploads land,
        # not what is computed: with no deadline/buffer/discount the run
        # still matches the engine exactly
        se_e, h_e, _ = _run("engine")
        se_a, h_a, _ = _run("async", link_sigma=0.8,
                            straggler_fraction=0.25, straggler_factor=10.0)
        _assert_exact_decisions(h_e, h_a)
        _assert_encoders_close(se_e, se_a)
        assert h_a.makespan_s > 0

    def test_clients_written_back(self):
        _, _, cl_e = _run("engine")
        _, _, cl_a = _run("async")
        for c_e, c_a in zip(cl_e, cl_a):
            assert c_e.recency.last_upload == c_a.recency.last_upload
            for m in c_e.modality_names:
                for k in c_e.encoders[m]:
                    np.testing.assert_allclose(
                        np.asarray(c_a.encoders[m][k]),
                        np.asarray(c_e.encoders[m][k]), atol=TOL, rtol=0)


class TestAsyncSemantics:
    def test_deadline_drops_stragglers_and_caps_cycles(self):
        base = dict(client_strategy="all", delta=1.0,
                    compute_sec_per_step=0.05,
                    straggler_fraction=0.25, straggler_factor=10.0)
        _, h_wait, _ = _run("async", **base)
        cfg_probe = MFedMCConfig(rounds=1, local_epochs=1, batch_size=10,
                                 seed=0, **base)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg_probe,
                                         seed=0, samples_per_client=24)
        nom = nominal_cycle_seconds(clients, spec, cfg_probe)
        _, h_drop, _ = _run("async", deadline_s=1.5 * nom, **base)
        assert h_drop.makespan_s < h_wait.makespan_s
        dropped = {cid for r in h_drop.records for cid in r.dropped}
        assert dropped            # the 10x stragglers miss the deadline
        # dropped uploads never ship: strictly fewer ledger bytes
        assert h_drop.records[-1].comm_mb < h_wait.records[-1].comm_mb
        for r in h_drop.records:  # cycle duration capped by the deadline
            assert r.dropped == sorted(r.dropped)

    def test_dropped_uploads_not_recorded_or_marked(self):
        base = dict(client_strategy="all", delta=1.0,
                    compute_sec_per_step=0.05,
                    straggler_fraction=0.25, straggler_factor=10.0)
        cfg_probe = MFedMCConfig(rounds=1, local_epochs=1, batch_size=10,
                                 seed=0, **base)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg_probe,
                                         seed=0, samples_per_client=24)
        nom = nominal_cycle_seconds(clients, spec, cfg_probe)
        _, h, cl = _run("async", deadline_s=1.5 * nom, **base)
        for r in h.records:
            up_ids = {cid for cid, _ in r.uploads}
            assert not up_ids & set(r.dropped)
        # a client dropped every round never marks recency
        always_dropped = set(h.records[0].dropped)
        for r in h.records[1:]:
            always_dropped &= set(r.dropped)
        for c in cl:
            if c.client_id in always_dropped:
                assert all(v == -1 for v in c.recency.last_upload.values())

    def test_buffered_flushes_and_staleness_discount(self):
        base = dict(client_strategy="all", delta=1.0)
        _, h_buf, _ = _run("async", buffer_size=2)
        assert all(r.flushes > 1 for r in h_buf.records)
        # discount < 1 changes the aggregate (later flushes discount
        # nothing within themselves, but staleness accrues across flushes)
        se_plain, _, _ = _run("async", buffer_size=2, **base)
        se_disc, _, _ = _run("async", buffer_size=2,
                             staleness_discount=0.5, **base)
        diff = 0.0
        for m in se_plain:
            for k in se_plain[m]:
                diff += float(np.abs(np.asarray(se_plain[m][k])
                                     - np.asarray(se_disc[m][k])).sum())
        assert diff > 0

    def test_makespan_monotone_in_cycles(self):
        _, h, _ = _run("async", rounds=3)
        times = [r.sim_time for r in h.records]
        assert all(b > a for a, b in zip(times, times[1:]))

    def test_markov_trace_run(self):
        _, h, _ = _run("async", rounds=4,
                       availability_trace="markov:0.4,0.4")
        assert len(h.records) == 4
        assert np.isfinite(h.final_accuracy())

    def test_time_unit_recency_runs(self):
        _, h, _ = _run("async", rounds=3, recency_unit="time")
        assert len(h.records) == 3
        assert np.isfinite(h.final_accuracy())

    def test_time_unit_recency_needs_engine_selection(self):
        with pytest.raises(ValueError, match="engine"):
            _run("async", recency_unit="time", selection_impl="host")

    def test_time_unit_recency_needs_async_backend(self):
        with pytest.raises(ValueError, match="async"):
            _run("engine", recency_unit="time")

    def test_async_only_knobs_rejected_on_sync_backends(self):
        # a sync run must not silently drop a configured deadline/buffer
        for kw in (dict(deadline_s=2.0), dict(buffer_size=4),
                   dict(staleness_discount=0.5)):
            with pytest.raises(ValueError, match="async"):
                _run("engine", **kw)

    def test_bad_knobs_rejected(self):
        with pytest.raises(ValueError, match="deadline"):
            _run("async", deadline_s=0.0)
        with pytest.raises(ValueError, match="buffer"):
            _run("async", buffer_size=0)
        with pytest.raises(ValueError, match="staleness"):
            _run("async", staleness_discount=0.0)
        with pytest.raises(ValueError, match="recency_unit"):
            _run("async", recency_unit="epochs")


class TestAvailabilityParity:
    """§4.9 under the trace abstraction: loop, batched, and engine backends
    must stay in lockstep at availability=0.5 (the seed only pinned 1.0)."""

    @pytest.fixture(scope="class")
    def loop_run(self):
        return _run("loop", availability=0.5, rounds=3)

    def test_loop_vs_batched(self, loop_run):
        se_l, h_l, _ = loop_run
        se_b, h_b, _ = _run("batched", availability=0.5, rounds=3)
        _assert_exact_decisions(h_l, h_b)
        _assert_encoders_close(se_l, se_b)

    def test_loop_vs_engine(self, loop_run):
        se_l, h_l, _ = loop_run
        se_e, h_e, _ = _run("engine", availability=0.5, rounds=3)
        _assert_exact_decisions(h_l, h_e)
        _assert_encoders_close(se_l, se_e)

    def test_loop_vs_async_degenerate(self, loop_run):
        se_l, h_l, _ = loop_run
        se_a, h_a, _ = _run("async", availability=0.5, rounds=3)
        _assert_exact_decisions(h_l, h_a)
        _assert_encoders_close(se_l, se_a)

    def test_zero_availability_records_empty_rounds(self):
        for backend in ("loop", "async"):
            _, h, _ = _run(backend, availability=0.0, rounds=2)
            assert len(h.records) == 2
            for r in h.records:
                assert r.uploads == [] and r.comm_mb == 0.0

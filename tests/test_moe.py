"""MoE dispatch correctness: the capacity-buffer scatter/gather path must
match the dense evaluate-all-experts oracle when capacity is ample, and
respect capacity/top-k semantics otherwise."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import (init_moe, load_balance_loss, moe_ffn,
                              moe_ffn_dense_fallback, router_probs)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("granite-moe-1b-a400m").smoke()   # 4 experts, top-2
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    return cfg, params, x


class TestMoEDispatch:
    def test_matches_dense_oracle_with_ample_capacity(self, moe_setup):
        cfg, params, x = moe_setup
        y, _ = moe_ffn(params, x, cfg, capacity_factor=8.0)  # no drops
        y_ref = moe_ffn_dense_fallback(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_capacity_drops_reduce_output_norm(self, moe_setup):
        cfg, params, x = moe_setup
        y_full, _ = moe_ffn(params, x, cfg, capacity_factor=8.0)
        y_tight, _ = moe_ffn(params, x, cfg, capacity_factor=0.25)
        # dropped tokens contribute zero -> tight-capacity output smaller
        assert float(jnp.linalg.norm(y_tight)) < \
            float(jnp.linalg.norm(y_full))

    def test_router_probs_normalized(self, moe_setup):
        cfg, params, x = moe_setup
        probs, _ = router_probs(params, x.reshape(-1, cfg.d_model))
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0,
                                   rtol=1e-5)

    def test_aux_loss_bounds(self, moe_setup):
        cfg, params, x = moe_setup
        xt = x.reshape(-1, cfg.d_model)
        probs, _ = router_probs(params, xt)
        _, idx = jax.lax.top_k(probs, cfg.experts_per_token)
        aux = load_balance_loss(probs, idx, cfg.num_experts)
        # perfectly balanced -> ~k; pathological -> up to E·k
        assert 0.5 < float(aux) <= cfg.num_experts * cfg.experts_per_token

    def test_grad_flows_through_dispatch(self, moe_setup):
        cfg, params, x = moe_setup

        def loss(p):
            y, aux = moe_ffn(p, x, cfg)
            return jnp.sum(y ** 2) + aux

        grads = jax.grad(loss)(params)
        g = float(jnp.linalg.norm(grads["w_in"]))
        assert np.isfinite(g) and g > 0
        assert float(jnp.linalg.norm(grads["router"])) > 0

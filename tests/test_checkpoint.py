"""Checkpoint round-trips for model params and federated server state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.core.encoders import init_encoder


class TestCheckpoint:
    def test_roundtrip_nested(self, tmp_path):
        tree = {"a": {"b": jnp.arange(6.0).reshape(2, 3),
                      "c": [jnp.ones(4), jnp.zeros(2)]},
                "d": jnp.asarray(3, jnp.int32)}
        path = str(tmp_path / "ckpt.npz")
        save_pytree(path, tree, meta={"round": 7})
        back, meta = load_pytree(path, like=tree)
        assert meta == {"round": 7}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_flat_load(self, tmp_path):
        enc = init_encoder(jax.random.key(0), (8, 4), 5)
        path = str(tmp_path / "enc.npz")
        save_pytree(path, {"m": enc})
        flat, _ = load_pytree(path)
        assert "m/w_x" in flat

    def test_missing_leaf_raises(self, tmp_path):
        path = str(tmp_path / "x.npz")
        save_pytree(path, {"a": jnp.ones(2)})
        with pytest.raises(KeyError):
            load_pytree(path, like={"a": jnp.ones(2), "b": jnp.ones(2)})

"""Ragged-federation fast path: loop-vs-batched parity on populations with
structurally missing modalities and skewed sample counts, the padded-SGD
property (mask-weighted padded SGD == unpadded SGD), the masked mesh round,
the empty-candidate guard, and the top-γ tie-break regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.bench_batched_round import ragged_federation
from repro.core import encoders as enc
from repro.core.batched import (masked_batched_epoch, num_steps,
                                padded_perm_indices,
                                padded_population_batches)
from repro.core.rounds import (MFedMCConfig, _weighted_accuracy,
                               build_federation, run_federation)
from repro.core.selection import select_top_gamma

TOL = 1e-5


def ragged_clients(K=9, n_max=22, seed=0):
    """The benchmark's heterogeneous federation at test scale: three
    distinct modality sets ({acc}, {gyro}, {acc, gyro}) and sample counts
    skewed from n_max down to min_n — every schedule length and presence
    pattern differs."""
    return ragged_federation(K, n=n_max, seed=seed, min_n=6)


def _run(backend, **cfg_kw):
    base = dict(rounds=1, local_epochs=2, batch_size=8, seed=0,
                modality_strategy="random", gamma=1)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = ragged_clients()
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _assert_server_match(se_loop, se_batched):
    assert set(se_loop) == set(se_batched)
    for m in se_loop:
        for k in se_loop[m]:
            np.testing.assert_allclose(np.asarray(se_batched[m][k]),
                                       np.asarray(se_loop[m][k]),
                                       atol=TOL, rtol=0,
                                       err_msg=f"{m}/{k}")


class TestRaggedParity:
    """Round-1 aggregates, ledger bytes, and selection decisions pinned to
    the loop backend on a federation no signature grouping could stack."""

    def test_random_strategy(self):
        se_l, h_l, _ = _run("loop")
        se_b, h_b, _ = _run("batched")
        _assert_server_match(se_l, se_b)
        assert h_b.records[0].comm_mb == h_l.records[0].comm_mb
        assert h_b.records[0].uploads == h_l.records[0].uploads
        assert h_b.records[0].accuracy == pytest.approx(
            h_l.records[0].accuracy, abs=1e-6)

    def test_priority_strategy_vmapped_shapley(self):
        # exercises batched_shapley_values (one vmapped 2^M enumeration)
        kw = dict(modality_strategy="priority", client_strategy="low_loss",
                  background_size=10, eval_size=8)
        se_l, h_l, _ = _run("loop", **kw)
        se_b, h_b, _ = _run("batched", **kw)
        _assert_server_match(se_l, se_b)
        assert h_b.records[0].uploads == h_l.records[0].uploads
        for m in h_l.records[0].shapley:
            assert h_b.records[0].shapley[m] == pytest.approx(
                h_l.records[0].shapley[m], abs=1e-4)

    def test_per_client_losses_track(self):
        _, h_l, cl_l = _run("loop", local_epochs=1)
        _, h_b, cl_b = _run("batched", local_epochs=1)
        for c_l, c_b in zip(cl_l, cl_b):
            assert c_l.modality_names == c_b.modality_names
            for m in c_l.modality_names:
                assert c_b.losses[m] == pytest.approx(c_l.losses[m],
                                                      abs=1e-5)

    def test_batched_evaluate_matches_loop(self):
        _, _, cl = _run("batched", local_epochs=1)
        from repro.core.batched import batched_evaluate
        acc_b, loss_b = batched_evaluate(cl)
        acc_l, loss_l = _weighted_accuracy(cl)
        assert acc_b == pytest.approx(acc_l, abs=1e-6)
        assert loss_b == pytest.approx(loss_l, abs=1e-5)


class TestPaddedSgdProperty:
    """Mask-weighted padded SGD must reproduce unpadded SGD: same params,
    same per-batch losses, across random (n, B) schedule shapes."""

    @pytest.mark.parametrize("seed", range(6))
    def test_matches_unpadded(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 40))
        bsz = int(rng.integers(2, 12))
        t, f, c = 5, int(rng.integers(2, 6)), 4
        params = enc.init_encoder(jax.random.key(seed), (t, f), c)
        x = rng.standard_normal((n, t, f)).astype(np.float32)
        y = rng.integers(0, c, n).astype(np.int32)
        perm = rng.permutation(n)

        # reference: the loop backend's batch semantics
        ref = params
        ref_losses = []
        for i in range(0, n, bsz):
            sel = perm[i:i + bsz]
            ref, loss = enc.encoder_sgd_step(ref, jnp.asarray(x[sel]),
                                             jnp.asarray(y[sel]), lr=0.1)
            ref_losses.append(float(loss))

        # padded: pad the schedule with 2 extra fully-masked steps
        steps = num_steps(n, bsz) + 2
        idx, w = padded_perm_indices([perm], [n], steps, bsz)
        xe = x[idx[0]].reshape(1, steps, bsz, t, f)
        ye = y[idx[0]].reshape(1, steps, bsz)
        ws = w.reshape(1, steps, bsz)
        stacked = jax.tree.map(lambda v: v[None], params)
        out, losses = masked_batched_epoch(stacked, jnp.asarray(xe),
                                           jnp.asarray(ye),
                                           jnp.asarray(ws), 0.1)
        got = jax.tree.map(lambda v: np.asarray(v[0]), out)
        for key in got:
            np.testing.assert_allclose(got[key], np.asarray(ref[key]),
                                       atol=TOL, rtol=0, err_msg=key)
        real = num_steps(n, bsz)
        np.testing.assert_allclose(np.asarray(losses)[0, :real],
                                   ref_losses, atol=TOL, rtol=0)
        # fully-padded steps: zero loss, and (already checked) no-op updates
        np.testing.assert_array_equal(np.asarray(losses)[0, real:], 0.0)


class TestMaskedMeshRound:
    """The mesh round consumes the same padded layout: ragged sample counts
    and absent-modality dummy slots inside one jit'd program."""

    def test_matches_per_client_loop(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.core.distributed import make_federated_round
        t, f, c, bsz = 6, 4, 3, 4
        ns = [5, 0, 11]                      # client 1 lacks the modality
        rng = np.random.default_rng(0)
        xs = [None if n == 0 else
              rng.standard_normal((n, t, f)).astype(np.float32) for n in ns]
        ys = [np.zeros((0,), np.int32) if x is None else
              rng.integers(0, c, len(x)).astype(np.int32) for x in xs]
        batches = padded_population_batches(xs, ys, bsz)
        params = enc.init_encoder(jax.random.key(1), (t, f), c)
        stacked = jax.tree.map(lambda v: jnp.stack([v] * 3), params)
        select = jnp.asarray([1.0, 0.0, 1.0])
        weight = jnp.asarray([float(n) for n in ns])
        rnd = make_federated_round(mesh, local_steps=3, lr=0.05)
        with mesh:
            deployed, agg, losses = jax.jit(rnd)(stacked, batches, select,
                                                 weight)

        # hand-rolled reference: per-client loop over the real batches
        def local(x, y):
            p = params
            for i in range(0, len(x), bsz):
                g = jax.grad(enc.encoder_loss)(p, jnp.asarray(x[i:i + bsz]),
                                               jnp.asarray(y[i:i + bsz]))
                p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
            return p
        ref = {k: local(xs[k], ys[k]) for k in (0, 2)}
        wsum = float(ns[0] + ns[2])
        for key in agg:
            expect = (ns[0] * np.asarray(ref[0][key])
                      + ns[2] * np.asarray(ref[2][key])) / wsum
            np.testing.assert_allclose(np.asarray(agg[key]), expect,
                                       atol=1e-5, rtol=1e-4, err_msg=key)
        # the dummy slot trains nothing and reports zero loss
        assert float(losses[1]) == 0.0
        np.testing.assert_allclose(
            np.asarray(deployed["w_fc"][1]), np.asarray(agg["w_fc"]),
            rtol=1e-5)


class TestEmptyCandidateRound:
    """No client has a selectable modality -> an explicit empty-upload
    round, not incidental behavior (random client selection used to raise
    on the empty candidate set)."""

    @pytest.mark.parametrize("strategy", ["low_loss", "random"])
    def test_records_empty_round(self, strategy):
        cfg = MFedMCConfig(rounds=1, local_epochs=1, batch_size=8, seed=0,
                           client_strategy=strategy,
                           allowed_modalities={})
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=16)
        cfg = dataclasses.replace(
            cfg, allowed_modalities={c.client_id: set() for c in clients})
        hist = run_federation(clients, spec, cfg)
        assert hist.records[0].uploads == []
        assert hist.records[0].comm_mb == 0.0


class TestSelectTopGammaTieBreak:
    def test_ties_break_by_name_not_input_order(self):
        # equal priorities: the docstring promises name order, but the old
        # stable argsort kept input order ("b" before "a")
        names = ["b", "a", "c"]
        prio = np.array([1.0, 1.0, 0.5])
        assert select_top_gamma(prio, names, 2) == ["a", "b"]
        assert select_top_gamma(prio, names, 3) == ["a", "b", "c"]

    def test_priority_still_dominates_name(self):
        names = ["a", "b"]
        assert select_top_gamma(np.array([0.1, 0.9]), names, 1) == ["b"]

"""Context-parallel flash-decode: shard_map partial-softmax combine must be
exact vs the unsharded oracle (and vs plain softmax attention)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.decode_attention import (
    _partial_attention,
    flash_decode_reference,
)


class TestPartialAttention:
    def test_reference_matches_plain_softmax(self):
        ks = jax.random.split(jax.random.key(0), 3)
        b, h, kv, t, d = 2, 4, 2, 64, 16
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, t, kv, d))
        v = jax.random.normal(ks[2], (b, t, kv, d))
        kv_pos = jnp.arange(t)
        pos = jnp.asarray(40)
        out = flash_decode_reference(q, k, v, kv_pos, pos)

        # plain attention oracle
        g = h // kv
        scale = d ** -0.5
        qg = (q * scale).reshape(b, kv, g, d)
        scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
        scores = jnp.where((kv_pos <= pos)[None, None, None, :], scores,
                           -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        exp = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v
                         ).reshape(b, h, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)

    def test_split_combine_is_exact(self):
        """Manually splitting KV into shards and merging (o, m, l) must equal
        the unsharded result — the flash-decoding identity."""
        ks = jax.random.split(jax.random.key(1), 3)
        b, h, kv, t, d = 1, 4, 4, 128, 8
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, t, kv, d))
        v = jax.random.normal(ks[2], (b, t, kv, d))
        kv_pos = jnp.arange(t)
        pos = jnp.asarray(t - 1)
        full = flash_decode_reference(q, k, v, kv_pos, pos)

        # 4-way manual shard + merge
        outs = []
        for i in range(4):
            sl = slice(i * 32, (i + 1) * 32)
            outs.append(_partial_attention(q, k[:, sl], v[:, sl],
                                           kv_pos[sl], pos))
        m = jnp.stack([o[1] for o in outs])            # [S, B, H]
        M = jnp.max(m, axis=0)
        corr = jnp.exp(m - M[None])
        o = sum(outs[i][0] * corr[i][..., None] for i in range(4))
        l = sum(outs[i][2] * corr[i] for i in range(4))
        merged = o / jnp.maximum(l, 1e-30)[..., None]
        np.testing.assert_allclose(np.asarray(merged),
                                   np.asarray(full, np.float32),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_shard_map_flash_decode_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.decode_attention import (make_flash_decode,
                                                   flash_decode_reference)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ks = jax.random.split(jax.random.key(0), 3)
        b, h, kv, t, d = 1, 4, 2, 256, 16
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, t, kv, d))
        v = jax.random.normal(ks[2], (b, t, kv, d))
        kv_pos = jnp.arange(t)
        pos = jnp.asarray(200)
        fd = make_flash_decode(mesh)
        with mesh:
            out = jax.jit(fd)(q, k, v, kv_pos, pos)
        exp = flash_decode_reference(q, k, v, kv_pos, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)
        print("FLASH_DECODE_OK")
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "FLASH_DECODE_OK" in out.stdout, out.stderr[-2000:]

"""Checkpoint/resume of a federation: a run split across two processes must
continue from the restored global encoders and recency state."""
import numpy as np

from repro.core import MFedMCConfig
from repro.core.checkpoint_io import load_federation, save_federation
from repro.core.rounds import build_federation, run_federation

CFG = dict(local_epochs=1, background_size=16, eval_size=16, seed=0)


class TestFederationResume:
    def test_roundtrip_preserves_encoders_and_recency(self, tmp_path):
        cfg = MFedMCConfig(rounds=2, **CFG)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=24)
        server = {}
        run_federation(clients, spec, cfg, server_encoders=server)
        path = str(tmp_path / "fed.npz")
        save_federation(path, server, clients, round_idx=2)

        clients2, _ = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                       samples_per_client=24)
        server2, rnd = load_federation(path, clients2)
        assert rnd == 2
        assert set(server2) == set(server)
        for m in server:
            for k in server[m]:
                np.testing.assert_array_equal(np.asarray(server[m][k]),
                                              np.asarray(server2[m][k]))
        # recency restored
        for c, c2 in zip(clients, clients2):
            assert c.recency.last_upload == c2.recency.last_upload
        # encoders deployed onto the fresh population
        any_m = next(iter(server))
        for c2 in clients2:
            if any_m in c2.encoders:
                np.testing.assert_array_equal(
                    np.asarray(c2.encoders[any_m]["w_fc"]),
                    np.asarray(server[any_m]["w_fc"]))

    def test_resumed_run_keeps_learning(self, tmp_path):
        cfg = MFedMCConfig(rounds=2, **CFG)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=24)
        server = {}
        h1 = run_federation(clients, spec, cfg, server_encoders=server)
        path = str(tmp_path / "fed.npz")
        save_federation(path, server, clients, round_idx=2)

        clients2, spec2 = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                           samples_per_client=24)
        server2, _ = load_federation(path, clients2)
        h2 = run_federation(clients2, spec2,
                            MFedMCConfig(rounds=2, **CFG),
                            server_encoders=server2)
        # resumed federation should be at least as good as the fresh start
        assert h2.final_accuracy() >= h1.records[0].accuracy - 0.1

"""Datacenter mapping: the masked sparse all-reduce federated round.

The single-device-mesh test validates the math (masking, weighting,
deployment); the multi-device variant runs in a subprocess so the forced
host-device count never leaks into this test session."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import (make_federated_round,
                                    make_multimodal_federated_round,
                                    selection_masks)
from repro.core.encoders import encoder_loss, init_encoder


def _inputs(K=4, steps=2, B=8, t=6, f=4, c=3, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    enc = init_encoder(ks[0], (t, f), c)
    stacked = jax.tree.map(
        lambda x: jnp.stack([x + 0.01 * i for i in range(K)]), enc)
    x = jax.random.normal(ks[1], (K, steps, B, t, f))
    y = jax.random.randint(ks[2], (K, steps, B), 0, c)
    return stacked, {"x": x, "y": y}


class TestFederatedRound:
    def setup_method(self):
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))

    def _run(self, select, weight, K=4):
        stacked, batches = _inputs(K)
        rnd = make_federated_round(self.mesh, local_steps=2, lr=0.05)
        with self.mesh:
            out = jax.jit(rnd)(stacked, batches,
                               jnp.asarray(select, jnp.float32),
                               jnp.asarray(weight, jnp.float32))
        return stacked, batches, out

    def test_masked_aggregation_matches_numpy(self):
        select = [1, 0, 1, 0]
        weight = [10, 20, 30, 40]
        stacked, batches, (deployed, agg, losses) = self._run(select, weight)

        # independently train each client with plain jax and FedAvg by hand
        def local(params_k, xk, yk):
            p = params_k
            for s in range(2):
                g = jax.grad(encoder_loss)(p, xk[s], yk[s])
                p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
            return p

        per_client = [
            local(jax.tree.map(lambda v: v[k], stacked),
                  batches["x"][k], batches["y"][k]) for k in range(4)]
        w = np.array(select, float) * np.array(weight, float)
        w /= w.sum()
        for key in agg:
            expect = sum(w[k] * np.asarray(per_client[k][key])
                         for k in range(4))
            np.testing.assert_allclose(np.asarray(agg[key]), expect,
                                       rtol=1e-4, atol=1e-5)

    def test_unselected_clients_contribute_nothing(self):
        _, _, (_, agg1, _) = self._run([1, 0, 0, 0], [1, 1, 1, 1])
        _, _, (_, agg2, _) = self._run([1, 0, 0, 0], [1, 99, 99, 99])
        for k in agg1:
            np.testing.assert_allclose(np.asarray(agg1[k]),
                                       np.asarray(agg2[k]), rtol=1e-5)

    def test_deployment_broadcasts_aggregate(self):
        _, _, (deployed, agg, _) = self._run([1, 1, 0, 0], [1, 1, 1, 1])
        for k in agg:
            for kk in range(4):
                np.testing.assert_allclose(np.asarray(deployed[k][kk]),
                                           np.asarray(agg[k]), rtol=1e-5)

    def test_losses_shape_finite(self):
        _, _, (_, _, losses) = self._run([1, 1, 1, 1], [1, 1, 1, 1])
        assert losses.shape == (4,)
        assert bool(jnp.isfinite(losses).all())


class TestMultimodalRound:
    """Batched multi-modality round: per-(client, modality) masks gate each
    modality's Eq. 21 reduction independently inside one jit'd program."""

    def setup_method(self):
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))

    def _multimodal_inputs(self, K=4):
        # two modalities with different feature shapes (LSTM encoders)
        params, batches = {}, {}
        for i, (m, t, f) in enumerate([("audio", 6, 4), ("imu", 5, 3)]):
            stacked, b = _inputs(K=K, t=t, f=f, seed=10 + i)
            params[m], batches[m] = stacked, b
        return params, batches

    def _run(self, params, batches, select, weight):
        rnd = make_multimodal_federated_round(self.mesh, local_steps=2,
                                              lr=0.05)
        with self.mesh:
            return jax.jit(rnd)(params, batches, select, weight)

    def test_matches_per_modality_single_rounds(self):
        params, batches = self._multimodal_inputs()
        select = {"audio": jnp.asarray([1., 0., 1., 0.]),
                  "imu": jnp.asarray([0., 1., 1., 1.])}
        weight = {m: jnp.asarray([10., 20., 30., 40.]) for m in params}
        deployed, agg, losses = self._run(params, batches, select, weight)

        single = make_federated_round(self.mesh, local_steps=2, lr=0.05)
        for m in params:
            with self.mesh:
                d1, a1, l1 = jax.jit(single)(params[m], batches[m],
                                             select[m], weight[m])
            for k in a1:
                np.testing.assert_allclose(np.asarray(agg[m][k]),
                                           np.asarray(a1[k]), rtol=1e-5)
            np.testing.assert_allclose(np.asarray(losses[m]),
                                       np.asarray(l1), rtol=1e-5)

    def test_per_client_modality_mask_is_independent(self):
        """Changing one modality's mask must not move the other's aggregate."""
        params, batches = self._multimodal_inputs()
        weight = {m: jnp.ones((4,)) for m in params}
        base = {"audio": jnp.asarray([1., 1., 0., 0.]),
                "imu": jnp.asarray([1., 1., 1., 1.])}
        flipped = dict(base, imu=jnp.asarray([0., 0., 1., 1.]))
        _, agg_a, _ = self._run(params, batches, base, weight)
        _, agg_b, _ = self._run(params, batches, flipped, weight)
        for k in agg_a["audio"]:
            np.testing.assert_allclose(np.asarray(agg_a["audio"][k]),
                                       np.asarray(agg_b["audio"][k]),
                                       rtol=1e-5)
        # while the flipped modality's aggregate does move
        assert any(
            float(jnp.max(jnp.abs(agg_a["imu"][k] - agg_b["imu"][k]))) > 1e-6
            for k in agg_a["imu"])

    def test_all_zero_mask_keeps_local_params(self):
        """A modality nobody uploads keeps its per-client local updates."""
        params, batches = self._multimodal_inputs()
        weight = {m: jnp.ones((4,)) for m in params}
        select = {"audio": jnp.zeros((4,)), "imu": jnp.ones((4,))}
        deployed, _, _ = self._run(params, batches, select, weight)
        # audio slots stay distinct (no broadcast happened)
        leaf = deployed["audio"]["w_fc"]
        assert float(jnp.max(jnp.abs(leaf[0] - leaf[1]))) > 1e-6
        # imu slots all equal the aggregate
        leaf = deployed["imu"]["w_fc"]
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[3]),
                                   rtol=1e-5)


def test_selection_masks_encode_joint_selection():
    choices = {0: ["audio"], 1: ["audio", "imu"], 2: ["imu"]}
    masks = selection_masks(choices, selected_clients=[0, 1], num_clients=4,
                            modality_names=["audio", "imu"])
    np.testing.assert_array_equal(np.asarray(masks["audio"]), [1, 1, 0, 0])
    # client 2 chose imu but was not server-selected; client 3 chose nothing
    np.testing.assert_array_equal(np.asarray(masks["imu"]), [0, 1, 0, 0])


def test_multimodal_input_specs_shapes():
    from repro.core.distributed import multimodal_input_specs
    enc = {m: init_encoder(jax.random.key(0), shape, 3)
           for m, shape in [("audio", (6, 4)), ("imu", (5, 3))]}
    param_specs = {m: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), e)
        for m, e in enc.items()}
    specs = multimodal_input_specs(8, 2, 4,
                                   {"audio": (6, 4), "imu": (5, 3)},
                                   param_specs)
    assert specs["batches"]["audio"]["x"].shape == (8, 2, 4, 6, 4)
    assert specs["batches"]["imu"]["x"].shape == (8, 2, 4, 5, 3)
    assert specs["select"]["imu"].shape == (8,)
    for m in enc:
        assert specs["params"][m]["w_fc"].shape == \
            (8,) + enc[m]["w_fc"].shape


@pytest.mark.slow
def test_multi_device_mesh_subprocess():
    """8 forced host devices, clients sharded 4-way over 'data'."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import make_federated_round
        from repro.core.encoders import init_encoder
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        K = 8
        enc = init_encoder(jax.random.key(0), (6, 4), 3)
        stacked = jax.tree.map(lambda x: jnp.stack([x] * K), enc)
        x = jax.random.normal(jax.random.key(1), (K, 2, 8, 6, 4))
        y = jax.random.randint(jax.random.key(2), (K, 2, 8), 0, 3)
        sel = jnp.asarray([1, 0] * 4, jnp.float32)
        w = jnp.ones((K,))
        rnd = make_federated_round(mesh, local_steps=2, lr=0.05)
        with mesh:
            d, agg, losses = jax.jit(rnd)(stacked, {"x": x, "y": y}, sel, w)
        assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(agg))
        err = max(float(jnp.max(jnp.abs(v - a[None])))
                  for v, a in zip(jax.tree.leaves(d), jax.tree.leaves(agg)))
        assert err < 1e-5, err
        print("MULTI_DEVICE_OK")
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MULTI_DEVICE_OK" in out.stdout, out.stderr[-2000:]

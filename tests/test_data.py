"""Data pipeline tests: registry shapes, generators, all five partitioners."""
import numpy as np
import pytest

from repro.data import (DATASETS, get_dataset_spec, make_dataset,
                        make_federation)
from repro.data.partition import (partition_class_noniid, partition_iid,
                                  partition_longtail,
                                  partition_modality_noniid,
                                  partition_natural)


class TestRegistry:
    def test_table1_counts(self):
        assert get_dataset_spec("actionsense").num_clients == 9
        assert get_dataset_spec("ucihar").num_clients == 30
        assert get_dataset_spec("ptbxl").num_clients == 39
        assert get_dataset_spec("meld").num_clients == 42
        assert get_dataset_spec("dfc23").num_clients == 27

    def test_table1_modalities(self):
        assert len(get_dataset_spec("actionsense").modalities) == 6
        spec = get_dataset_spec("dfc23")
        assert all(m.kind == "image" for m in spec.modalities)
        assert spec.modality("optical").shape == (32, 32, 3)

    def test_ucihar_identical_encoder_sizes(self):
        # the paper's §4.4 point: both UCI-HAR modalities have equal dims
        spec = get_dataset_spec("ucihar")
        assert spec.modalities[0].shape == spec.modalities[1].shape


class TestGenerator:
    @pytest.mark.parametrize("name", sorted(DATASETS))
    def test_shapes_and_determinism(self, name):
        ds = make_dataset(name, seed=3)
        spec = ds.spec
        labels = np.arange(spec.num_classes).repeat(2) % spec.num_classes
        c1 = ds.sample_client(0, labels, spec.modality_names)
        c2 = make_dataset(name, seed=3).sample_client(
            0, labels, spec.modality_names)
        for m in spec.modality_names:
            exp = spec.modality(m).feature_shape(True)
            assert c1.modalities[m].shape == (len(labels),) + exp
            np.testing.assert_array_equal(c1.modalities[m],
                                          c2.modalities[m])

    def test_client_heterogeneity(self):
        ds = make_dataset("ucihar", seed=0)
        labels = np.zeros(4, np.int64)
        a = ds.sample_client(0, labels, ["accelerometer"])
        b = ds.sample_client(1, labels, ["accelerometer"])
        assert not np.allclose(a.modalities["accelerometer"],
                               b.modalities["accelerometer"])

    def test_split(self):
        ds = make_dataset("ucihar", seed=0)
        data = ds.sample_client(0, np.arange(20) % 6, ["gyroscope"])
        tr, te = data.split(0.8)
        assert tr.num_samples == 16 and te.num_samples == 4


class TestPartitioners:
    def test_iid(self):
        ds = make_dataset("ucihar", seed=0)
        clients = partition_iid(ds, samples_per_client=24)
        assert len(clients) == 30
        for c in clients:
            assert c.num_samples == 24
            assert set(c.modality_names) == {"accelerometer", "gyroscope"}
            # balanced-ish classes
            assert len(np.unique(c.labels)) == 6

    def test_natural_missing_modalities(self):
        ds = make_dataset("actionsense", seed=0)
        clients = partition_natural(ds, samples_per_client=16)
        for k in (5, 6, 7, 8):
            assert "tactile_left" not in clients[k].modalities
            assert "tactile_right" not in clients[k].modalities
        assert "tactile_left" in clients[0].modalities

    def test_natural_skew(self):
        ds = make_dataset("ptbxl", seed=0)
        clients = partition_natural(ds, samples_per_client=64)
        counts = sorted(c.num_samples for c in clients)
        assert counts[-1] > 5 * counts[0]   # heavy head

    def test_dirichlet_concentration(self):
        ds = make_dataset("ucihar", seed=0)
        skewed = partition_class_noniid(ds, beta=0.1, samples_per_client=60)
        uniform = partition_class_noniid(ds, beta=100.0,
                                         samples_per_client=60)

        def mean_entropy(cs):
            es = []
            for c in cs:
                p = np.bincount(c.labels, minlength=6) / c.num_samples
                es.append(-(p[p > 0] * np.log(p[p > 0])).sum())
            return np.mean(es)

        assert mean_entropy(skewed) < mean_entropy(uniform) - 0.3

    @pytest.mark.parametrize("rate", [0.3, 0.8])
    def test_modality_noniid(self, rate):
        ds = make_dataset("actionsense", seed=0)
        clients = partition_modality_noniid(ds, missing_rate=rate,
                                            samples_per_client=8)
        for c in clients:
            assert len(c.modality_names) >= 2       # keep_min
        total = sum(len(c.modality_names) for c in clients)
        assert total < 9 * 6                        # some dropped

    def test_longtail_if(self):
        ds = make_dataset("ucihar", seed=0)
        clients = partition_longtail(ds, imbalance_factor=50,
                                     max_samples=100)
        counts = [c.num_samples for c in clients]
        assert max(counts) / max(min(counts), 1) > 10

    def test_make_federation_dispatch(self):
        clients = make_federation("meld", "iid", samples_per_client=8)
        assert len(clients) == 42

"""The batched backend is a pure execution-strategy change: round-1 server
encoders, comm-ledger bytes, uploads, and losses must match the Python-loop
backend to float tolerance on the same federation."""

import numpy as np
import pytest

from repro.core.batched import plan_permutations
from repro.core.rounds import MFedMCConfig, build_federation, run_federation

TOL = 1e-5


def _run(backend, dataset="ucihar", scenario="iid", n=24, **cfg_kw):
    base = dict(rounds=1, local_epochs=2, batch_size=10, seed=0,
                modality_strategy="random", gamma=1)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = build_federation(dataset, scenario, cfg=cfg, seed=0,
                                     samples_per_client=n)
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _assert_server_match(se_loop, se_batched):
    assert set(se_loop) == set(se_batched)
    for m in se_loop:
        for k in se_loop[m]:
            np.testing.assert_allclose(np.asarray(se_batched[m][k]),
                                       np.asarray(se_loop[m][k]),
                                       atol=TOL, rtol=0,
                                       err_msg=f"{m}/{k}")


class TestLoopBatchedParity:
    def test_round1_server_encoders_and_ledger(self):
        se_l, h_l, _ = _run("loop")
        se_b, h_b, _ = _run("batched")
        _assert_server_match(se_l, se_b)
        assert h_b.records[0].comm_mb == h_l.records[0].comm_mb
        assert h_b.records[0].uploads == h_l.records[0].uploads

    def test_parity_with_partial_batches(self):
        # n=24, B=10 -> 2 full batches + a trailing partial batch of 4
        se_l, h_l, _ = _run("loop", batch_size=10, n=24)
        se_b, h_b, _ = _run("batched", batch_size=10, n=24)
        _assert_server_match(se_l, se_b)
        assert h_b.records[0].accuracy == pytest.approx(
            h_l.records[0].accuracy, abs=1e-6)

    def test_parity_on_ragged_federation(self):
        # actionsense 'natural': structural missing modalities + skewed
        # sample counts all run on the padded mask-weighted batched path
        kw = dict(dataset="actionsense", scenario="natural", n=20,
                  local_epochs=1, batch_size=8)
        se_l, h_l, _ = _run("loop", **kw)
        se_b, h_b, _ = _run("batched", **kw)
        _assert_server_match(se_l, se_b)
        assert h_b.records[0].comm_mb == h_l.records[0].comm_mb

    def test_parity_full_paper_strategy(self):
        # priority modality selection (Shapley) + low-loss client selection
        kw = dict(modality_strategy="priority", client_strategy="low_loss",
                  local_epochs=1, background_size=12, eval_size=12)
        se_l, h_l, _ = _run("loop", **kw)
        se_b, h_b, _ = _run("batched", **kw)
        _assert_server_match(se_l, se_b)
        assert h_b.records[0].uploads == h_l.records[0].uploads
        assert h_b.records[0].shapley.keys() == h_l.records[0].shapley.keys()

    def test_multi_round_losses_track(self):
        _, h_l, cl_l = _run("loop", rounds=2, local_epochs=1)
        _, h_b, cl_b = _run("batched", rounds=2, local_epochs=1)
        for c_l, c_b in zip(cl_l, cl_b):
            for m in c_l.modality_names:
                assert c_b.losses[m] == pytest.approx(c_l.losses[m],
                                                      abs=1e-5)
        np.testing.assert_allclose(h_b.accuracies, h_l.accuracies, atol=1e-3)

    def test_unknown_backend_rejected(self):
        cfg = MFedMCConfig(rounds=1)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=16)
        with pytest.raises(ValueError):
            run_federation(clients, spec, cfg, backend="gpu")


class TestPermutationPlan:
    def test_plan_consumes_rng_like_loop(self):
        cfg = MFedMCConfig(rounds=1, local_epochs=3)
        clients, _ = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                      samples_per_client=16)
        rng_a = np.random.default_rng(7)
        plans = plan_permutations(clients[:2], 3, rng_a)
        rng_b = np.random.default_rng(7)
        for c in clients[:2]:
            n = c.train.num_samples
            for m in c.modality_names:
                for e in range(3):
                    expect = rng_b.permutation(n)
                    got = next(p for p in plans
                               if p.client is c).encoder_perms[m][e]
                    np.testing.assert_array_equal(got, expect)
            for e in range(3):
                expect = rng_b.permutation(n)
                got = next(p for p in plans if p.client is c).fusion_perms[e]
                np.testing.assert_array_equal(got, expect)
        # both generators end in the same state
        assert rng_a.integers(1 << 30) == rng_b.integers(1 << 30)

"""Launcher smoke tests (direct main() calls, tiny workloads)."""
import sys

import pytest


@pytest.mark.slow
def test_train_launcher_smoke(tmp_path):
    from repro.launch.train import main
    ckpt = str(tmp_path / "ck.npz")
    assert main(["--arch", "xlstm-125m", "--steps", "3",
                 "--ckpt", ckpt]) == 0


@pytest.mark.slow
def test_serve_launcher_smoke():
    from repro.launch.serve import main
    assert main(["--arch", "phi3-medium-14b", "--tokens", "4",
                 "--batch", "1", "--cache-len", "32"]) == 0


@pytest.mark.slow
def test_fed_train_launcher_smoke():
    import subprocess, os
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.fed_train", "--dataset",
         "ucihar", "--rounds", "1", "--devices", "2", "--steps", "2",
         "--batch", "8"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-1000:]
    assert "done" in out.stdout

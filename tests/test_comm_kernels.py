"""Fused communication hot path (``repro.kernels.comm``) parity pinning.

Four layers:

1. **Kernel vs oracle (``kernels`` marker).** The Pallas ``quantize_pack``
   and ``dequantize_weight_reduce`` kernels (interpret mode on CPU) must be
   bit-identical to the ``ref.py`` oracles on packed words, scales and
   zeros, and ≤1e-5 on Eq. 21 aggregates — across bits 1..16, odd leaf
   sizes, and multi-tile rows.
2. **Production programs vs oracle.** The XLA population programs the
   federation backends actually call (``quantize_pack_population`` /
   ``reduce_packed_population``, plus the error-feedback variant) carry the
   same bit-identity contract, and their payload bytes equal the §4.10
   ledger's wire accounting exactly at packable widths.
3. **pack/unpack content round-trip.** Property test over every bits ∈
   1..16 × odd sizes (hypothesis where available, seeded sweep otherwise) —
   the historical tests pinned only the packed *size* at non-divisor
   widths.
4. **Full-round fused-vs-reference.** ``comm_impl="fused"`` vs
   ``"reference"`` through the real backends: batched/engine/async (and
   sharded at D ∈ {1, 8} via the ``multidevice`` tier), with error
   feedback, with identical ledgers and ≤1e-5 server encoders — and the
   fused path must measure *fewer* uplink bytes on the
   ``repro.core.hostsync`` counter at sub-byte precision.

``REPRO_COMM_IMPL`` (fused|reference) selects the config default exercised
by the smoke-round test; CI runs this module once per mode.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hostsync
from repro.core.encoders import init_encoder
from repro.core.quantize import (code_dtype, pack_codes, pytree_wire_bytes,
                                 quantize_population,
                                 quantize_population_with_error_feedback,
                                 unpack_codes)
from repro.core.rounds import MFedMCConfig, build_federation, run_federation
from repro.kernels.comm import (dequantize_weight_reduce_pallas,
                                packed_width, payload_nbytes,
                                quantize_pack_pallas,
                                quantize_pack_population,
                                quantize_pack_population_ef,
                                reduce_packed_population, wire_payload_bytes)
from repro.kernels.ref import dequantize_weight_reduce_ref, quantize_pack_ref

TOL = 1e-5
COMM_IMPL = os.environ.get("REPRO_COMM_IMPL", "fused")

ALL_BITS = (1, 2, 3, 4, 5, 8, 12, 16)
# odd sizes, a sub-tile row, and a >2-tile row (kernel tile = 1024)
SHAPES = ((4, (7, 9)), (3, (2050,)), (1, (5,)), (2, (13, 3, 5)))


def _rows(bits, k, shape, seed_mul=100):
    key = jax.random.fold_in(jax.random.key(0), bits * seed_mul + k)
    return jax.random.normal(key, (k,) + shape)


# ---------------------------------------------------------------------------
# layer 1: Pallas kernels vs pure-jnp oracles
# ---------------------------------------------------------------------------

@pytest.mark.kernels
class TestKernelVsOracle:
    @pytest.mark.parametrize("bits", ALL_BITS)
    def test_quantize_pack_bit_identical(self, bits):
        for k, shape in SHAPES:
            x = _rows(bits, k, shape)
            pr, sr, zr = quantize_pack_ref(x, bits)
            pk, sk, zk = quantize_pack_pallas(x, bits, interpret=True)
            n = int(np.prod(shape))
            assert pk.shape == (k, packed_width(n, bits))
            assert pk.dtype == pr.dtype
            np.testing.assert_array_equal(np.asarray(pr), np.asarray(pk),
                                          err_msg=f"bits={bits} {shape}")
            np.testing.assert_array_equal(np.asarray(sr), np.asarray(sk))
            np.testing.assert_array_equal(np.asarray(zr), np.asarray(zk))

    @pytest.mark.parametrize("bits", ALL_BITS)
    def test_dequantize_weight_reduce(self, bits):
        for k, shape in SHAPES:
            x = _rows(bits, k, shape)
            n = int(np.prod(shape))
            p, s, z = quantize_pack_ref(x, bits)
            w = jnp.arange(1.0, k + 1.0)
            want = dequantize_weight_reduce_ref(p, s, z, w, bits=bits, n=n)
            got = dequantize_weight_reduce_pallas(p, s, z, w, bits=bits,
                                                  n=n, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=TOL, rtol=0,
                                       err_msg=f"bits={bits} {shape}")

    def test_staleness_discounted_weights(self):
        """Async staleness discounts are plain per-client weights — the
        fused reduction must honour arbitrary positive w_k."""
        x = _rows(4, 4, (33,))
        p, s, z = quantize_pack_ref(x, 4)
        w = jnp.asarray([24.0 * 0.5 ** 3, 16.0, 8.0 * 0.5, 0.0])
        want = dequantize_weight_reduce_ref(p, s, z, w, bits=4, n=33)
        got = dequantize_weight_reduce_pallas(p, s, z, w, bits=4, n=33,
                                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=TOL, rtol=0)

    def test_all_zero_weights_yield_zeros_not_nan(self):
        x = _rows(4, 2, (17,))
        p, s, z = quantize_pack_ref(x, 4)
        out = dequantize_weight_reduce_pallas(p, s, z, jnp.zeros((2,)),
                                              bits=4, n=17, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.zeros(17))

    def test_constant_row_quantizes_under_zero_range_guard(self):
        x = jnp.concatenate([jnp.full((1, 40), 3.0),
                             _rows(2, 1, (40,))])
        pr, sr, zr = quantize_pack_ref(x, 2)
        pk, sk, zk = quantize_pack_pallas(x, 2, interpret=True)
        np.testing.assert_array_equal(np.asarray(pr), np.asarray(pk))
        assert float(sk[0]) == pytest.approx(1e-12)


# ---------------------------------------------------------------------------
# layer 2: production XLA programs vs oracle + wire-byte identity
# ---------------------------------------------------------------------------

class TestProductionPrograms:
    def _tree(self, k=4, seed=0):
        enc = init_encoder(jax.random.key(seed), (6, 4), 3)
        return jax.tree.map(
            lambda v: jnp.stack([v + 0.01 * i for i in range(k)]), enc)

    @pytest.mark.parametrize("bits", (2, 4, 8, 16))
    def test_population_bit_identical_to_oracle(self, bits):
        stacked = self._tree()
        P, S, Z = quantize_pack_population(stacked, bits=bits)
        w = jnp.asarray([3.0, 1.0, 4.0, 1.5])
        shapes = tuple(tuple(l.shape[1:])
                       for l in jax.tree_util.tree_leaves(stacked))
        agg = reduce_packed_population(P, S, Z, w, bits=bits, shapes=shapes)
        for name in stacked:
            pr, sr, zr = quantize_pack_ref(stacked[name], bits)
            np.testing.assert_array_equal(np.asarray(P[name]),
                                          np.asarray(pr), err_msg=name)
            np.testing.assert_array_equal(np.asarray(S[name]),
                                          np.asarray(sr))
            np.testing.assert_array_equal(np.asarray(Z[name]),
                                          np.asarray(zr))
            n = int(np.prod(stacked[name].shape[1:]))
            want = dequantize_weight_reduce_ref(pr, sr, zr, w, bits=bits,
                                                n=n)
            np.testing.assert_allclose(
                np.asarray(agg[name]).reshape(-1), np.asarray(want),
                atol=TOL, rtol=0, err_msg=name)

    @pytest.mark.parametrize("bits", (2, 4, 8))
    def test_error_feedback_program_bit_identical(self, bits):
        stacked = self._tree()
        res = jax.tree.map(
            lambda v: jnp.full(v.shape, 0.03, jnp.float32), stacked)
        c0, s0, z0, r0 = quantize_population_with_error_feedback(
            stacked, res, bits=bits)
        P, S, Z, R = quantize_pack_population_ef(stacked, res, bits=bits)
        pack_pop = jax.jit(jax.vmap(
            lambda row: pack_codes(row.reshape(-1), bits)))
        for name in stacked:
            np.testing.assert_array_equal(np.asarray(pack_pop(c0[name])),
                                          np.asarray(P[name]), err_msg=name)
            np.testing.assert_array_equal(np.asarray(s0[name]),
                                          np.asarray(S[name]))
            np.testing.assert_array_equal(np.asarray(z0[name]),
                                          np.asarray(Z[name]))
            np.testing.assert_allclose(np.asarray(r0[name]),
                                       np.asarray(R[name]), atol=1e-6,
                                       rtol=0, err_msg=name)

    @pytest.mark.parametrize("bits", (1, 2, 4, 8, 16))
    def test_payload_bytes_equal_ledger_wire_bytes(self, bits):
        """At packable widths the fused payload's device bytes ARE the
        ledger's exact wire count: K × (packed codes + 8B metadata/tensor).
        The reference payload carries unpacked containers — strictly more
        below 8 bits."""
        k = 4
        stacked = self._tree(k)
        template = jax.tree.map(lambda v: v[0], stacked)
        P, S, Z = quantize_pack_population(stacked, bits=bits)
        fused = payload_nbytes(P, S, Z)
        assert fused == wire_payload_bytes(template, bits, k)
        assert fused == k * pytree_wire_bytes(template, bits)
        codes, scales, zeros = quantize_population(stacked, bits=bits)
        reference = payload_nbytes(codes, scales, zeros)
        if bits < 8:
            assert fused < reference
        else:
            assert fused == reference


# ---------------------------------------------------------------------------
# layer 3 (satellite): pack/unpack content round-trip, bits 1..16
# ---------------------------------------------------------------------------

class TestPackRoundtripContent:
    @pytest.mark.parametrize("bits", range(1, 17))
    @pytest.mark.parametrize("n", (1, 3, 7, 17, 63, 255, 257))
    def test_seeded_roundtrip(self, bits, n):
        levels = 2 ** bits - 1
        codes = np.random.default_rng(bits * 1000 + n).integers(
            0, levels + 1, size=n).astype(np.dtype(code_dtype(bits)))
        packed = pack_codes(jnp.asarray(codes), bits)
        back = unpack_codes(packed, bits, n, (n,))
        np.testing.assert_array_equal(np.asarray(back), codes,
                                      err_msg=f"bits={bits} n={n}")

    def test_hypothesis_roundtrip(self):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(deadline=None, max_examples=60)
        @given(st.integers(1, 16), st.integers(1, 300), st.integers(0, 2**31))
        def run(bits, n, seed):
            levels = 2 ** bits - 1
            codes = np.random.default_rng(seed).integers(
                0, levels + 1, size=n).astype(np.dtype(code_dtype(bits)))
            packed = pack_codes(jnp.asarray(codes), bits)
            back = unpack_codes(packed, bits, n, (n,))
            np.testing.assert_array_equal(np.asarray(back), codes)

        run()


# ---------------------------------------------------------------------------
# layer 4: full-round fused vs reference through the real backends
# ---------------------------------------------------------------------------

def _run(backend, comm_impl, bits=4, **cfg_kw):
    base = dict(rounds=1, local_epochs=1, batch_size=8, seed=0,
                modality_strategy="random", gamma=1, quantize_bits=bits,
                comm_impl=comm_impl, background_size=12, eval_size=12)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                     samples_per_client=16)
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _assert_server_match(se_a, se_b, atol=TOL):
    assert set(se_a) == set(se_b)
    for m in se_a:
        for k in se_a[m]:
            np.testing.assert_allclose(np.asarray(se_b[m][k]),
                                       np.asarray(se_a[m][k]),
                                       atol=atol, rtol=0,
                                       err_msg=f"{m}/{k}")


class TestFullRoundParity:
    @pytest.mark.parametrize("backend", ("batched", "engine", "async"))
    def test_fused_matches_reference(self, backend):
        se_f, h_f, _ = _run(backend, "fused")
        se_r, h_r, _ = _run(backend, "reference")
        _assert_server_match(se_r, se_f)
        assert h_f.records[0].uploads == h_r.records[0].uploads
        assert h_f.records[0].comm_mb == h_r.records[0].comm_mb

    def test_fused_matches_reference_with_error_feedback(self):
        se_f, _, cl_f = _run("batched", "fused", error_feedback=True)
        se_r, _, cl_r = _run("batched", "reference", error_feedback=True)
        _assert_server_match(se_r, se_f)
        # client-held EF residuals stay bit-compatible across impls
        for a, b in zip(cl_f, cl_r):
            assert set(a.residuals) == set(b.residuals)
            for m in a.residuals:
                for k in a.residuals[m]:
                    np.testing.assert_allclose(
                        np.asarray(a.residuals[m][k]),
                        np.asarray(b.residuals[m][k]), atol=1e-6, rtol=0)

    def test_fused_moves_fewer_bytes_at_4bit(self):
        hostsync.reset()
        _run("engine", "fused")
        fused = hostsync.bytes_moved()
        hostsync.reset()
        _run("engine", "reference")
        reference = hostsync.bytes_moved()
        hostsync.reset()
        assert 0 < fused < reference

    def test_invalid_comm_impl_rejected(self):
        with pytest.raises(ValueError, match="comm_impl"):
            _run("batched", "fussed")

    def test_env_selected_impl_smokes(self):
        """CI runs this module under both REPRO_COMM_IMPL values; whatever
        mode is selected must complete a round and record uplink bytes."""
        hostsync.reset()
        _, hist, _ = _run("batched", COMM_IMPL)
        assert hist.records and hist.records[0].uploads
        assert hostsync.bytes_moved() > 0
        hostsync.reset()


class TestShardedParity:
    def test_sharded_d1_fused_matches_reference(self):
        se_f, h_f, _ = _run("sharded", "fused", mesh_clients=1)
        se_r, h_r, _ = _run("sharded", "reference", mesh_clients=1)
        _assert_server_match(se_r, se_f)
        assert h_f.records[0].comm_mb == h_r.records[0].comm_mb

    @pytest.mark.multidevice
    def test_sharded_d8_fused_matches_reference(self):
        se_f, h_f, _ = _run("sharded", "fused", mesh_clients=8)
        se_r, h_r, _ = _run("sharded", "reference", mesh_clients=8)
        _assert_server_match(se_r, se_f)
        assert h_f.records[0].uploads == h_r.records[0].uploads

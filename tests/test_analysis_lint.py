"""The lint tier: the analysis passes on trial.

Two obligations, tested in both directions:

- **zero findings on main** — every audit runs clean over the real round
  programs of every backend (at the comm impl the session selects via
  ``REPRO_COMM_IMPL``, matching the CI matrix), and the pinned
  ``budgets.json`` matches a fresh measurement;
- **each violation class is caught** — a stray callback, an f32 decision
  op, a per-round recompile, an unguarded masked div, an over-budget psum
  payload, and a regressed host-sync budget are each injected and must
  produce the specific finding, with an actionable message.

Run standalone: ``PYTHONPATH=src python -m pytest -q -m lint``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis.framework import (AGGREGATION, COLLECTIVE, DECISION,
                                      TRAINING, ProgramSpec, run_passes)
from repro.analysis.passes import (CollectiveAuditPass, DonationPass,
                                   HostTransferPass, MaskSafetyPass,
                                   PrecisionPass, default_passes)
from repro.core import hostsync

pytestmark = pytest.mark.lint

COMM_IMPL = os.environ.get("REPRO_COMM_IMPL", "fused")
BACKENDS = ("batched", "engine", "async", "sharded")


def _spec(name, role, fn, *args, **kw):
    return ProgramSpec(name, "test", "n/a", role, jax.make_jaxpr(fn)(*args),
                       **kw)


# ---------------------------------------------------------------------------
# satellite: hostsync.measuring() scoping
# ---------------------------------------------------------------------------

def test_measuring_scopes_and_restores():
    hostsync.fetch(jnp.zeros(3))            # pre-existing outer count
    with hostsync.measuring() as m:
        assert m.syncs == 0 and m.bytes_moved == 0
        hostsync.fetch(jnp.zeros(3))
        hostsync.record_bytes(128)
        assert m.syncs == 1 and m.bytes_moved == 128   # live view
    assert m.syncs == 1 and m.bytes_moved == 128       # frozen after exit
    # outer counters accumulate the scope's activity on top of their own
    assert hostsync.count() == 2
    assert hostsync.bytes_moved() == 128


def test_measuring_nests():
    with hostsync.measuring() as outer:
        hostsync.fetch_scalar(jnp.zeros(()))
        with hostsync.measuring() as inner:
            hostsync.fetch(jnp.zeros(2))
            hostsync.record_bytes(64)
        assert inner.syncs == 1 and inner.bytes_moved == 64
        hostsync.record_bytes(1)
    assert outer.syncs == 2 and outer.bytes_moved == 65
    # a later fetch must not mutate the frozen measurement
    hostsync.fetch(jnp.zeros(1))
    assert outer.syncs == 2


# ---------------------------------------------------------------------------
# satellite: the FLOP meter reports unknown primitives
# ---------------------------------------------------------------------------

def test_flop_meter_surfaces_unknown_primitives():
    from repro.roofline.jaxpr_flops import count_step_flops_detailed
    _, unknown = count_step_flops_detailed(
        jax.lax.population_count, jax.ShapeDtypeStruct((8,), jnp.int32))
    assert unknown == {"population_count": 1}
    # classified ops stay silent
    _, unknown = count_step_flops_detailed(
        lambda a: jnp.sum(a * a), jax.ShapeDtypeStruct((8,), jnp.float32))
    assert unknown == {}


# ---------------------------------------------------------------------------
# violation injection: each pass catches its class
# ---------------------------------------------------------------------------

def test_stray_callback_is_flagged():
    def leaky(a):
        return jax.pure_callback(
            lambda b: b, jax.ShapeDtypeStruct((4,), np.float32), a)

    prog = _spec("inj/callback", TRAINING, leaky,
                 jax.ShapeDtypeStruct((4,), jnp.float32))
    findings = HostTransferPass().check(prog)
    assert len(findings) == 1
    assert "pure_callback" in findings[0].message
    # the same program via jit traces the callback through pjit: still seen
    prog2 = _spec("inj/callback_jit", TRAINING, jax.jit(leaky),
                  jax.ShapeDtypeStruct((4,), jnp.float32))
    assert HostTransferPass().check(prog2)


def test_f32_decision_op_is_flagged():
    with enable_x64():
        x64 = jax.ShapeDtypeStruct((8, 2), jnp.float64)
        bad = ProgramSpec(
            "inj/f32_decision", "test", "n/a", DECISION,
            jax.make_jaxpr(
                lambda a: jnp.sum(a.astype(jnp.float32)))(x64))
        good = ProgramSpec(
            "ctl/f64_decision", "test", "n/a", DECISION,
            jax.make_jaxpr(lambda a: jnp.argsort(jnp.sum(a, axis=1)))(x64))
    findings = PrecisionPass().check(bad)
    assert findings and all("float" in f.message for f in findings)
    assert any("downcast" in f.message for f in findings)
    assert PrecisionPass().check(good) == []


def test_x64_leak_into_aggregation_is_flagged():
    with enable_x64():
        prog = ProgramSpec(
            "inj/x64_leak", "test", "n/a", AGGREGATION,
            jax.make_jaxpr(lambda a: a.astype(jnp.float64).sum())(
                jax.ShapeDtypeStruct((8,), jnp.float32)))
    findings = PrecisionPass().check(prog)
    assert any("float64 leaked" in f.message for f in findings)


def test_unguarded_masked_div_is_flagged():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    bad = _spec("inj/raw_div", AGGREGATION,
                lambda a, w: jnp.sum(a * w) / jnp.sum(w), x, x)
    findings = MaskSafetyPass().check(bad)
    assert len(findings) == 1 and "unguarded div" in findings[0].message
    # every real guard idiom passes
    for name, fn in [
        ("max_eps", lambda a, w: jnp.sum(a * w) /
         jnp.maximum(jnp.sum(w), 1e-12)),
        ("max_one", lambda a, w: jnp.sum(a * w) /
         jnp.maximum(jnp.sum(w), 1.0)),
        ("where", lambda a, w: a / jnp.where(w > 0, w, 1.0)),
        ("softmax_sum", lambda a, w: jnp.exp(a) / jnp.sum(jnp.exp(a))),
    ]:
        assert MaskSafetyPass().check(
            _spec(f"ctl/{name}", AGGREGATION, fn, x, x)) == [], name


def test_unguarded_rsqrt_is_flagged():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    bad = _spec("inj/rsqrt", TRAINING, lambda a: jax.lax.rsqrt(a), x)
    assert MaskSafetyPass().check(bad)
    good = _spec("ctl/rsqrt", TRAINING,
                 lambda a: jax.lax.rsqrt(jnp.maximum(a, 1e-6)), x)
    assert MaskSafetyPass().check(good) == []


def test_undonated_resident_stack_is_flagged():
    """Satellite: a fused round program that loses its donate_argnums —
    re-jitted without the flag — must fail the donation audit."""
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def prog(meta):
        return _spec("inj/undonated", TRAINING, lambda p, g: p - 0.1 * g,
                     x, x, meta=meta)

    bad = DonationPass().check(prog(
        {"donation": {"resident": (0,), "donated": (False, False)}}))
    assert len(bad) == 1 and "NOT donated" in bad[0].message
    assert "donate_argnums" in bad[0].message
    good = DonationPass().check(prog(
        {"donation": {"resident": (0,), "donated": (True, False)}}))
    assert good == []
    # programs without a donation contract (the per-epoch reference
    # chain) are out of scope, as are non-training roles
    assert DonationPass().check(prog({})) == []
    agg = ProgramSpec("ctl/agg", "test", "n/a", AGGREGATION,
                      jax.make_jaxpr(lambda p: p * 2)(x),
                      meta={"donation": {"resident": (0,),
                                         "donated": (False,)}})
    assert DonationPass().check(agg) == []


def test_real_fused_programs_record_donation():
    """The lowering-derived meta on the real fused specs proves the
    resident stacks ARE donated, for every backend including the sharded
    shard_map form."""
    from repro.analysis.programs import round_programs
    fused = [p for b in BACKENDS for p in round_programs(b, COMM_IMPL)
             if "round_encoder_fused" in p.name
             or "round_fusion_fused" in p.name]
    assert len(fused) >= 2 * len(BACKENDS)
    for p in fused:
        don = p.meta["donation"]
        assert don["donated"][0] is True, p.name
        assert DonationPass().check(p) == [], p.name


def test_overbudget_psum_is_flagged():
    from repro.sharding.partition import client_mesh, client_spec
    mesh = client_mesh(1)
    spec = client_spec()
    stacked = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((8,), jnp.float32)

    def per_row_leak(s, ww):                # psums the whole population
        return jax.lax.psum(s * ww[:, None], "clients")

    def partials_only(s, ww):               # the correct Eq. 21 shape
        wsum = jax.lax.psum(jnp.sum(ww), "clients")
        wn = ww / jnp.maximum(wsum, 1e-12)
        return jax.lax.psum(jnp.einsum("k,kn->n", wn, s), "clients")

    def as_prog(name, fn):
        jitted = jax.jit(shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                                   out_specs=P()))
        return ProgramSpec(name, "sharded", COMM_IMPL, COLLECTIVE,
                           jax.make_jaxpr(jitted)(stacked, w),
                           mesh_devices=1)

    bad = CollectiveAuditPass().check(as_prog("inj/psum_rows",
                                              per_row_leak))
    assert bad and "exceeds the [leaf]-shaped partial bound" in \
        bad[0].message
    assert CollectiveAuditPass().check(
        as_prog("ctl/psum_partials", partials_only)) == []
    # an aggregate that never reduces across the mesh is also wrong
    none = CollectiveAuditPass().check(ProgramSpec(
        "inj/no_collective", "sharded", COMM_IMPL, COLLECTIVE,
        jax.make_jaxpr(lambda s: s * 2)(stacked), mesh_devices=1))
    assert none and "no collective" in none[0].message


def test_per_round_recompile_is_flagged():
    from repro.analysis.recompile import audit_rounds

    @jax.jit
    def step(x):
        return jnp.sum(x * 2)

    def leaky_round(i):                     # fresh shape every round
        step(np.ones(100 + i, np.float32))

    findings, report = audit_rounds(leaky_round, rounds=3,
                                    program="inj/leaky")
    assert findings and report.count >= 3
    assert "step" in findings[0].message

    def steady_round(i):                    # constant shape: warm cache
        step(np.ones(50, np.float32))

    findings, report = audit_rounds(steady_round, rounds=3,
                                    program="ctl/steady")
    assert findings == [] and report.count == 0


# ---------------------------------------------------------------------------
# zero findings on main
# ---------------------------------------------------------------------------

def test_static_passes_clean_on_all_backends():
    from repro.analysis.lint import lint_static
    targets = [(b, COMM_IMPL) for b in BACKENDS]
    findings, unknown = lint_static(targets)
    assert findings == [], [str(f) for f in findings]
    assert unknown == {}, (
        f"unclassified primitives in the FLOP meter: {unknown}")


def test_budget_manifest_matches_reality():
    """The checked-in budgets.json replays: a fresh measurement of the
    engine backend at this session's comm impl is byte-identical."""
    from repro.analysis import budgets
    pinned = budgets.load_budgets()
    assert pinned is not None, "budgets.json missing — run lint --bless"
    measured = {"config": pinned["config"],
                "engine": {COMM_IMPL: budgets.measure("engine",
                                                      COMM_IMPL)}}
    findings = budgets.compare(measured, pinned)
    assert findings == [], [str(f) for f in findings]


def test_regressed_budget_fails_with_actionable_diff(monkeypatch):
    """Satellite (c): an extra hostsync.fetch smuggled into the round
    path must fail the budget audit with an expected-vs-measured diff."""
    from repro.analysis import budgets
    from repro.core import rounds as rounds_mod
    pinned = budgets.load_budgets()
    orig = rounds_mod.aggregate_uploads

    def chatty_aggregate(*args, **kwargs):  # one stray fetch per upload
        hostsync.fetch(jnp.zeros(()))
        return orig(*args, **kwargs)

    monkeypatch.setattr(rounds_mod, "aggregate_uploads", chatty_aggregate)
    measured = {"config": pinned["config"],
                "engine": {COMM_IMPL: budgets.measure("engine",
                                                      COMM_IMPL)}}
    findings = budgets.compare(measured, pinned)
    assert len(findings) == 1
    msg = findings[0].message
    exp = pinned["engine"][COMM_IMPL]["host_syncs"]
    got = measured["engine"][COMM_IMPL]["host_syncs"]
    assert got > exp
    assert f"expected {exp}" in msg and f"measured {got}" in msg
    assert "re-bless" in msg and "host syncs" in msg


def test_lint_cli_static_clean():
    from repro.analysis.lint import main
    assert main(["--backend", "all", "--comm-impl", COMM_IMPL,
                 "--static-only"]) == 0


def test_run_passes_order_is_deterministic():
    x = jax.ShapeDtypeStruct((4,), jnp.float32)
    progs = [_spec("a/raw_div", AGGREGATION, lambda a: a / jnp.sum(a), x),
             _spec("b/callback", TRAINING,
                   lambda a: jax.pure_callback(
                       lambda b: b, jax.ShapeDtypeStruct((4,), np.float32),
                       a), x)]
    first = [str(f) for f in run_passes(default_passes(), progs)]
    second = [str(f) for f in run_passes(default_passes(), progs)]
    assert first == second
    # (program, pass) order: program a's mask-safety finding precedes
    # program b's host-transfer finding
    assert [f.split("]")[0] for f in first] == ["[mask-safety",
                                                "[host-transfer"]

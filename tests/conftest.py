"""Shared fixtures. NOTE: no XLA device-count forcing here — smoke tests and
benchmarks must see the single real CPU device (the dry-run launcher is the
only entry point that forces 512 host devices, in its own process)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

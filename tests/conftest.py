"""Shared fixtures + the multi-device test tier.

NOTE: no XLA device-count forcing at import here — smoke tests and
benchmarks must see the single real CPU device. Tests marked
``@pytest.mark.multidevice`` need a real 8-way mesh instead; since
``XLA_FLAGS=--xla_force_host_platform_device_count`` only takes effect
before jax initializes, this conftest re-execs each marked test in a fresh
subprocess with the flag set (and reports its outcome as the test's own).
A session that *already* sees ≥8 devices — CI's forced-8 job, or the child
itself — runs the marked tests inline with zero overhead.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

MULTIDEVICE_DEVICES = 8
_CHILD_ENV = "REPRO_MULTIDEVICE_CHILD"
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _hostsync_isolation():
    """Zero the process-global host-sync/bytes counters around every test:
    a module that measures (lint tier, budget round-trips) can never leak
    counts into — or inherit counts from — an unrelated test."""
    from repro.core import hostsync
    hostsync.reset()
    yield
    hostsync.reset()


def _device_count() -> int:
    import jax
    return jax.device_count()


def run_forced_multidevice(nodeid: str) -> subprocess.CompletedProcess:
    """Re-exec one pytest node under a forced 8-device host platform."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count="
                        f"{MULTIDEVICE_DEVICES}").strip()
    env[_CHILD_ENV] = "1"
    env.setdefault("PYTHONPATH", os.path.join(_ROOT, "src"))
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         nodeid],
        cwd=_ROOT, env=env, capture_output=True, text=True, timeout=1500)


def pytest_runtest_setup(item):
    if item.get_closest_marker("multidevice") is None:
        return
    if os.environ.get(_CHILD_ENV):
        if _device_count() < MULTIDEVICE_DEVICES:
            pytest.fail(f"multidevice child saw {_device_count()} devices; "
                        "XLA_FLAGS forcing did not take effect")
        return                                  # child: run inline
    if _device_count() >= MULTIDEVICE_DEVICES:
        return                                  # forced-8 session: inline
    res = run_forced_multidevice(item.nodeid)
    if res.returncode != 0:
        pytest.fail("multidevice subprocess failed "
                    f"(exit {res.returncode}):\n{res.stdout[-6000:]}\n"
                    f"{res.stderr[-2000:]}", pytrace=False)
    # the child already ran (and passed) this exact node on 8 devices;
    # make the local call a no-op so the node reports one green result
    item.runtest = lambda: None

"""Sharding-rule regressions found during the dry-run: vocab padding and
the sequence-sharded decode cache default."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.models.model import cache_specs, param_specs
from repro.sharding.partition import cache_pspecs, register_mesh


class _FakeMesh:
    shape = {"data": 16, "model": 16}


class TestVocabPadding:
    @pytest.mark.parametrize("arch", list_archs())
    def test_padded_vocab_divides_model_axis(self, arch):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256

    def test_embed_uses_padded(self):
        cfg = get_config("granite-moe-1b-a400m")
        specs = param_specs(cfg)
        assert specs["embed"].shape[0] == cfg.padded_vocab
        assert specs["lm_head"].shape[1] == cfg.padded_vocab


class TestCacheSeqSharding:
    def _kv_specs(self, arch, seq_shard):
        register_mesh(_FakeMesh())
        cfg = get_config(arch)
        shape = INPUT_SHAPES["decode_32k"]
        specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
        return cfg, cache_pspecs(cfg, specs, shape, False,
                                 seq_shard=seq_shard)

    def test_default_shards_sequence_over_model(self):
        cfg, pspecs = self._kv_specs("yi-34b", True)
        k_spec = pspecs["layers"]["k"]
        # [L, B, T, KV, hd]: batch on data, seq on model
        assert k_spec[1] == "data"
        assert k_spec[2] == "model"

    def test_baseline_replicates_sequence(self):
        cfg, pspecs = self._kv_specs("yi-34b", False)
        k_spec = pspecs["layers"]["k"]
        assert k_spec[2] is None

    def test_long500k_context_parallel(self):
        register_mesh(_FakeMesh())
        cfg = get_config("xlstm-125m")
        # SSM carries recurrent state — no T dim; use a dense arch instead
        cfg = get_config("phi3-medium-14b")
        shape = INPUT_SHAPES["long_500k"]
        specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
        pspecs = cache_pspecs(cfg, specs, shape, False)
        k_spec = pspecs["layers"]["k"]
        # batch==1: sequence sharded over every axis
        assert k_spec[2] == ("data", "model")

"""Roofline metering tests: the jaxpr FLOP counter must multiply scan trip
counts (the exact failure mode of XLA's cost_analysis), and the collective
parser must weight while-body collectives by their trip count."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import (analytic_hbm_bytes, collective_bytes,
                            count_step_flops)
from repro.roofline.collectives import (computation_multipliers,
                                        split_computations)


class TestJaxprFlops:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        flops = count_step_flops(lambda x, y: x @ y, a, b)
        assert flops == pytest.approx(2 * 32 * 64 * 128, rel=0.01)

    def test_scan_multiplies_trip_count(self):
        d, L = 64, 8
        h = jax.ShapeDtypeStruct((4, d), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)

        def f(h0, w):
            out, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), h0, w)
            return out

        flops = count_step_flops(f, h, ws)
        assert flops == pytest.approx(L * 2 * 4 * d * d, rel=0.01)

    def test_grad_roughly_3x_forward(self):
        d = 32
        x = jax.ShapeDtypeStruct((8, d), jnp.float32)
        w = jax.ShapeDtypeStruct((d, d), jnp.float32)

        def loss(ww, xx):
            return jnp.sum((xx @ ww) ** 2)

        fwd = count_step_flops(loss, w, x)
        bwd = count_step_flops(jax.grad(loss), w, x)
        assert 2.0 <= bwd / fwd <= 4.0

    def test_remat_counts_recompute(self):
        d, L = 32, 4
        h = jax.ShapeDtypeStruct((4, d), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)

        def f(h0, w):
            body = jax.checkpoint(lambda c, wi: (jnp.tanh(c @ wi), None))
            out, _ = jax.lax.scan(body, h0, w)
            return jnp.sum(out)

        plain = count_step_flops(jax.grad(f, argnums=1), h, ws)
        # remat bwd >= non-remat fwd * 3 (fwd + recompute + transpose)
        fwd = count_step_flops(f, h, ws)
        assert plain >= 2.5 * fwd

    def test_batched_dot_general(self):
        a = jax.ShapeDtypeStruct((2, 8, 16, 32), jnp.float32)
        b = jax.ShapeDtypeStruct((2, 8, 32, 64), jnp.float32)
        flops = count_step_flops(
            lambda x, y: jnp.einsum("bhik,bhkj->bhij", x, y), a, b)
        assert flops == pytest.approx(2 * 2 * 8 * 16 * 32 * 64, rel=0.01)


SYNTH_HLO = """\
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (param: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}

%cond.1 (param.1: (s32[], f32[128,256])) -> pred[] {
  %p1 = (s32[], f32[128,256]) parameter(0)
  %limit = s32[] constant(12)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

ENTRY %main (p: f32[128,256]) -> f32[] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[64,64]{1,0} all-gather(%y), replica_groups={}
  ROOT %r = f32[] reduce(%z)
}
"""


class TestCollectiveParser:
    def test_split(self):
        comps = split_computations(SYNTH_HLO)
        assert set(comps) == {"body.1", "cond.1", "main"}

    def test_trip_multiplier(self):
        _, mult = computation_multipliers(SYNTH_HLO)
        assert mult["body.1"] == 12
        assert mult["main"] == 1

    def test_weighted_bytes(self):
        out = collective_bytes(SYNTH_HLO)
        assert out["all-reduce"] == 12 * 128 * 256 * 4
        assert out["all-gather"] == 64 * 64 * 4


class TestAnalyticMemory:
    def test_train_terms(self):
        from repro.configs import get_config, get_shape
        cfg = get_config("phi3-medium-14b")
        shape = get_shape("train_4k")
        m = analytic_hbm_bytes(cfg, shape, 256, 16)
        assert m["total"] == m["params"] + m["acts"] + m["logits"] + m["cache"]
        assert m["params"] > 0 and m["acts"] > 0

    def test_decode_cache_dominates_params_at_32k(self):
        from repro.configs import get_config, get_shape
        cfg = get_config("yi-34b")
        m = analytic_hbm_bytes(cfg, get_shape("decode_32k"), 256, 16)
        assert m["cache"] > 0


class TestQuantizedUplinkRoofline:
    """The real-round comm meters: byte bounds must order wire ≤ fused ≤
    reference ≤ raw, with fused exactly the wire format below 8 bits."""

    def _template(self):
        return {"w": jnp.zeros((17, 9)), "b": jnp.zeros((9,))}

    def test_byte_ordering_and_flops(self):
        from repro.roofline import quantized_uplink_roofline
        r = quantized_uplink_roofline(self._template(), k=8, bits=4)
        assert (r["wire_bytes"] <= r["payload_bytes"]["fused"]
                <= r["payload_bytes"]["reference"] <= r["raw_bytes"])
        assert r["payload_bytes"]["fused"] == r["wire_bytes"]
        assert r["payload_bytes"]["reference"] > r["wire_bytes"]
        for impl in ("fused", "reference"):
            assert r["flops"][impl]["uplink"] > 0
            assert r["flops"][impl]["downlink"] > 0

    def test_payloads_equal_at_byte_aligned_bits(self):
        from repro.roofline import quantized_uplink_roofline
        for bits in (8, 16):
            r = quantized_uplink_roofline(self._template(), k=4, bits=bits)
            assert (r["payload_bytes"]["fused"]
                    == r["payload_bytes"]["reference"] == r["wire_bytes"])

    def test_sharded_round_programs_lower(self):
        from repro.core.encoders import init_encoder
        from repro.roofline import sharded_round_programs
        from repro.sharding.partition import client_mesh
        mesh = client_mesh(1)
        template = jax.eval_shape(
            lambda: init_encoder(jax.random.key(0), (4, 3), 5))
        progs = sharded_round_programs(
            mesh, k=4, steps=2, batch=4, feat=(4, 3),
            template=template, lr=0.1, bits=4)
        assert set(progs) == {"epoch", "epoch_fused", "aggregate_full",
                              "aggregate_q_reference", "aggregate_q_fused"}
        for name, (prog, args) in progs.items():
            with mesh:
                prog.lower(*args)  # must trace at the abstract shapes

"""ServeEngine behaviour: bucketing, completion, eos handling, and greedy
equivalence with raw decode_step."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import decode_step, init_cache, init_params
from repro.serving import ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("xlstm-125m").smoke()
    params = init_params(jax.random.key(0), cfg)
    return cfg, params


class TestServeEngine:
    def test_all_requests_complete(self, small_model):
        cfg, params = small_model
        eng = ServeEngine(params, cfg, max_batch=2, cache_len=64, bucket=8)
        for plen in (3, 5, 9, 12):
            eng.submit(list(range(1, plen + 1)), max_new_tokens=4)
        done = eng.run()
        assert all(r.done for r in done)
        assert all(len(r.output) == 4 for r in done)
        assert len(eng.stats) >= 2          # two buckets at least

    def test_eos_stops_early(self, small_model):
        cfg, params = small_model
        eng = ServeEngine(params, cfg, max_batch=1, cache_len=64, bucket=8)
        # find the greedy first token, then use it as eos
        probe = ServeEngine(params, cfg, max_batch=1, cache_len=64, bucket=8)
        r0 = probe.submit([1, 2, 3], max_new_tokens=2)
        probe.run()
        second = r0.output[1]
        r = eng.submit([1, 2, 3], max_new_tokens=8, eos_id=second)
        eng.run()
        assert r.output[-1] == second
        assert len(r.output) <= 8

    def test_matches_raw_decode(self, small_model):
        """Single request: engine output == manual greedy decode."""
        cfg, params = small_model
        prompt = [5, 7, 11]
        eng = ServeEngine(params, cfg, max_batch=1, cache_len=64, bucket=8)
        r = eng.submit(prompt, max_new_tokens=4)
        eng.run()

        cache = init_cache(cfg, 1, 64)
        # engine pads the prompt to the bucket (8) with zeros and keeps
        # stepping; replicate exactly
        padded = prompt + [0] * (8 - len(prompt))
        logits = None
        saved = None
        for t, tok in enumerate(padded):
            logits, cache = decode_step(
                params, cfg, cache,
                {"tokens": jnp.asarray([[tok]], jnp.int32)})
            if t + 1 == len(prompt):
                saved = logits
        out = [int(jnp.argmax(saved[0]))]
        nxt = out[0]
        for _ in range(3):
            logits, cache = decode_step(
                params, cfg, cache,
                {"tokens": jnp.asarray([[nxt]], jnp.int32)})
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
        assert r.output == out

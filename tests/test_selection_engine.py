"""The device-resident selection engine must reproduce the numpy reference
(`modality_priority` + `select_top_gamma` + `select_clients`) bit-identically
on selection *outcomes* — every strategy, every tie case — and a full
`run_federation` under the engine must match the pre-refactor loop backend
exactly on uploads/ledger and to 1e-5 on encoders."""
import numpy as np
import pytest

from repro.core import selection_engine as se
from repro.core.federation_state import ClientStore, FederationState
from repro.core.rounds import MFedMCConfig, build_federation, run_federation
from repro.core.selection import (joint_select, modality_priority,
                                  select_clients, select_top_gamma)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAS_HYPOTHESIS,
                                      reason="hypothesis not installed")

ALPHAS = dict(alpha_s=1 / 3, alpha_c=1 / 3, alpha_r=1 / 3)


def _reference_choices(names, phi, sizes, rec, t, gamma):
    prio = modality_priority(phi, sizes, rec, t, **ALPHAS)
    return select_top_gamma(prio, list(names), gamma)


class TestLexicographicRank:
    def test_rank_orders_names(self):
        names = ["gyro", "acc", "mic"]
        rank = se.lexicographic_rank(names)
        np.testing.assert_array_equal(rank, [1, 0, 2])

    def test_rank_preserves_comparisons(self):
        names = ["b10", "b2", "a", "zz"]
        rank = se.lexicographic_rank(names)
        for i in range(len(names)):
            for j in range(len(names)):
                assert (names[i] < names[j]) == (rank[i] < rank[j])


class TestModalityParity:
    """Engine vs per-client numpy on random populations — exact outcomes,
    including the ordered top-γ lists (priority desc, then name)."""

    def _check(self, phi, sizes, recm, presence, names, t, gamma):
        dec = se.select_modalities_arrays(
            phi, sizes, recm, presence, se.lexicographic_rank(names),
            t=t, gamma=gamma, **ALPHAS)
        for k in range(phi.shape[0]):
            own = [j for j in range(len(names)) if presence[k, j]]
            if not own:
                assert dec.counts[k] == 0 and not dec.mask[k].any()
                continue
            ref = _reference_choices([names[j] for j in own], phi[k, own],
                                     sizes[k, own], recm[k, own], t, gamma)
            assert dec.choices(k, names) == ref

    def test_seeded_random_populations(self):
        rng = np.random.default_rng(7)
        for trial in range(60):
            K = int(rng.integers(1, 10))
            M = int(rng.integers(1, 6))
            names = list(rng.permutation([f"m{i}" for i in range(M)]))
            presence = rng.random((K, M)) < 0.8
            phi = rng.standard_normal((K, M))
            sizes = rng.random((K, M)) * 1e6
            if trial % 5 == 0:
                phi[:] = 0.25                    # constant vector -> Eq. 12
            if trial % 7 == 0:
                sizes[:] = 321.0                 # normalizes to all-zeros
            t = int(rng.integers(1, 9))
            recm = (t - rng.integers(-1, 6, (K, M)) - 1).astype(float)
            self._check(phi, sizes, recm, presence, names, t,
                        int(rng.integers(1, M + 2)))

    def test_gamma_exceeds_m(self):
        names = ["a", "b"]
        dec = se.select_modalities_arrays(
            np.array([[0.1, 0.9]]), np.ones((1, 2)), np.zeros((1, 2)),
            np.ones((1, 2), bool), se.lexicographic_rank(names),
            t=3, gamma=7, **ALPHAS)
        assert dec.choices(0, names) == ["b", "a"]   # all, priority order

    def test_all_equal_priorities_tie_break_by_name(self):
        # the satellite regression: an index-ordered top_k would pick input
        # order; the reference (and engine) break ties lexicographically
        names = ["gyro", "acc", "tactile", "mic"]
        K, M = 3, 4
        dec = se.select_modalities_arrays(
            np.ones((K, M)), np.ones((K, M)), np.zeros((K, M)),
            np.ones((K, M), bool), se.lexicographic_rank(names),
            t=1, gamma=2, **ALPHAS)
        for k in range(K):
            assert dec.choices(k, names) == ["acc", "gyro"]
            assert dec.choices(k, names) == _reference_choices(
                names, np.ones(M), np.ones(M), np.zeros(M), 1, 2)

    def test_partial_tie_prefers_name_order(self):
        # two modalities tie on priority, third wins outright
        names = ["c", "a", "b"]
        phi = np.array([[0.5, 0.2, 0.2]])
        dec = se.select_modalities_arrays(
            phi, np.ones((1, 3)), np.zeros((1, 3)), np.ones((1, 3), bool),
            se.lexicographic_rank(names), t=1, gamma=2, alpha_s=1.0,
            alpha_c=0.0, alpha_r=0.0)
        assert dec.choices(0, names) == ["c", "a"]


class TestClientParity:
    def _ref(self, losses_d, delta, crit, rec_d, lw):
        return select_clients(losses_d, delta, criterion=crit,
                              recency=rec_d, loss_weight=lw)

    def test_seeded_random_criteria(self):
        rng = np.random.default_rng(11)
        for trial in range(60):
            K = int(rng.integers(1, 14))
            M = int(rng.integers(1, 4))
            mask = rng.random((K, M)) < 0.7
            losses = rng.random((K, M)) * 4
            if trial % 4 == 0:
                losses[:] = 1.0                  # full tie -> stable id order
            delta = float(rng.uniform(0.05, 1.0))
            lw = float(rng.random())
            rec_vec = rng.integers(0, 10, K).astype(float)
            cand = [k for k in range(K) if mask[k].any()]
            if not cand:
                continue
            rep = {k: float(min(losses[k, j] for j in range(M)
                                if mask[k, j])) for k in cand}
            rec_d = {k: int(rec_vec[k]) for k in cand}
            for crit in ("low_loss", "high_loss", "loss_recency"):
                ref = self._ref(rep, delta, crit, rec_d, lw)
                got = se.select_clients_arrays(
                    losses, mask, delta=delta, criterion=crit,
                    client_recency=rec_vec, loss_weight=lw)
                assert [k for k in range(K) if got[k]] == ref, \
                    (trial, crit, delta)

    def test_loss_recency_blend_extremes(self):
        # lw=0 -> pure staleness; lw=1 -> pure loss (the §4.8 endpoints)
        losses = np.array([[0.5], [0.1], [0.9], [0.3]])
        mask = np.ones((4, 1), bool)
        rec = np.array([9.0, 0.0, 5.0, 1.0])
        stale = se.select_clients_arrays(losses, mask, delta=0.5,
                                         criterion="loss_recency",
                                         client_recency=rec, loss_weight=0.0)
        assert list(np.nonzero(stale)[0]) == [0, 2]     # stalest two
        lossy = se.select_clients_arrays(losses, mask, delta=0.5,
                                         criterion="loss_recency",
                                         client_recency=rec, loss_weight=1.0)
        assert list(np.nonzero(lossy)[0]) == [1, 3]     # lowest-loss two

    def test_random_criterion_rejected(self):
        with pytest.raises(ValueError):
            se.select_clients_arrays(np.ones((2, 1)), np.ones((2, 1), bool),
                                     delta=0.5, criterion="random")

    def test_empty_candidates(self):
        got = se.select_clients_arrays(np.ones((3, 2)),
                                       np.zeros((3, 2), bool), delta=0.5)
        assert not got.any()


if HAS_HYPOTHESIS:
    class TestHypothesisParity:
        @given(st.integers(1, 8), st.integers(1, 5),
               st.integers(0, 10 ** 6), st.integers(1, 6))
        @settings(max_examples=40, deadline=None)
        def test_modality_outcomes(self, k, m, seed, gamma):
            rng = np.random.default_rng(seed)
            names = [f"m{i}" for i in range(m)]
            presence = rng.random((k, m)) < 0.85
            phi = rng.standard_normal((k, m))
            sizes = rng.random((k, m)) * 10 ** rng.integers(0, 7)
            t = int(rng.integers(1, 12))
            recm = (t - rng.integers(-1, 8, (k, m)) - 1).astype(float)
            dec = se.select_modalities_arrays(
                phi, sizes, recm, presence, se.lexicographic_rank(names),
                t=t, gamma=gamma, **ALPHAS)
            for row in range(k):
                own = [j for j in range(m) if presence[row, j]]
                if not own:
                    continue
                assert dec.choices(row, names) == _reference_choices(
                    [names[j] for j in own], phi[row, own], sizes[row, own],
                    recm[row, own], t, gamma)

        @given(st.integers(1, 10), st.floats(0.01, 1.0),
               st.floats(0.0, 1.0), st.integers(0, 10 ** 6))
        @settings(max_examples=40, deadline=None)
        def test_client_outcomes(self, k, delta, lw, seed):
            rng = np.random.default_rng(seed)
            losses = rng.random((k, 1)) * 5
            mask = np.ones((k, 1), bool)
            rec = rng.integers(0, 10, k).astype(float)
            rep = {i: float(losses[i, 0]) for i in range(k)}
            rec_d = {i: int(rec[i]) for i in range(k)}
            for crit in ("low_loss", "high_loss", "loss_recency"):
                ref = select_clients(rep, delta, criterion=crit,
                                     recency=rec_d, loss_weight=lw)
                got = se.select_clients_arrays(losses, mask, delta=delta,
                                               criterion=crit,
                                               client_recency=rec,
                                               loss_weight=lw)
                assert [i for i in range(k) if got[i]] == ref


class TestJointSelectArrays:
    """The composing wrapper (Eq. 20) must match ``selection.joint_select``
    end-to-end: same choices, same selected clients, same upload mask."""

    def test_matches_reference_joint_select(self):
        rng = np.random.default_rng(3)
        for crit in ("low_loss", "high_loss", "loss_recency"):
            K, M = 7, 3
            names = ["gyro", "acc", "mic"]
            phi = rng.standard_normal((K, M))
            sizes = rng.random((K, M)) * 1e5
            recm = rng.integers(0, 5, (K, M)).astype(float)
            losses = rng.random((K, M)) * 2
            crec = rng.integers(0, 8, K).astype(float)
            t, gamma, delta, lw = 4, 2, 0.4, 0.3
            dec = se.joint_select_arrays(
                phi, sizes, recm, losses, np.ones((K, M), bool),
                se.lexicographic_rank(names), t=t, gamma=gamma, delta=delta,
                client_criterion=crit, client_recency=crec, loss_weight=lw,
                **ALPHAS)
            # reference composition over the same per-client vectors
            prios = {k: (names, modality_priority(phi[k], sizes[k], recm[k],
                                                  t, **ALPHAS))
                     for k in range(K)}
            ref_choices = {k: select_top_gamma(prios[k][1], names, gamma)
                           for k in range(K)}
            rep = {k: min(losses[k, names.index(m)] for m in ref_choices[k])
                   for k in range(K)}
            ref_sel = select_clients(rep, delta, criterion=crit,
                                     recency={k: int(crec[k])
                                              for k in range(K)},
                                     loss_weight=lw)
            for k in range(K):
                assert dec.modality.choices(k, names) == ref_choices[k]
            assert [k for k in range(K) if dec.client_mask[k]] == ref_sel
            # Eq. 20: upload_mask = chosen modalities of selected clients
            up = dec.upload_mask
            for k in range(K):
                expect = ({names.index(m) for m in ref_choices[k]}
                          if k in ref_sel else set())
                assert {j for j in range(M) if up[k, j]} == expect


class TestRngRequired:
    """Random draws must use the caller's generator — a silent shared
    default makes two 'random' runs identical."""

    def test_select_clients_random_requires_rng(self):
        with pytest.raises(ValueError, match="rng"):
            select_clients({0: 0.1, 1: 0.2}, 0.5, criterion="random")

    def test_joint_select_modality_random_requires_rng(self):
        prios = {0: (["a", "b"], np.array([0.1, 0.9]))}
        with pytest.raises(ValueError, match="rng"):
            joint_select(prios, {0: 0.5}, gamma=1, delta=1.0,
                         modality_random=True)

    def test_joint_select_deterministic_needs_no_rng(self):
        prios = {0: (["a", "b"], np.array([0.1, 0.9]))}
        res = joint_select(prios, {0: 0.5}, gamma=1, delta=1.0)
        assert res.modality_choices == {0: ["b"]}


class TestFederationState:
    def _clients(self, n=24):
        cfg = MFedMCConfig(rounds=1, local_epochs=1, seed=0)
        return build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                samples_per_client=n)

    def test_recency_matrix_eq11(self):
        clients, spec = self._clients()
        state = FederationState.build(clients, spec, 32, stack=False)
        np.testing.assert_array_equal(state.recency_matrix(3),
                                      np.full_like(state.sizes, 3))
        mask = np.zeros_like(state.presence)
        mask[0, 0] = True
        state.mark_uploaded(mask, 3)
        rec = state.recency_matrix(5)
        assert rec[0, 0] == 1 and rec[0, 1] == 5      # t − t_m^k − 1

    def test_client_staleness_matches_tracker_expression(self):
        clients, spec = self._clients()
        state = FederationState.build(clients, spec, 32, stack=False)
        mask = np.zeros_like(state.presence)
        mask[1] = state.presence[1]
        state.mark_uploaded(mask, 2)
        clients[1].recency.mark_uploaded(list(clients[1].modality_names), 2)
        t = 4
        for k, c in enumerate(clients[:3]):
            ref = t - 1 - max(c.recency.last_upload.values(), default=-1)
            assert state.client_staleness(t)[k] == ref

    def test_sizes_match_encoder_bytes(self):
        from repro.core.encoders import encoder_bytes
        clients, spec = self._clients()
        state = FederationState.build(clients, spec, 8, stack=False)
        c = clients[0]
        for m in c.modality_names:
            assert state.sizes[0, state.mod_index[m]] == \
                encoder_bytes(c.encoders[m], 8)

    def test_statestore_roundtrip(self):
        # gather == ClientStore's stack; write_back restores bit-exactly
        clients, spec = self._clients()
        state = FederationState.build(clients, spec, 32)
        ref_store = ClientStore()
        pairs = [(clients[0], clients[0].modality_names[0]),
                 (clients[1], clients[1].modality_names[0])]
        a = state.store.gather_encoders(pairs)
        b = ref_store.gather_encoders(pairs)
        for key in a:
            np.testing.assert_array_equal(np.asarray(a[key]),
                                          np.asarray(b[key]))
        before = {m: {k: np.asarray(v) for k, v in
                      clients[0].encoders[m].items()}
                  for m in clients[0].modality_names}
        state.write_back()
        for m in before:
            for k in before[m]:
                np.testing.assert_array_equal(
                    np.asarray(clients[0].encoders[m][k]), before[m][k])


TOL = 1e-5


def _run(backend, impl, dataset="ucihar", scenario="iid", n=24, **cfg_kw):
    base = dict(rounds=2, local_epochs=1, batch_size=10, seed=0,
                background_size=12, eval_size=12, selection_impl=impl)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = build_federation(dataset, scenario, cfg=cfg, seed=0,
                                     samples_per_client=n)
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _assert_exact_decisions(h_ref, h):
    for r_ref, r in zip(h_ref.records, h.records):
        assert r.uploads == r_ref.uploads
        assert r.comm_mb == r_ref.comm_mb


def _assert_encoders_close(se_ref, se_new):
    assert set(se_ref) == set(se_new)
    for m in se_ref:
        for k in se_ref[m]:
            np.testing.assert_allclose(np.asarray(se_new[m][k]),
                                       np.asarray(se_ref[m][k]),
                                       atol=TOL, rtol=0, err_msg=f"{m}/{k}")


class TestFullRunParity:
    """run_federation under the engine == the pre-refactor loop backend:
    selection/ledger exact, encoders within 1e-5."""

    def test_engine_backend_matches_host_loop(self):
        se_l, h_l, _ = _run("loop", "host")
        se_e, h_e, _ = _run("engine", "engine")
        _assert_exact_decisions(h_l, h_e)
        _assert_encoders_close(se_l, se_e)

    def test_engine_selection_on_loop_backend_is_exact(self):
        # same backend, only the decision layer swaps: records identical
        se_h, h_h, _ = _run("loop", "host")
        se_e, h_e, _ = _run("loop", "engine")
        _assert_exact_decisions(h_h, h_e)
        for m in se_h:
            for k in se_h[m]:
                np.testing.assert_array_equal(np.asarray(se_e[m][k]),
                                              np.asarray(se_h[m][k]))

    def test_engine_backend_ragged_paper_strategy(self):
        kw = dict(dataset="actionsense", scenario="natural", n=20,
                  modality_strategy="priority", client_strategy="low_loss",
                  batch_size=8)
        se_l, h_l, _ = _run("loop", "host", **kw)
        se_e, h_e, _ = _run("engine", "engine", **kw)
        _assert_exact_decisions(h_l, h_e)
        _assert_encoders_close(se_l, se_e)

    def test_engine_backend_loss_recency(self):
        kw = dict(client_strategy="loss_recency", loss_weight=0.4)
        se_l, h_l, _ = _run("loop", "host", **kw)
        se_e, h_e, _ = _run("engine", "engine", **kw)
        _assert_exact_decisions(h_l, h_e)
        _assert_encoders_close(se_l, se_e)

    def test_engine_backend_writes_clients_back(self):
        # after a resident run the Client objects match the batched
        # backend's bit-exactly (same training programs, same layout)
        _, _, cl_b = _run("batched", "engine")
        _, _, cl_e = _run("engine", "engine")
        for c_b, c_e in zip(cl_b, cl_e):
            assert c_b.recency.last_upload == c_e.recency.last_upload
            for m in c_b.modality_names:
                for k in c_b.encoders[m]:
                    np.testing.assert_array_equal(
                        np.asarray(c_e.encoders[m][k]),
                        np.asarray(c_b.encoders[m][k]))

    def test_unknown_selection_impl_rejected(self):
        with pytest.raises(ValueError):
            _run("loop", "numpy")


def test_selection_masks_from_matrix():
    from repro.core.distributed import selection_masks_from_matrix
    up = np.array([[1, 0], [0, 1], [0, 0]], bool)
    masks = selection_masks_from_matrix(up, ["acc", "gyro"])
    np.testing.assert_array_equal(np.asarray(masks["acc"]), [1.0, 0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(masks["gyro"]), [0.0, 1.0, 0.0])

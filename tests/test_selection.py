"""Unit tests for the joint-selection math (Eqs. 9–20) against hand-computed
values, plus hypothesis properties for the invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.selection import (RecencyTracker, joint_select,
                                  minmax_normalize, modality_priority,
                                  select_clients, select_top_gamma)


class TestMinMax:
    def test_hand(self):
        out = minmax_normalize(np.array([1.0, 3.0, 2.0]))
        np.testing.assert_allclose(out, [0.0, 1.0, 0.5])

    def test_constant_vector(self):
        np.testing.assert_allclose(minmax_normalize(np.array([2.0, 2.0])),
                                   [0.0, 0.0])

    @given(st.lists(st.floats(-1e6, 1e6), min_size=2, max_size=16))
    def test_range(self, xs):
        out = minmax_normalize(np.array(xs))
        assert np.all(out >= 0.0) and np.all(out <= 1.0)


class TestPriority:
    def test_hand_computed_eq13(self):
        # 3 modalities: shapley (.3, .1, .2), sizes (100, 300, 200), rec (0,2,1), t=3
        phi = np.array([0.3, 0.1, 0.2])
        sizes = np.array([100.0, 300.0, 200.0])
        rec = np.array([0.0, 2.0, 1.0])
        p = modality_priority(phi, sizes, rec, 3, 1 / 3, 1 / 3, 1 / 3)
        # normalized: phi (1, 0, .5); size (0, 1, .5) -> 1-size (1, 0, .5);
        # rec/t (0, 2/3, 1/3)
        expect = (np.array([1, 0, .5]) + np.array([1, 0, .5])
                  + np.array([0, 2 / 3, 1 / 3])) / 3
        np.testing.assert_allclose(p, expect, rtol=1e-12)

    def test_alpha_s_only_ranks_by_shapley(self):
        phi = np.array([0.1, 0.9, 0.5])
        p = modality_priority(phi, np.array([1., 2., 3.]),
                              np.array([5., 0., 1.]), 6, 1.0, 0.0, 0.0)
        assert np.argmax(p) == 1

    def test_alpha_c_only_prefers_small(self):
        p = modality_priority(np.array([0.9, 0.1]), np.array([100.0, 10.0]),
                              np.zeros(2), 1, 0.0, 1.0, 0.0)
        assert np.argmax(p) == 1

    def test_negative_shapley_uses_magnitude(self):
        p = modality_priority(np.array([-0.9, 0.1]), np.ones(2),
                              np.zeros(2), 1, 1.0, 0.0, 0.0)
        assert np.argmax(p) == 0

    @given(st.integers(1, 6), st.integers(2, 6))
    @settings(max_examples=30, deadline=None)
    def test_top_gamma_cardinality(self, gamma, m):
        names = [f"m{i}" for i in range(m)]
        prio = np.random.default_rng(0).random(m)
        sel = select_top_gamma(prio, names, gamma)
        assert len(sel) == min(gamma, m)
        assert len(set(sel)) == len(sel)
        # selected are exactly the top-γ by priority
        thresh = sorted(prio, reverse=True)[len(sel) - 1]
        for s in sel:
            assert prio[names.index(s)] >= thresh - 1e-12


class TestRecency:
    def test_eq11(self):
        r = RecencyTracker(("a", "b"))
        # never uploaded: T = t - (-1) - 1 = t
        assert r.recency("a", 5) == 5
        r.mark_uploaded(["a"], 5)
        assert r.recency("a", 6) == 0       # just uploaded
        assert r.recency("b", 6) == 6
        assert r.recency("a", 9) == 3

    def test_mark_resets_only_named(self):
        r = RecencyTracker(("a", "b", "c"))
        r.mark_uploaded(["b"], 3)
        assert r.last_upload == {"a": -1, "b": 3, "c": -1}


class TestClientSelection:
    LOSSES = {0: 0.5, 1: 0.1, 2: 0.9, 3: 0.3, 4: 0.7}

    def test_low_loss_eq18(self):
        assert select_clients(self.LOSSES, 0.4) == [1, 3]

    def test_high_loss(self):
        assert select_clients(self.LOSSES, 0.4,
                              criterion="high_loss") == [2, 4]

    def test_ceil_delta_k(self):
        # ⌈0.5 * 5⌉ = 3
        assert len(select_clients(self.LOSSES, 0.5)) == 3
        # ⌈0.01 * 5⌉ = 1
        assert len(select_clients(self.LOSSES, 0.01)) == 1

    def test_random_is_seeded_and_sized(self):
        rng = np.random.default_rng(7)
        out = select_clients(self.LOSSES, 0.4, criterion="random", rng=rng)
        assert len(out) == 2 and set(out) <= set(self.LOSSES)

    def test_loss_recency_pure_recency(self):
        rec = {0: 9, 1: 0, 2: 5, 3: 1, 4: 7}
        out = select_clients(self.LOSSES, 0.4, criterion="loss_recency",
                             recency=rec, loss_weight=0.0)
        assert out == [0, 4]        # stalest two

    @given(st.floats(0.01, 1.0), st.integers(2, 20))
    @settings(max_examples=30, deadline=None)
    def test_cardinality_property(self, delta, k):
        losses = {i: float(i) for i in range(k)}
        out = select_clients(losses, delta)
        assert len(out) == max(1, int(np.ceil(delta * k)))


class TestJointSelect:
    def test_eq20_composition(self):
        prios = {
            0: (["a", "b"], np.array([0.9, 0.1])),
            1: (["a", "b"], np.array([0.2, 0.8])),
            2: (["a"], np.array([0.5])),
        }
        losses = {0: 0.1, 1: 0.9, 2: 0.5}
        res = joint_select(prios, losses, gamma=1, delta=0.34)
        assert res.modality_choices == {0: ["a"], 1: ["b"], 2: ["a"]}
        # ⌈0.34 · 3⌉ = 2 lowest-loss clients
        assert res.selected_clients == [0, 2]
        assert res.uploads == [(0, "a"), (2, "a")]
        res1 = joint_select(prios, losses, gamma=1, delta=0.1)
        assert res1.selected_clients == [0]

    def test_comm_reduction_factor(self):
        # γ/M̄ · δ (paper's Eq. after 20): 100 clients × 3 modalities,
        # γ=1, δ=0.2 -> 20 uploads instead of 300
        prios = {k: ([f"m{i}" for i in range(3)],
                     np.random.default_rng(k).random(3))
                 for k in range(100)}
        losses = {k: float(k) for k in range(100)}
        res = joint_select(prios, losses, gamma=1, delta=0.2)
        assert len(res.uploads) == 20

"""§4.10 communication subsystem: exact wire accounting, device-resident
quantization, error feedback, and full quantized-round loop-vs-batched
parity."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.aggregation import (aggregate_quantized, aggregate_stacked,
                                    stack_uploads)
from repro.core.encoders import encoder_bytes, init_encoder
from repro.core.quantize import (TENSOR_METADATA_BYTES, code_dtype,
                                 dequantize_encoder, dequantize_pytree,
                                 fake_quantize_pytree, pack_codes,
                                 pytree_wire_bytes, quantize_encoder,
                                 quantize_population,
                                 quantize_pytree, quantize_tensor,
                                 quantize_with_error_feedback,
                                 quantized_roundtrip, tensor_wire_bytes,
                                 unpack_codes, zero_residual)
from repro.core.rounds import MFedMCConfig, build_federation, run_federation

TOL = 1e-5


def _enc(seed=0, feat=(8, 4), classes=5):
    return init_encoder(jax.random.key(seed), feat, classes)


# ---------------------------------------------------------------------------
# exact ledger accounting
# ---------------------------------------------------------------------------

class TestExactWireBytes:
    def test_known_encoder_regression(self):
        """Pin exact ledger bytes for the (8, 4)-feature LSTM encoder:
        bit-packed codes in the smallest sufficient dtype plus an 8-byte
        scale/zero pair per tensor."""
        e = _enc()
        ns = [int(np.prod(v.shape)) for v in e.values()]
        assert sum(ns) == 68741                     # the known encoder
        expect = {
            32: sum(4 * n for n in ns),                            # 274964
            16: sum(2 * n + 8 for n in ns),                        # 137522
            8: sum(n + 8 for n in ns),                             #  68781
            4: sum(-((n * 4) // -8) + 8 for n in ns),              #  34411
        }
        assert expect[32] == 274964 and expect[16] == 137522
        assert expect[8] == 68781 and expect[4] == 34411
        for bits, want in expect.items():
            assert encoder_bytes(e, bits) == want

    def test_16bit_codes_ship_as_2_bytes(self):
        """The seed bug: 16-bit codes were stored int32 (4 bytes shipped)
        while the ledger counted 2. Codes now ship uint16 and the count is
        the container's true width."""
        x = jnp.asarray(np.random.default_rng(0).standard_normal((16,)),
                        jnp.float32)
        codes, _, _ = quantize_tensor(x, 16)
        assert codes.dtype == jnp.uint16
        assert tensor_wire_bytes(x.shape, 16) == \
            codes.nbytes + TENSOR_METADATA_BYTES

    @pytest.mark.parametrize("bits", [1, 2, 4, 8])
    def test_packed_buffer_matches_accounting(self, bits):
        x = jnp.asarray(np.random.default_rng(1).standard_normal((7, 5)),
                        jnp.float32)
        codes, _, _ = quantize_tensor(x, bits)
        packed = pack_codes(codes, bits)
        assert packed.nbytes + TENSOR_METADATA_BYTES == \
            tensor_wire_bytes(x.shape, bits)
        back = unpack_codes(packed, bits, x.size, x.shape)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    def test_metadata_counted_per_tensor(self):
        e = _enc()
        n = sum(int(np.prod(v.shape)) for v in e.values())
        assert encoder_bytes(e, 8) == n + len(e) * TENSOR_METADATA_BYTES

    def test_full_precision_uses_param_dtype(self):
        bf = {"w": jnp.zeros((10, 3), jnp.bfloat16)}
        assert pytree_wire_bytes(bf, 32) == 60      # 2 bytes/param, no meta

    @pytest.mark.parametrize("bad", [0, -8, 17, 24, 31])
    def test_accounting_rejects_invalid_bits(self, bad):
        with pytest.raises(ValueError):
            tensor_wire_bytes((100,), bad)


# ---------------------------------------------------------------------------
# quantizer semantics
# ---------------------------------------------------------------------------

class TestQuantizerSemantics:
    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_roundtrip_error_at_most_half_step(self, bits):
        """Property: per-element |deq − x| ≤ scale/2 for every leaf."""
        e = _enc(seed=3)
        codes, scales, zeros = quantize_pytree(e, bits)
        back = dequantize_pytree(codes, scales, zeros)
        for k in e:
            err = float(jnp.max(jnp.abs(back[k] - e[k])))
            assert err <= float(scales[k]) / 2 + 1e-6, (k, bits)

    def test_population_quantize_is_per_client(self):
        """vmapped quantization must compute per-client ranges, not one
        range across the stacked population."""
        a = jnp.full((4, 4), 1.0)
        b = jnp.full((4, 4), 100.0)
        stacked = {"w": jnp.stack([a, b])}
        _, scales, zeros = quantize_population(stacked, bits=8)
        assert scales["w"].shape == (2,)
        assert float(zeros["w"][0]) == pytest.approx(1.0)
        assert float(zeros["w"][1]) == pytest.approx(100.0)

    def test_dequantize_restores_dtype(self):
        e16 = jax.tree.map(lambda v: v.astype(jnp.bfloat16), _enc())
        back = dequantize_encoder(quantize_encoder(e16, 8))
        for k, v in back.items():
            assert v.dtype == jnp.bfloat16, k
        rt = fake_quantize_pytree(e16, 8)
        for k in e16:
            assert rt[k].dtype == jnp.bfloat16

    def test_bits32_guard_and_passthrough(self):
        e = _enc()
        assert quantized_roundtrip(e, 32) is e      # passthrough
        for bad in (0, 17, 31, 32, 64):
            with pytest.raises(ValueError):
                quantize_encoder(e, bad)
            with pytest.raises(ValueError):
                code_dtype(bad)

    def test_docstring_semantics_are_asymmetric_minmax(self):
        """zero-point = min(x): an all-positive tensor quantizes with lo>0
        (a symmetric scheme would force the range through 0)."""
        x = jnp.asarray([2.0, 2.5, 3.0])
        codes, scale, zero = quantize_tensor(x, 4)
        assert float(zero) == pytest.approx(2.0)
        assert int(codes[0]) == 0 and int(codes[-1]) == 15


# ---------------------------------------------------------------------------
# stacked + quantized aggregation
# ---------------------------------------------------------------------------

class TestStackedAggregation:
    def test_quantized_aggregation_matches_manual(self):
        encs = [_enc(seed=i) for i in range(3)]
        w = jnp.asarray([30.0, 10.0, 20.0])
        stacked = stack_uploads(encs)
        codes, scales, zeros = quantize_population(stacked, bits=8)
        agg = aggregate_quantized(codes, scales, zeros, w)
        # manual: dequantize each upload, then Eq. 21
        wn = np.asarray(w) / np.asarray(w).sum()
        for k in encs[0]:
            deq = [np.asarray(codes[k][j], np.float32) * float(scales[k][j])
                   + float(zeros[k][j]) for j in range(3)]
            manual = sum(wi * d for wi, d in zip(wn, deq))
            np.testing.assert_allclose(np.asarray(agg[k]), manual, atol=1e-5)

    @pytest.mark.parametrize("bits", [2, 4, 8, 16])
    def test_reduce_from_codes_matches_old_dequantize_stack(self, bits):
        """Regression oracle for the aggregate_quantized rewrite: the
        einsum-over-codes reduction (affine applied to the reduced sums)
        must match the historical implementation that materialized the
        full [K, ...] dequantized stack via vmap(dequantize_tensor)."""
        from repro.core.quantize import dequantize_tensor
        encs = [_enc(seed=i) for i in range(5)]
        w = jnp.asarray([12.0, 0.0, 7.0, 31.0, 3.0])
        stacked = stack_uploads(encs)
        codes, scales, zeros = quantize_population(stacked, bits=bits)
        agg = aggregate_quantized(codes, scales, zeros, w)

        @jax.jit
        def old_impl(codes, scales, zeros, weights):
            wn = weights / jnp.maximum(jnp.sum(weights), 1e-12)
            def leaf(c, s, z):
                deq = jax.vmap(dequantize_tensor)(c, s, z)
                return jnp.einsum("k,k...->...", wn, deq)
            return jax.tree.map(leaf, codes, scales, zeros)

        want = old_impl(codes, scales, zeros, w)
        for k in encs[0]:
            np.testing.assert_allclose(np.asarray(agg[k]),
                                       np.asarray(want[k]),
                                       atol=1e-5, rtol=0, err_msg=k)

    def test_reduce_from_codes_zero_weights_safe(self):
        stacked = stack_uploads([_enc(seed=9)])
        codes, scales, zeros = quantize_population(stacked, bits=4)
        agg = aggregate_quantized(codes, scales, zeros, jnp.zeros((1,)))
        for k in agg:
            np.testing.assert_array_equal(np.asarray(agg[k]),
                                          np.zeros_like(np.asarray(agg[k])))

    def test_stacked_matches_convex_combination(self):
        e1, e2 = _enc(seed=0), _enc(seed=1)
        agg = aggregate_stacked(stack_uploads([e1, e2]),
                                jnp.asarray([3.0, 1.0]))
        for k in agg:
            np.testing.assert_allclose(
                np.asarray(agg[k]),
                0.75 * np.asarray(e1[k]) + 0.25 * np.asarray(e2[k]),
                atol=1e-6)
            assert agg[k].dtype == e1[k].dtype


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

class TestErrorFeedback:
    def test_residual_cancels_bias_over_rounds(self):
        """The running mean of EF uploads converges to the true params —
        plain low-bit quantization keeps a constant rounding bias."""
        p = {"w": jnp.asarray(
            np.random.default_rng(0).standard_normal((32, 8)), jnp.float32)}
        r = zero_residual(p)
        sends = []
        for _ in range(40):
            codes, scales, zeros, r = quantize_with_error_feedback(
                p, r, bits=2)
            sends.append(np.asarray(
                dequantize_pytree(codes, scales, zeros)["w"]))
        ef_err = np.abs(np.mean(sends, axis=0) - np.asarray(p["w"])).max()
        codes, scales, zeros = quantize_pytree(p, 2)
        plain = np.abs(np.asarray(
            dequantize_pytree(codes, scales, zeros)["w"])
            - np.asarray(p["w"])).max()
        assert ef_err < plain / 3

    def test_federation_populates_residuals(self):
        cfg = MFedMCConfig(rounds=1, local_epochs=1, seed=0, gamma=1,
                           modality_strategy="random", quantize_bits=4,
                           error_feedback=True)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=16)
        h = run_federation(clients, spec, cfg)
        uploaded = {(cid, m) for r in h.records for cid, m in r.uploads}
        assert uploaded
        by_id = {c.client_id: c for c in clients}
        for cid, m in uploaded:
            res = by_id[cid].residuals[m]
            for k, v in res.items():
                arr = np.asarray(v)
                assert np.isfinite(arr).all()
                assert arr.shape == np.asarray(
                    by_id[cid].encoders[m][k]).shape
        # non-uploading clients hold no residual state
        for c in clients:
            for m in c.residuals:
                assert (c.client_id, m) in uploaded


# ---------------------------------------------------------------------------
# full quantized round: loop vs batched parity
# ---------------------------------------------------------------------------

def _run(backend, bits, **cfg_kw):
    base = dict(rounds=1, local_epochs=2, batch_size=10, seed=0,
                modality_strategy="random", gamma=1, quantize_bits=bits)
    base.update(cfg_kw)
    cfg = MFedMCConfig(**base)
    clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                     samples_per_client=24)
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist


class TestQuantizedRoundParity:
    @pytest.mark.parametrize("bits", [8, 4])
    def test_loop_vs_batched_quantized(self, bits):
        """Round-1 server encoders, exact ledger bytes, and selection
        decisions match across backends under a quantized uplink."""
        se_l, h_l = _run("loop", bits)
        se_b, h_b = _run("batched", bits)
        assert set(se_l) == set(se_b)
        for m in se_l:
            for k in se_l[m]:
                np.testing.assert_allclose(np.asarray(se_b[m][k]),
                                           np.asarray(se_l[m][k]),
                                           atol=TOL, rtol=0,
                                           err_msg=f"{m}/{k}")
        assert h_b.records[0].comm_mb == h_l.records[0].comm_mb
        assert h_b.records[0].uploads == h_l.records[0].uploads

    def test_ledger_bytes_are_exact(self):
        _, h = _run("batched", 8)
        clients, _ = build_federation(
            "ucihar", "iid", cfg=MFedMCConfig(seed=0), seed=0,
            samples_per_client=24)
        per_enc = {m: encoder_bytes(clients[0].encoders[m], 8)
                   for m in clients[0].modality_names}
        expect = sum(per_enc[m] for _, m in h.records[0].uploads)
        assert h.records[0].comm_mb == expect / 1e6

    def test_quantize_bits_override_kwarg(self):
        cfg = MFedMCConfig(rounds=1, local_epochs=1, seed=0,
                           modality_strategy="random", quantize_bits=32)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=16)
        h8 = run_federation(clients, spec, cfg, backend="batched",
                            quantize_bits=8)
        clients2, _ = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                       samples_per_client=16)
        h32 = run_federation(clients2, spec, cfg, backend="batched")
        assert h8.records[0].uploads == h32.records[0].uploads
        assert h8.records[0].comm_mb < 0.3 * h32.records[0].comm_mb

    def test_invalid_bits_rejected(self):
        cfg = MFedMCConfig(rounds=1, quantize_bits=20)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                         samples_per_client=16)
        with pytest.raises(ValueError):
            run_federation(clients, spec, cfg)


# ---------------------------------------------------------------------------
# mesh (Tier 3) composition
# ---------------------------------------------------------------------------

class TestMeshQuantizedUplink:
    def setup_method(self):
        from repro.core.distributed import make_federated_round
        self.make = make_federated_round
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))

    def _inputs(self, K=4, steps=2, B=8, t=6, f=4, c=3):
        ks = jax.random.split(jax.random.key(0), 3)
        enc = init_encoder(ks[0], (t, f), c)
        stacked = jax.tree.map(
            lambda x: jnp.stack([x + 0.01 * i for i in range(K)]), enc)
        x = jax.random.normal(ks[1], (K, steps, B, t, f))
        y = jax.random.randint(ks[2], (K, steps, B), 0, c)
        return stacked, {"x": x, "y": y}

    def test_aggregate_is_fedavg_of_quantized_payloads(self):
        """make_federated_round(quantize_bits=8): the server aggregate is
        Eq. 21 over fake-quantized locally-trained params — the §4.10
        composition as real code, not a comment."""
        from repro.core.encoders import encoder_loss
        K = 4
        stacked, batches = self._inputs(K)
        select = jnp.asarray([1, 0, 1, 1], jnp.float32)
        weight = jnp.asarray([10, 20, 30, 40], jnp.float32)
        rnd = self.make(self.mesh, local_steps=2, lr=0.05, quantize_bits=8)
        with self.mesh:
            deployed, agg, _ = jax.jit(rnd)(stacked, batches, select, weight)

        def local(params_k, xk, yk):
            p = params_k
            for s in range(2):
                g = jax.grad(encoder_loss)(p, xk[s], yk[s])
                p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
            return p

        trained = [local(jax.tree.map(lambda v: v[k], stacked),
                         batches["x"][k], batches["y"][k])
                   for k in range(K)]
        per_client = [fake_quantize_pytree(t, 8) for t in trained]
        scales = [quantize_pytree(t, 8)[1] for t in trained]
        w = np.asarray(select) * np.asarray(weight)
        w = w / w.sum()
        for key in agg:
            expect = sum(w[k] * np.asarray(per_client[k][key], np.float32)
                         for k in range(K))
            diff = np.abs(np.asarray(agg[key]) - expect)
            # the reference retrains with a different op order, so a few
            # elements may land across a code boundary: allow ≤ one code
            # step there, and exact (1e-5) agreement everywhere else
            step = max(float(scales[k][key]) for k in range(K))
            assert diff.max() <= step + 1e-5, key
            assert np.mean(diff > 1e-5) < 1e-3, key
        # deployment is unchanged by quantization: the (quantized-payload)
        # aggregate broadcasts into every slot, selected or not
        for key in agg:
            for k in range(K):
                np.testing.assert_array_equal(
                    np.asarray(deployed[key][k]), np.asarray(agg[key]),
                    err_msg=f"{key}[{k}]")

    def test_empty_selection_keeps_full_precision_locals(self):
        """With an all-zero mask nothing aggregates, and each client keeps
        its own locally-trained params — which must NOT be quantized values
        (local training runs full precision; only the uplink payload is
        fake-quantized)."""
        stacked, batches = self._inputs()
        select = jnp.zeros((4,), jnp.float32)
        weight = jnp.ones((4,), jnp.float32)
        rnd = self.make(self.mesh, local_steps=2, lr=0.05, quantize_bits=4)
        with self.mesh:
            deployed, _, _ = jax.jit(rnd)(stacked, batches, select, weight)
        for k in range(4):
            local_k = jax.tree.map(lambda v: v[k], deployed)
            q_k = fake_quantize_pytree(local_k, 4)
            assert not np.allclose(np.asarray(local_k["w_fc"]),
                                   np.asarray(q_k["w_fc"]))

    def test_bits32_is_identity_composition(self):
        stacked, batches = self._inputs()
        select = jnp.ones((4,), jnp.float32)
        weight = jnp.ones((4,), jnp.float32)
        plain = self.make(self.mesh, local_steps=2, lr=0.05)
        passthru = self.make(self.mesh, local_steps=2, lr=0.05,
                             quantize_bits=32)
        with self.mesh:
            _, a1, _ = jax.jit(plain)(stacked, batches, select, weight)
            _, a2, _ = jax.jit(passthru)(stacked, batches, select, weight)
        for key in a1:
            np.testing.assert_array_equal(np.asarray(a1[key]),
                                          np.asarray(a2[key]))

    def test_invalid_bits_rejected_at_build(self):
        with pytest.raises(ValueError):
            self.make(self.mesh, local_steps=2, quantize_bits=24)

"""Paper-faithful encoder + fusion module tests (shapes, learning signal)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoders import (
    encoder_forward,
    encoder_num_params,
    encoder_predict,
    encoder_sgd_step,
    init_cnn_encoder,
    init_encoder,
    init_lstm_encoder,
)
from repro.core.fusion import (fusion_eval, fusion_forward, fusion_sgd_step,
                               init_fusion)


class TestLSTMEncoder:
    def test_shapes(self):
        p = init_lstm_encoder(jax.random.key(0), 6, 5)
        x = jnp.ones((3, 10, 6))
        assert encoder_forward(p, x).shape == (3, 5)

    def test_loss_decreases_on_separable_data(self):
        rng = np.random.default_rng(0)
        n, t, f, c = 64, 8, 4, 3
        y = rng.integers(0, c, n)
        x = rng.standard_normal((n, t, f)).astype(np.float32) * 0.1
        x[:, :, 0] += y[:, None]            # class-coded feature
        p = init_encoder(jax.random.key(0), (t, f), c)
        xb, yb = jnp.asarray(x), jnp.asarray(y)
        first = None
        for _ in range(30):
            p, loss = encoder_sgd_step(p, xb, yb, lr=0.5)
            first = first if first is not None else float(loss)
        assert float(loss) < first * 0.7

    def test_predict_is_onehot(self):
        p = init_lstm_encoder(jax.random.key(0), 4, 7)
        out = encoder_predict(p, jnp.ones((5, 6, 4)))
        np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0)
        assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}


class TestCNNEncoder:
    def test_shapes(self):
        p = init_cnn_encoder(jax.random.key(0), (32, 32, 3), 12)
        x = jnp.ones((2, 32, 32, 3))
        assert encoder_forward(p, x).shape == (2, 12)

    def test_init_dispatch(self):
        assert "conv_w" in init_encoder(jax.random.key(0), (32, 32, 1), 4)
        assert "w_x" in init_encoder(jax.random.key(0), (16, 8), 4)

    def test_param_count(self):
        p = init_cnn_encoder(jax.random.key(0), (32, 32, 1), 12)
        # conv 5·5·1·32 + 32 + fc (14·14·32)·12 + 12
        assert encoder_num_params(p) == 5 * 5 * 32 + 32 + 14 * 14 * 32 * 12 + 12


class TestFusion:
    def test_shapes_and_mask(self):
        m, c = 4, 6
        p = init_fusion(jax.random.key(0), m, c)
        preds = jnp.ones((8, m, c))
        out = fusion_forward(p, preds, jnp.ones((m,)))
        assert out.shape == (8, c)
        # per-sample mask also supported
        out2 = fusion_forward(p, preds, jnp.ones((8, m)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2))

    def test_masked_modality_is_ignored(self):
        m, c = 3, 4
        p = init_fusion(jax.random.key(0), m, c)
        preds_a = jnp.asarray(np.random.default_rng(0).random((5, m, c)),
                              jnp.float32)
        preds_b = preds_a.at[:, 2].set(99.0)   # only differs at masked slot
        mask = jnp.asarray([1.0, 1.0, 0.0])
        np.testing.assert_allclose(
            np.asarray(fusion_forward(p, preds_a, mask)),
            np.asarray(fusion_forward(p, preds_b, mask)))

    def test_fusion_learns(self):
        rng = np.random.default_rng(1)
        m, c, n = 3, 4, 128
        y = rng.integers(0, c, n)
        onehot = np.eye(c, dtype=np.float32)[y]
        preds = np.stack([onehot, onehot,
                          rng.random((n, c)).astype(np.float32)], 1)
        p = init_fusion(jax.random.key(1), m, c)
        mask = jnp.ones((m,))
        pj, yj = jnp.asarray(preds), jnp.asarray(y)
        for _ in range(60):
            p, _ = fusion_sgd_step(p, pj, mask, yj, lr=0.5)
        _, acc = fusion_eval(p, pj, mask, yj)
        assert float(acc) > 0.9

"""System-substrate behaviour tests: blocked attention oracle, optimizers,
loss chunking, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.steps import chunked_cross_entropy, cross_entropy
from repro.models.blocked_attention import _plain_attention, flash_attention
from repro.models.model import param_specs
from repro.optim import adamw, apply_updates, sgd_momentum
from repro.sharding.partition import opt_state_pspecs, param_pspecs


class TestBlockedAttention:
    @pytest.mark.parametrize("window", [0, 16])
    def test_matches_plain(self, window):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (2, 2, 2, 64, 16))
        k = jax.random.normal(ks[1], (2, 2, 128, 16))
        v = jax.random.normal(ks[2], (2, 2, 128, 16))
        blocked = flash_attention(q, k, v, causal=True, window=window,
                                  q_offset=64, block_q=16, block_k=32)
        plain = _plain_attention(q, k, v, causal=True, window=window,
                                 q_offset=64)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(plain),
                                   rtol=2e-5, atol=2e-5)

    def test_mla_mismatched_v_dim(self):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 1, 4, 64, 24))
        k = jax.random.normal(ks[1], (1, 1, 64, 24))
        v = jax.random.normal(ks[2], (1, 1, 64, 16))     # dv != dqk
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        exp = _plain_attention(q, k, v, causal=True, window=0)
        assert out.shape == (1, 1, 4, 64, 16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)


class TestLoss:
    def test_chunked_ce_matches_dense(self):
        ks = jax.random.split(jax.random.key(0), 3)
        b, s, d, v = 2, 64, 16, 50
        h = jax.random.normal(ks[0], (b, s, d))
        w = jax.random.normal(ks[1], (d, v)) * 0.1
        y = jax.random.randint(ks[2], (b, s), 0, v)
        dense = cross_entropy(h @ w, y)
        chunked = chunked_cross_entropy(h, w, y, chunk=16)
        np.testing.assert_allclose(float(chunked), float(dense), rtol=1e-5)

    def test_chunked_ce_grads_match(self):
        ks = jax.random.split(jax.random.key(1), 3)
        b, s, d, v = 2, 32, 8, 20
        h = jax.random.normal(ks[0], (b, s, d))
        w = jax.random.normal(ks[1], (d, v)) * 0.1
        y = jax.random.randint(ks[2], (b, s), 0, v)
        g1 = jax.grad(lambda ww: cross_entropy(h @ ww, y))(w)
        g2 = jax.grad(lambda ww: chunked_cross_entropy(h, ww, y, chunk=8))(w)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-6)


class TestOptim:
    def test_sgd_momentum(self):
        params = {"w": jnp.ones(3)}
        opt = sgd_momentum(0.1, momentum=0.9)
        state = opt.init(params)
        grads = {"w": jnp.ones(3)}
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
        np.testing.assert_allclose(np.asarray(params["w"]), 0.9, rtol=1e-6)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
        # velocity = 0.9*1 + 1 = 1.9 -> w = 0.9 - 0.19
        np.testing.assert_allclose(np.asarray(params["w"]), 0.71, rtol=1e-5)

    def test_adamw_converges_quadratic(self):
        opt = adamw(0.1)
        params = {"w": jnp.asarray(5.0)}
        state = opt.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda w: 2 * w, params)
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        assert abs(float(params["w"])) < 0.1


class TestShardingRules:
    def test_param_pspecs_structure(self):
        cfg = get_config("phi3-medium-14b").smoke()
        specs = param_specs(cfg)
        pspecs = param_pspecs(specs)
        flat = jax.tree_util.tree_flatten_with_path(pspecs)[0]
        by_name = {}
        for path, spec in flat:
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            by_name.setdefault(name, spec)
        assert by_name["wq"][-1] == "model"
        assert by_name["wo"][-2] == "model"
        assert by_name["embed"] == P(None, "model")

    def test_moe_expert_parallel(self):
        cfg = get_config("granite-moe-1b-a400m").smoke()
        specs = param_specs(cfg)
        pspecs = param_pspecs(specs)
        flat = jax.tree_util.tree_flatten_with_path(pspecs)[0]
        moe_in = [s for p, s in flat
                  if "moe" in str(p) and str(p[-1].key) == "w_in"
                  and "dense_residual" not in str(p)]
        assert moe_in and moe_in[0][-3] == "model"   # experts on model axis

    def test_zero1_adds_data_axis(self):
        cfg = get_config("xlstm-125m").smoke()
        specs = param_specs(cfg)

        class FakeMesh:
            shape = {"data": 2, "model": 1}

        opt_specs = opt_state_pspecs(specs, FakeMesh())
        has_data = any("data" in tuple(s)
                       for s in jax.tree.leaves(
                           opt_specs, is_leaf=lambda x: isinstance(x, P)))
        assert has_data

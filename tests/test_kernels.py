"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kv,s,d", [
        (1, 4, 4, 64, 32),          # MHA
        (2, 4, 2, 128, 32),         # GQA g=2
        (1, 8, 1, 64, 64),          # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_shapes_vs_oracle(self, b, h, kv, s, d, causal):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, kv, s, d))
        v = jax.random.normal(ks[2], (b, kv, s, d))
        out = ops.flash_attention(q, k, v, causal=causal,
                                  block_q=32, block_k=32)
        exp = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   **_tol(q.dtype))

    def test_window(self):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 16))
        k = jax.random.normal(ks[1], (1, 2, 128, 16))
        v = jax.random.normal(ks[2], (1, 2, 128, 16))
        out = ops.flash_attention(q, k, v, causal=True, window=32,
                                  block_q=32, block_k=32)
        exp = ref.flash_attention_ref(q, k, v, causal=True, window=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)

    def test_bfloat16(self):
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 32), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.bfloat16)
        out = ops.flash_attention(q, k, v, causal=True,
                                  block_q=32, block_k=32)
        exp = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            **_tol(jnp.bfloat16))

    def test_cross_block_boundary(self):
        """Online-softmax must combine across k blocks: one strong kv hit
        in the first block, queries in the last."""
        s, d = 128, 16
        q = jnp.zeros((1, 1, s, d)).at[:, :, -1, 0].set(10.0)
        k = jnp.zeros((1, 1, s, d)).at[:, :, 3, 0].set(10.0)
        v = jax.random.normal(jax.random.key(3), (1, 1, s, d))
        out = ops.flash_attention(q, k, v, causal=True,
                                  block_q=32, block_k=32)
        exp = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)


class TestRGLRUScan:
    @pytest.mark.parametrize("b,s,w,bt,bw", [
        (1, 32, 32, 8, 16),
        (2, 64, 64, 16, 32),
        (2, 128, 32, 32, 32),       # single width block
    ])
    def test_vs_oracle(self, b, s, w, bt, bw):
        ks = jax.random.split(jax.random.key(0), 4)
        x = jax.random.normal(ks[0], (b, s, w))
        wa = 0.05 * jax.random.normal(ks[1], (w, w))
        wx = 0.05 * jax.random.normal(ks[2], (w, w))
        lam = jax.random.normal(ks[3], (w,))
        h, hl = ops.rglru_scan(x, wa, wx, lam, block_t=bt, block_w=bw)
        hr, hlr = ref.rglru_scan_ref(x, wa, wx, lam)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                                   rtol=1e-4, atol=1e-5)

    def test_state_carries_across_time_blocks(self):
        """An impulse in the first time block must decay into later blocks."""
        b, s, w = 1, 64, 32
        x = jnp.zeros((b, s, w)).at[:, 0, :].set(1.0)
        wa = jnp.zeros((w, w))       # r = 0.5 -> slow decay
        wx = jnp.zeros((w, w))       # i = 0.5
        lam = jnp.full((w,), -2.0)
        h, _ = ops.rglru_scan(x, wa, wx, lam, block_t=8, block_w=32)
        hr, _ = ref.rglru_scan_ref(x, wa, wx, lam)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   rtol=1e-5, atol=1e-6)
        assert float(jnp.abs(h[:, 40:]).max()) > 0   # state propagated


class TestMLSTMScan:
    @pytest.mark.parametrize("b,h,s,dk,dv,chunk", [
        (1, 2, 32, 16, 16, 8),
        (2, 2, 64, 16, 32, 16),     # dk != dv
        (1, 4, 128, 32, 32, 64),
    ])
    def test_vs_oracle(self, b, h, s, dk, dv, chunk):
        ks = jax.random.split(jax.random.key(0), 5)
        q = jax.random.normal(ks[0], (b, h, s, dk))
        k = jax.random.normal(ks[1], (b, h, s, dk))
        v = jax.random.normal(ks[2], (b, h, s, dv))
        ip = jax.random.normal(ks[3], (b, h, s))
        fp = jax.random.normal(ks[4], (b, h, s)) + 2.0
        out, (C, n, m) = ops.mlstm_scan(q, k, v, ip, fp, chunk=chunk)
        exp, (Cr, nr, mr) = ref.mlstm_scan_ref(q, k, v, ip, fp, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(C), np.asarray(Cr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(m), np.asarray(mr),
                                   rtol=1e-5, atol=1e-5)

    def test_chunk_boundary_state(self):
        """Different chunk sizes must give identical results (the carried
        (C, n, m) state is exact, not approximate)."""
        ks = jax.random.split(jax.random.key(7), 5)
        b, h, s, d = 1, 1, 64, 8
        q = jax.random.normal(ks[0], (b, h, s, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        ip = jax.random.normal(ks[3], (b, h, s))
        fp = jax.random.normal(ks[4], (b, h, s)) + 2.0
        o8, _ = ops.mlstm_scan(q, k, v, ip, fp, chunk=8)
        o32, _ = ops.mlstm_scan(q, k, v, ip, fp, chunk=32)
        np.testing.assert_allclose(np.asarray(o8), np.asarray(o32),
                                   rtol=1e-4, atol=1e-4)

"""Model ↔ Pallas-kernel integration: forcing the kernel path (interpret
mode on CPU) must reproduce the XLA path's forward outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as kops
from repro.configs import get_config, smoke_shape
from repro.models.model import forward, init_params, input_specs


@pytest.fixture
def force_pallas(monkeypatch):
    monkeypatch.setattr(kops, "use_pallas", lambda: True)


def _smoke_batch(cfg, shape, seed=0):
    specs = input_specs(cfg, shape)
    rng = jax.random.key(seed)
    out = {}
    for k, s in specs.items():
        rng, sub = jax.random.split(rng)
        if s.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size)
        else:
            out[k] = 0.1 * jax.random.normal(sub, s.shape, s.dtype)
    return out


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "recurrentgemma-2b",
                                  "xlstm-125m"])
def test_kernel_path_matches_xla_path(arch, force_pallas):
    cfg = get_config(arch).smoke()
    params = init_params(jax.random.key(0), cfg)
    shape = smoke_shape("train")
    batch = _smoke_batch(cfg, shape)
    logits_kernel, _ = forward(params, cfg, batch)
    assert kops.use_pallas()          # fixture active

    # undo the patch for the reference run
    import repro.kernels.ops
    object.__setattr__  # noqa: B018 — no-op, clarity only
    repro.kernels.ops.use_pallas = lambda: False
    try:
        logits_xla, _ = forward(params, cfg, batch)
    finally:
        pass
    np.testing.assert_allclose(np.asarray(logits_kernel, np.float32),
                               np.asarray(logits_xla, np.float32),
                               rtol=5e-3, atol=5e-3)

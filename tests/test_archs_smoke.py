"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step + one decode step on
CPU with finite outputs and the expected shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_shape
from repro.launch.steps import make_serve_step, make_train_step
from repro.models.model import (decode_step, forward, init_cache,
                                init_params, input_specs)
from repro.optim import adamw

ARCHS = list_archs()


def _batch(cfg, shape, seed=0):
    specs = input_specs(cfg, shape)
    rng = jax.random.key(seed)
    out = {}
    for k, s in specs.items():
        rng, sub = jax.random.split(rng)
        if s.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size)
        else:
            out[k] = 0.1 * jax.random.normal(sub, s.shape, s.dtype)
    return out


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).smoke()
            cache[arch] = (cfg, init_params(jax.random.key(0), cfg))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch).smoke()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, params_cache):
    cfg, params = params_cache(arch)
    shape = smoke_shape("train")
    batch = _batch(cfg, shape)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (shape.global_batch, shape.seq_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, params_cache):
    cfg, params = params_cache(arch)
    shape = smoke_shape("train")
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, shape))
    batch = _batch(cfg, shape)
    new_params, _, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_one_decode_step(arch, params_cache):
    cfg, params = params_cache(arch)
    shape = smoke_shape("decode")
    mem_len = cfg.vision_tokens if cfg.family == "vlm" else \
        (max(shape.seq_len // cfg.encoder_frame_ratio, 1)
         if cfg.family == "audio" else 0)
    cache = init_cache(cfg, shape.global_batch, shape.seq_len,
                       memory_len=mem_len)
    step = jax.jit(make_serve_step(cfg, shape))
    batch = {"tokens": jnp.zeros((shape.global_batch, 1), jnp.int32)}
    logits, new_cache = step(params, cache, batch)
    assert logits.shape == (shape.global_batch, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(jax.tree.leaves(
        {"pos": new_cache["pos"]})[0]) == 1


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "recurrentgemma-2b",
                                  "xlstm-125m"])
def test_decode_matches_prefill_tail(arch, params_cache):
    """Greedy decode after a prompt must agree with full-sequence forward
    at the same position (cache correctness)."""
    cfg, params = params_cache(arch)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["vision_embeddings"] = 0.1 * jax.random.normal(
            jax.random.key(6), (b, cfg.vision_tokens, cfg.d_model))
    logits_full, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, b, s)
    lg = None
    for t in range(s):
        lg, cache = decode_step(params, cfg, cache,
                                {"tokens": tokens[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)

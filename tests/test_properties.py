"""Hypothesis property tests on system invariants that cut across modules:
quantization error bounds, selection/priority invariances, ledger linearity,
and data-partitioner conservation laws."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.aggregation import CommLedger, aggregate_modality
from repro.core.quantize import dequantize_tensor, quantize_tensor
from repro.core.selection import minmax_normalize, modality_priority

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


class TestQuantizeProperties:
    @given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                   max_side=16),
                      elements=st.floats(-1e3, 1e3, width=32)),
           st.sampled_from([4, 8, 16]))
    def test_roundtrip_error_bounded_by_half_step(self, x, bits):
        xj = jnp.asarray(x)
        codes, scale, zero = quantize_tensor(xj, bits)
        back = dequantize_tensor(codes, scale, zero)
        # slack: fixed epsilon plus a few float32 ulps of the value
        # magnitude — at 16 bits the half-step (~range/2^17) is of the same
        # order as ulp(|x|), so rounding in codes*scale+zero is visible
        slack = 1e-4 + 4e-7 * float(jnp.max(jnp.abs(xj)) + 1)
        assert float(jnp.max(jnp.abs(back - xj))) <= scale / 2 + slack

    @given(hnp.arrays(np.float32, (8, 4),
                      elements=st.floats(-10, 10, width=32)))
    def test_codes_within_range(self, x):
        codes, _, _ = quantize_tensor(jnp.asarray(x), 4)
        assert int(jnp.max(codes)) <= 15 and int(jnp.min(codes)) >= 0


class TestPriorityProperties:
    @given(st.lists(st.floats(0, 10, width=32), min_size=2, max_size=6),
           st.floats(0.01, 1), st.floats(0.01, 1), st.floats(0.01, 1))
    def test_priority_in_unit_interval_scaled(self, phis, a, b, c):
        """0 ≤ P ≤ α_s + α_c + α_r for any inputs."""
        m = len(phis)
        phi = np.array(phis)
        sizes = np.linspace(100, 200, m)
        rec = np.arange(m, dtype=float)
        p = modality_priority(phi, sizes, rec, t=max(m, 1),
                              alpha_s=a, alpha_c=b, alpha_r=c)
        assert np.all(p >= -1e-9)
        assert np.all(p <= a + b + c + 1e-9)

    @given(st.lists(st.floats(-100, 100), min_size=2, max_size=8),
           st.floats(0.1, 10), st.floats(-50, 50))
    def test_minmax_invariant_to_affine(self, xs, scale, shift):
        """Normalization is invariant to positive affine transforms."""
        x = np.array(xs)
        if np.ptp(x) < 1e-6:
            return
        a = minmax_normalize(x)
        b = minmax_normalize(scale * x + shift)
        np.testing.assert_allclose(a, b, atol=1e-6)


class TestAggregationProperties:
    @given(st.integers(2, 5), st.integers(0, 10_000))
    def test_permutation_invariance(self, n, seed):
        """FedAvg must not depend on upload order."""
        rng = np.random.default_rng(seed)
        encs = [{"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
                for _ in range(n)]
        counts = rng.integers(1, 100, n).tolist()
        agg1 = aggregate_modality(encs, counts)
        perm = rng.permutation(n)
        agg2 = aggregate_modality([encs[i] for i in perm],
                                  [counts[i] for i in perm])
        np.testing.assert_allclose(np.asarray(agg1["w"]),
                                   np.asarray(agg2["w"]), rtol=1e-5)

    @given(st.lists(st.floats(1, 1e6), min_size=1, max_size=20))
    def test_ledger_linearity(self, amounts):
        led = CommLedger()
        for a in amounts:
            led.record(a)
        assert led.uploaded_bytes == pytest.approx(sum(amounts), rel=1e-9)
        assert led.uploads == len(amounts)


class TestDataProperties:
    @given(st.integers(0, 1000))
    def test_partition_conserves_labels_range(self, seed):
        from repro.data import make_dataset
        from repro.data.partition import partition_class_noniid
        ds = make_dataset("ucihar", seed=seed % 7)
        clients = partition_class_noniid(ds, beta=0.5, seed=seed,
                                         samples_per_client=12)
        assert len(clients) == 30
        for c in clients:
            assert c.labels.min() >= 0
            assert c.labels.max() < 6
            for m, arr in c.modalities.items():
                assert arr.shape[0] == c.num_samples
                assert np.isfinite(arr).all()

"""Aggregation (Eq. 21), comm ledger / transport model, and quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.aggregation import CommLedger, IOT_UPLINK, aggregate_modality
from repro.core.encoders import encoder_bytes, init_encoder
from repro.core.quantize import (dequantize_tensor, quantize_tensor,
                                 quantized_roundtrip)


def _encs(n, seed=0):
    return [init_encoder(jax.random.key(seed + i), (8, 4), 5)
            for i in range(n)]


class TestAggregation:
    def test_weights_eq21(self):
        e1, e2 = _encs(2)
        agg = aggregate_modality([e1, e2], [30, 10])
        for k in agg:
            np.testing.assert_allclose(
                np.asarray(agg[k]), 0.75 * np.asarray(e1[k])
                + 0.25 * np.asarray(e2[k]), rtol=1e-6)

    def test_single_upload_identity(self):
        (e,) = _encs(1)
        agg = aggregate_modality([e], [17])
        for k in agg:
            np.testing.assert_allclose(np.asarray(agg[k]), np.asarray(e[k]),
                                       rtol=1e-7)

    @given(st.lists(st.integers(1, 1000), min_size=2, max_size=5))
    @settings(max_examples=20, deadline=None)
    def test_convexity(self, counts):
        """Aggregate lies inside the per-leaf convex hull of the uploads."""
        encs = _encs(len(counts))
        agg = aggregate_modality(encs, counts)
        for k in agg:
            stack = np.stack([np.asarray(e[k]) for e in encs])
            assert np.all(np.asarray(agg[k]) <= stack.max(0) + 1e-5)
            assert np.all(np.asarray(agg[k]) >= stack.min(0) - 1e-5)


class TestTransport:
    def test_paper_time_model(self):
        # Table 7: T = bytes × 1.2 × 1.5 / (10e6/8)
        assert IOT_UPLINK.seconds(10e6 / 8) == pytest.approx(1.2 * 1.5)

    def test_ledger(self):
        led = CommLedger()
        led.record(1_000_000)
        led.record(500_000, 2)
        assert led.megabytes == pytest.approx(1.5)
        assert led.uploads == 3


class TestQuantize:
    @pytest.mark.parametrize("bits,tol", [(8, 0.02), (4, 0.3)])
    def test_roundtrip_error_bound(self, bits, tol):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 32)),
                        jnp.float32)
        codes, scale, zero = quantize_tensor(x, bits)
        back = dequantize_tensor(codes, scale, zero)
        # max error <= scale/2 + eps
        assert float(jnp.max(jnp.abs(back - x))) <= scale / 2 + 1e-6
        assert float(jnp.mean(jnp.abs(back - x))) < tol

    def test_encoder_roundtrip_structure(self):
        (e,) = _encs(1)
        back = quantized_roundtrip(e, 8)
        assert set(back) == set(e)
        for k in e:
            assert back[k].shape == e[k].shape

    def test_bits32_passthrough(self):
        (e,) = _encs(1)
        assert quantized_roundtrip(e, 32) is e

    def test_encoder_bytes_scaling(self):
        # exact wire accounting: packed codes + 8B scale/zero per tensor
        (e,) = _encs(1)
        n = sum(int(np.prod(v.shape)) for v in e.values())
        meta = 8 * len(e)
        assert encoder_bytes(e, 32) == 4 * n            # raw f32, no meta
        assert encoder_bytes(e, 16) == 2 * n + meta     # uint16, not int32
        assert encoder_bytes(e, 8) == n + meta
        # 4-bit packs two codes per byte (per-tensor ceil)
        assert encoder_bytes(e, 4) == \
            sum(-((int(np.prod(v.shape)) * 4) // -8) for v in e.values()) \
            + meta

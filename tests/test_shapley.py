"""Shapley estimator tests: game-theoretic axioms as (hypothesis) properties
on the exact interventional estimator, plus exact-vs-sampled agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fusion import fusion_forward, init_fusion
from repro.core.shapley import exact_shapley, sampled_shapley, subset_masks


def _setup(m=3, c=4, b=6, g=5, seed=0):
    rng = np.random.default_rng(seed)
    fusion = init_fusion(jax.random.key(seed), m, c)
    preds = jnp.asarray(rng.random((b, m, c)), jnp.float32)
    bg = jnp.asarray(rng.random((g, m, c)), jnp.float32)
    y = jnp.asarray(rng.integers(0, c, b), jnp.int32)
    return fusion, preds, bg, y


def _value(fusion, preds, bg, mask_vec, avail, y):
    """Direct coalition value v(S) for cross-checking."""
    b, m, c = preds.shape
    g = bg.shape[0]
    sm = jnp.asarray(mask_vec, jnp.float32)
    mixed = (sm[None, None, :, None] * preds[:, None]
             + (1 - sm)[None, None, :, None] * bg[None]).reshape(b * g, m, c)
    logits = fusion_forward(fusion, mixed,
                            jnp.broadcast_to(avail[None], (b * g, m)))
    p = jax.nn.softmax(logits.astype(jnp.float32)).reshape(b, g, c)
    pt = jnp.take_along_axis(p, jnp.broadcast_to(y[:, None, None], (b, g, 1)),
                             axis=2)
    return float(jnp.mean(pt))


class TestSubsetMasks:
    def test_enumeration(self):
        m = subset_masks(3)
        assert m.shape == (8, 3)
        assert m.sum() == 12                 # each player in half the subsets
        assert not m[0].any() and m[-1].all()


class TestAxioms:
    @pytest.mark.parametrize("m", [2, 3, 4])
    def test_efficiency(self, m):
        """Σφ = v(full) − v(∅) — the Shapley efficiency axiom."""
        fusion, preds, bg, y = _setup(m=m)
        avail = jnp.ones((m,), jnp.float32)
        phi = exact_shapley(fusion, preds, bg, avail, y, num_modalities=m)
        v_full = _value(fusion, preds, bg, np.ones(m), avail, y)
        v_empty = _value(fusion, preds, bg, np.zeros(m), avail, y)
        np.testing.assert_allclose(float(jnp.sum(phi)), v_full - v_empty,
                                   rtol=1e-4, atol=1e-5)

    def test_dummy_player(self):
        """A modality whose eval predictions equal the background ones has
        zero marginal contribution in every coalition -> φ = 0."""
        fusion, preds, bg, y = _setup(m=3, b=5, g=5)
        # make modality 1 a dummy: identical rows in eval and background
        const = jnp.broadcast_to(jnp.linspace(0, 1, 4)[None], (5, 4))
        preds = preds.at[:, 1].set(const)
        bg = bg.at[:, 1].set(const)
        avail = jnp.ones((3,), jnp.float32)
        phi = exact_shapley(fusion, preds, bg, avail, y, num_modalities=3)
        assert abs(float(phi[1])) < 1e-6

    def test_absent_modality_is_dummy(self):
        """Zero-filled absent modalities get exactly φ = 0 and do not change
        the other values vs the restricted game."""
        fusion, preds, bg, y = _setup(m=3)
        preds = preds.at[:, 2].set(0.0)
        bg = bg.at[:, 2].set(0.0)
        avail = jnp.asarray([1.0, 1.0, 0.0])
        phi = exact_shapley(fusion, preds, bg, avail, y, num_modalities=3)
        assert abs(float(phi[2])) < 1e-6

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_efficiency_random_instances(self, seed):
        fusion, preds, bg, y = _setup(m=3, seed=seed)
        avail = jnp.ones((3,), jnp.float32)
        phi = exact_shapley(fusion, preds, bg, avail, y, num_modalities=3)
        v_full = _value(fusion, preds, bg, np.ones(3), avail, y)
        v_empty = _value(fusion, preds, bg, np.zeros(3), avail, y)
        np.testing.assert_allclose(float(jnp.sum(phi)), v_full - v_empty,
                                   rtol=1e-3, atol=1e-5)


class TestSampledEstimator:
    def test_agrees_with_exact(self):
        fusion, preds, bg, y = _setup(m=3)
        avail = jnp.ones((3,), jnp.float32)
        phi_e = exact_shapley(fusion, preds, bg, avail, y, num_modalities=3)
        phi_s = sampled_shapley(fusion, preds, bg, avail, y,
                                num_modalities=3, num_permutations=200,
                                rng=np.random.default_rng(0))
        np.testing.assert_allclose(np.asarray(phi_s), np.asarray(phi_e),
                                   atol=0.02)

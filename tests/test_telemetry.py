"""Telemetry tier: the observability subsystem's three contracts.

1. **Reconciliation** — a traced seeded federation on every backend
   writes spans/metrics/Perfetto artifacts whose sums equal the global
   hostsync counters and the CommLedger exactly (and the report CLI
   re-proves it from the files alone);
2. **Zero-interference** — installing a tracer never changes a round
   outcome: uploads, losses, accuracies, and selection are bit-identical
   with tracing on and off;
3. **Scoping** — span counter deltas stay correct around fully-nested
   ``hostsync.measuring()`` windows, and the reconciliation checks
   actually fire on hand-built violations (the self-test the lint tier
   leans on).

The ``lint``-marked subset re-runs ``repro.analysis.telemetry_check``
the way ``python -m repro.analysis.lint`` does.
"""
import json
import os

import numpy as np
import pytest

from repro import telemetry
from repro.analysis import budgets
from repro.core import hostsync
from repro.core.rounds import MFedMCConfig, run_federation
from repro.telemetry import report
from repro.telemetry.export import METRICS_FILE, SPANS_FILE, TRACE_FILE
from repro.telemetry.reconcile import reconcile_records
from repro.telemetry.timer import interleaved_min

BACKENDS = ("loop", "batched", "engine", "async", "sharded")
ROUNDS = 3


def _mini(comm_impl="fused", rounds=ROUNDS):
    clients, spec = budgets.mini_federation()
    cfg = budgets.federation_config(comm_impl, rounds=rounds)
    return clients, spec, cfg


class TestTracedRuns:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_trace_reconciles_and_exports(self, backend, tmp_path):
        clients, spec, cfg = _mini()
        out = str(tmp_path / f"trace_{backend}")
        with telemetry.tracing(out) as tracer:
            h = run_federation(clients, spec, cfg, backend=backend)
        assert telemetry.get() is None          # uninstalled on exit
        assert len(h.records) == ROUNDS

        # live-tracer reconciliation: span sums == hostsync totals,
        # uplink log == CommLedger, exactly
        assert telemetry.reconcile(tracer) == []
        totals = tracer.finish()
        assert totals["host_syncs"] > 0
        assert totals["bytes_moved"] > 0
        rounds = [r for r in tracer.roots() if r.name == "round"]
        assert len(rounds) == ROUNDS
        names = {r.name for r in tracer.records}
        assert {"round", "train.local", "comm.uplink", "eval"} <= names

        # written artifacts carry the same records
        for fn in (SPANS_FILE, METRICS_FILE, TRACE_FILE):
            assert os.path.exists(os.path.join(out, fn))
        run_totals, spans, met_rounds, met_run = report.load_trace_dir(out)
        assert run_totals["host_syncs"] == totals["host_syncs"]
        assert len(spans) == len(tracer.records)
        assert [r["round"] for r in met_rounds] == list(range(1, ROUNDS + 1))
        assert met_run["backend"] == backend
        assert met_run["ledger_bytes"] == sum(
            u["bytes"] for r in met_rounds for u in r["uplink"])

        # Perfetto schema: every event has ph/name/pid/tid (+ts off "M")
        with open(os.path.join(out, TRACE_FILE)) as f:
            trace = json.load(f)
        assert trace["traceEvents"]
        for ev in trace["traceEvents"]:
            assert {"ph", "name", "pid", "tid"} <= set(ev)
            if ev["ph"] != "M":
                assert "ts" in ev

        # the report CLI reconciles from the files alone (exit 0)
        assert report.main([out]) == 0

    def test_per_round_metrics_schema(self):
        clients, spec, cfg = _mini()
        with telemetry.tracing() as tracer:
            run_federation(clients, spec, cfg, backend="engine")
        for rec in tracer.metrics.rounds:
            assert rec["kind"] == "round"
            assert set(rec) >= {"round", "accuracy", "mean_loss",
                                "comm_mb", "uplink", "selected",
                                "choices", "shapley", "dropped"}
            for u in rec["uplink"]:
                assert set(u) >= {"client", "modality", "bytes"}
        # δ=0.2 over K=8 keeps exactly 2 clients per round
        assert all(len(r["selected"]) == 2 for r in tracer.metrics.rounds)


class TestAsyncVirtualTime:
    def test_flush_drop_and_virtual_events(self, tmp_path):
        from repro.core.scheduler import nominal_cycle_seconds
        clients, spec = budgets.mini_federation()
        base = dict(rounds=ROUNDS, local_epochs=1, batch_size=8, seed=0,
                    gamma=1, delta=1.0, modality_strategy="priority",
                    client_strategy="all", quantize_bits=4,
                    compute_sec_per_step=0.05, straggler_fraction=0.25,
                    straggler_factor=10.0, buffer_size=2,
                    staleness_discount=0.9)
        nom = nominal_cycle_seconds(clients, spec, MFedMCConfig(**base))
        cfg = MFedMCConfig(deadline_s=1.5 * nom, **base)
        out = str(tmp_path / "trace_async_drop")
        with telemetry.tracing(out) as tracer:
            h = run_federation(clients, spec, cfg, backend="async")
        assert telemetry.reconcile(tracer) == []

        # virtual-clock lanes: dispatch/local/upload per client, server
        # flush instants and cycle slices
        ev_names = {e.name for e in tracer.events}
        assert {"dispatch", "local", "upload", "flush",
                "cycle"} <= ev_names
        assert all(e.tid == 0 for e in tracer.events
                   if e.name in ("flush", "cycle"))
        # one deadline_drop instant per dropped id, pinned to the cycle
        dropped = [cid for r in h.records for cid in r.dropped]
        drops = [e for e in tracer.events if e.name == "deadline_drop"]
        assert dropped, "straggler setup must force deadline drops"
        assert sorted(e.tid for e in drops) == sorted(dropped)
        # metrics mirror the history's async fields
        mrounds = tracer.metrics.rounds
        assert [r["flushes"] for r in mrounds] == \
            [r.flushes for r in h.records]
        assert [r["dropped"] for r in mrounds] == \
            [sorted(r.dropped) for r in h.records]
        assert any(r["staleness"] for r in mrounds)
        assert [r["sim_time"] for r in mrounds] == \
            [r.sim_time for r in h.records]
        # flush work also shows on the wall clock as comm.flush spans
        assert any(s.name == "comm.flush" for s in tracer.records)

        # the virtual timeline lands on Perfetto pid 2
        with open(os.path.join(out, TRACE_FILE)) as f:
            trace = json.load(f)
        virt = [e for e in trace["traceEvents"]
                if e["pid"] == 2 and e["ph"] != "M"]
        assert virt
        assert {e["ph"] for e in virt} <= {"X", "i"}


class TestZeroInterference:
    def test_disabled_tracing_changes_no_round_outcome(self):
        clients_a, spec_a, cfg = _mini()
        h_plain = run_federation(clients_a, spec_a, cfg, backend="engine")
        clients_b, spec_b, _ = _mini()
        with telemetry.tracing() as tracer:
            h_traced = run_federation(clients_b, spec_b, cfg,
                                      backend="engine")
        assert len(tracer.records) > 0
        for ra, rb in zip(h_plain.records, h_traced.records):
            assert ra.accuracy == rb.accuracy
            assert ra.mean_loss == rb.mean_loss
            assert ra.comm_mb == rb.comm_mb
            assert ra.uploads == rb.uploads
            assert ra.shapley == rb.shapley

    def test_span_is_shared_noop_when_disabled(self):
        assert telemetry.get() is None
        s1, s2 = telemetry.span("a"), telemetry.span("b", k=1)
        assert s1 is s2                         # the shared null span
        with s1 as rec:
            assert rec is None


class TestScoping:
    def test_span_counters_nest_with_measuring_window(self):
        tracer = telemetry.Tracer()
        with telemetry.install(tracer):
            with telemetry.span("outer"):
                hostsync.fetch(np.zeros(3))
                with hostsync.measuring() as m:
                    with telemetry.span("inner"):
                        hostsync.fetch(np.zeros(3))
                        hostsync.record_bytes(10)
                hostsync.fetch(np.zeros(3))
        # the window saw only its own fetch; the span saw all three
        assert m.as_dict() == {"host_syncs": 1, "bytes_moved": 10,
                               "dispatches": 0}
        outer, inner = tracer.records
        assert (outer.host_syncs, outer.bytes_moved) == (3, 10)
        assert (inner.host_syncs, inner.bytes_moved) == (1, 10)
        assert inner.parent == outer.index and inner.depth == 1
        assert tracer.finish()["host_syncs"] == 3
        assert telemetry.reconcile(tracer) == []

    def test_measurement_as_dict(self):
        with hostsync.measuring() as m:
            hostsync.fetch_scalar(1.0)
            hostsync.record_bytes(5)
            hostsync.record_dispatch(2)
        assert m.as_dict() == {"host_syncs": 1, "bytes_moved": 5,
                               "dispatches": 2}

    def test_install_restores_previous_tracer(self):
        t1, t2 = telemetry.Tracer(), telemetry.Tracer()
        with telemetry.install(t1):
            assert telemetry.get() is t1
            with telemetry.install(t2):
                assert telemetry.get() is t2
            assert telemetry.get() is t1
        assert telemetry.get() is None


class TestReconcileChecks:
    def test_flags_all_three_violations(self):
        spans = [
            {"name": "round", "index": 0, "parent": -1, "depth": 0,
             "host_syncs": 2, "bytes_moved": 100, "dispatches": 1},
            # child claims more syncs than its parent: double counting
            {"name": "train.local", "index": 1, "parent": 0, "depth": 1,
             "host_syncs": 5, "bytes_moved": 0, "dispatches": 0},
        ]
        run = {"host_syncs": 3, "bytes_moved": 100, "dispatches": 1}
        diffs = reconcile_records(
            run, spans,
            [{"uplink": [{"modality": "acc", "bytes": 80.0}]}],
            {"ledger_bytes": 100.0,
             "ledger_by_modality": {"acc": 100.0}})
        text = "\n".join(diffs)
        assert "root spans sum to 2" in text        # totals mismatch
        assert "double counting" in text            # child > parent
        assert "uplink bytes" in text               # ledger mismatch

    def test_clean_records_pass(self):
        spans = [
            {"name": "round", "index": 0, "parent": -1, "depth": 0,
             "host_syncs": 3, "bytes_moved": 100, "dispatches": 1},
            {"name": "train.local", "index": 1, "parent": 0, "depth": 1,
             "host_syncs": 2, "bytes_moved": 0, "dispatches": 1},
        ]
        run = {"host_syncs": 3, "bytes_moved": 100, "dispatches": 1}
        assert reconcile_records(
            run, spans,
            [{"uplink": [{"modality": "acc", "bytes": 60.0},
                         {"modality": "gyr", "bytes": 40.0}]}],
            {"ledger_bytes": 100.0,
             "ledger_by_modality": {"acc": 60.0, "gyr": 40.0}}) == []


class TestTimer:
    def test_interleaved_min_order_and_prepare(self):
        order = []

        def mk(label):
            def thunk(*a):
                order.append((label, a))
            return thunk

        best = interleaved_min(
            {"a": mk("a"), "b": mk("b")},
            prepare={"a": lambda: "payload"}, reps=3)
        assert set(best) == {"a", "b"}
        assert all(v >= 0.0 for v in best.values())
        # strict interleave: every rep runs every label once, in order
        assert [lbl for lbl, _ in order] == ["a", "b"] * 3
        # prepare's return feeds the thunk; bare labels get no argument
        assert all(a == ("payload",) for lbl, a in order if lbl == "a")
        assert all(a == () for lbl, a in order if lbl == "b")

    def test_phase_table_aggregates_depth(self):
        tracer = telemetry.Tracer()
        with telemetry.install(tracer):
            for _ in range(2):
                with telemetry.span("round"):
                    with telemetry.span("train.local"):
                        hostsync.record_dispatch(3)
                    with telemetry.span("eval"):
                        hostsync.fetch_scalar(0.0)
        table = telemetry.tracer_phase_table(tracer)
        assert table["train.local"]["count"] == 2
        assert table["train.local"]["dispatches"] == 6
        assert table["eval"]["host_syncs"] == 2
        assert "round" not in table                 # depth-0 spans excluded


@pytest.mark.lint
class TestLintTier:
    def test_telemetry_audit_clean(self):
        from repro.analysis.telemetry_check import check
        assert check("engine", "fused") == []
        assert check("async", "reference", "reference") == []

    def test_lint_matrix_includes_loop_on_full_target_set(self):
        from repro.analysis.programs import BACKENDS as PROGRAM_BACKENDS
        from repro.analysis import telemetry_check

        audited = []

        def fake_check_all(backends, comm_impls, *a, **kw):
            audited.append((tuple(backends), tuple(comm_impls)))
            return []

        orig = telemetry_check.check_all
        telemetry_check.check_all = fake_check_all
        try:
            targets = [(b, ci) for b in PROGRAM_BACKENDS
                       for ci in ("fused", "reference")]
            telemetry_check.lint_telemetry(targets)
        finally:
            telemetry_check.check_all = orig
        (backends, comm_impls), = audited
        assert backends[0] == "loop"
        assert set(backends) == {"loop"} | set(PROGRAM_BACKENDS)
        assert comm_impls == ("fused", "reference")

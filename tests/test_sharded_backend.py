"""Parity oracles for the client-axis sharded population backend.

Three layers of pinning (see ``repro.core.sharded``):

1. **Engine oracle (1×1 mesh, inline).** On a one-device mesh the
   shard-major layout degenerates to the engine's bucket order, so
   ``backend="sharded"`` must match ``backend="engine"`` exactly on
   uploads/ledger/selection outcomes and ≤1e-5 on encoders — the same
   contract every backend pair in this repo pins.
2. **Mesh-size invariance (8 devices, ``multidevice`` tier).** The same
   federation run on a 1-shard and an 8-shard mesh must agree: exact
   uploads/ledger/selection, ≤1e-5 encoders at full precision. At 8-bit
   uplink the tolerance is one quantization step: cross-mesh training
   drift is ~1 ulp (vmap width changes XLA's fp32 codegen), but a 1-ulp
   shift can flip a row's nearest code, moving its dequantized value by
   range/(2^8−1) ≈ 4e-3 — amplified drift, not an aggregation bug.
3. **Masked-psum properties.** Eq. 21's psum is invariant to the
   client→shard assignment (hypothesis property + a seeded sweep for
   environments without hypothesis), and an all-empty shard — or an
   entirely empty weight vector — contributes exact zeros, never NaN.
"""
import numpy as np
import pytest

from repro.core.rounds import MFedMCConfig, build_federation, run_federation

TOL = 1e-5
# one 8-bit quantization step of the widest encoder tensor (see layer 2)
QTOL8 = 5e-3


def _cfg(**kw):
    base = dict(rounds=2, local_epochs=1, batch_size=8, seed=0,
                modality_strategy="priority", client_strategy="low_loss",
                background_size=12, eval_size=12, gamma=1)
    base.update(kw)
    return MFedMCConfig(**base)


def _run_built(backend, clients, spec, cfg):
    server = {}
    hist = run_federation(clients, spec, cfg, server_encoders=server,
                          backend=backend)
    return server, hist, clients


def _run_ucihar(backend, mesh=None, **cfg_kw):
    cfg = _cfg(mesh_clients=mesh, **cfg_kw)
    clients, spec = build_federation("ucihar", "iid", cfg=cfg, seed=0,
                                     samples_per_client=24)
    return _run_built(backend, clients, spec, cfg)


def _run_synth(backend, K, mesh=None, n=20, **cfg_kw):
    from benchmarks.bench_batched_round import synthetic_federation
    cfg = _cfg(mesh_clients=mesh, **cfg_kw)
    clients, spec = synthetic_federation(K, n=n, seed=0)
    return _run_built(backend, clients, spec, cfg)


def _assert_records_match(h_a, h_b):
    assert len(h_a.records) == len(h_b.records)
    for r_a, r_b in zip(h_a.records, h_b.records):
        assert r_b.uploads == r_a.uploads, f"round {r_a.round}"
        assert r_b.comm_mb == r_a.comm_mb, f"round {r_a.round}"
        assert r_b.shapley.keys() == r_a.shapley.keys()


def _assert_server_match(se_a, se_b, atol=TOL):
    assert set(se_a) == set(se_b)
    for m in se_a:
        for k in se_a[m]:
            np.testing.assert_allclose(np.asarray(se_b[m][k]),
                                       np.asarray(se_a[m][k]),
                                       atol=atol, rtol=0,
                                       err_msg=f"{m}/{k}")


def _assert_losses_match(cl_a, cl_b, atol=TOL):
    for a, b in zip(cl_a, cl_b):
        for m in a.modality_names:
            assert b.losses[m] == pytest.approx(a.losses[m], abs=atol), \
                (a.client_id, m)


# ---------------------------------------------------------------------------
# layer 1: sharded-on-1×1-mesh ≡ engine (inline, single device)
# ---------------------------------------------------------------------------

class TestShardedEngineOracle:
    def test_1x1_mesh_matches_engine(self):
        se_e, h_e, cl_e = _run_ucihar("engine")
        se_s, h_s, cl_s = _run_ucihar("sharded", mesh=1)
        _assert_records_match(h_e, h_s)
        _assert_server_match(se_e, se_s)
        _assert_losses_match(cl_e, cl_s)
        np.testing.assert_allclose(h_s.accuracies, h_e.accuracies,
                                   atol=1e-6)

    def test_1x1_mesh_matches_engine_quantized(self):
        se_e, h_e, _ = _run_ucihar("engine", quantize_bits=8)
        se_s, h_s, _ = _run_ucihar("sharded", mesh=1, quantize_bits=8)
        _assert_records_match(h_e, h_s)
        _assert_server_match(se_e, se_s)

    def test_1x1_mesh_matches_engine_ragged(self):
        # three modality sets + skewed sample counts: uneven buckets
        from benchmarks.bench_batched_round import ragged_federation
        cfg = _cfg(rounds=1)
        runs = []
        for backend, mesh in (("engine", None), ("sharded", 1)):
            c = _cfg(rounds=1, mesh_clients=mesh)
            clients, spec = ragged_federation(9, n=20, seed=0)
            runs.append(_run_built(backend, clients, spec, c))
        (se_e, h_e, cl_e), (se_s, h_s, cl_s) = runs
        del cfg
        _assert_records_match(h_e, h_s)
        _assert_server_match(se_e, se_s)
        _assert_losses_match(cl_e, cl_s)

    def test_selection_program_matches_engine(self):
        # the shard_map'ped Eqs. 12–16 program is outcome-identical to the
        # engine's, row for row, on a random candidate block
        from repro.core.selection_engine import (lexicographic_rank,
                                                 select_modalities_arrays)
        from repro.core.sharded import client_mesh, select_modalities_sharded
        rng = np.random.default_rng(3)
        n, M = 13, 4
        phi = rng.standard_normal((n, M))
        sizes = rng.uniform(1e3, 1e6, (n, M))
        recency = rng.integers(0, 7, (n, M)).astype(float)
        presence = rng.random((n, M)) < 0.8
        presence[:, 0] = True                       # no empty rows
        rank = lexicographic_rank([f"m{j}" for j in range(M)])
        ref = select_modalities_arrays(phi, sizes, recency, presence, rank,
                                       t=5, gamma=2, alpha_s=1 / 3,
                                       alpha_c=1 / 3, alpha_r=1 / 3)
        dec = select_modalities_sharded(
            phi, sizes, recency, presence, rank,
            np.zeros(n, np.int64), client_mesh(1), t=5, gamma=2,
            alpha_s=1 / 3, alpha_c=1 / 3, alpha_r=1 / 3)
        np.testing.assert_array_equal(dec.mask, ref.mask)
        np.testing.assert_array_equal(dec.order, ref.order)
        np.testing.assert_array_equal(dec.counts, ref.counts)

    def test_config_validation(self):
        clients, spec = build_federation("ucihar", "iid", cfg=_cfg(),
                                         seed=0, samples_per_client=16)
        with pytest.raises(ValueError, match="mesh_clients"):
            run_federation(clients, spec, _cfg(mesh_clients=1),
                           backend="engine")
        with pytest.raises(ValueError, match="error_feedback"):
            run_federation(clients, spec,
                           _cfg(quantize_bits=8, error_feedback=True),
                           backend="sharded")
        with pytest.raises(ValueError, match="devices"):
            run_federation(clients, spec, _cfg(mesh_clients=10 ** 6),
                           backend="sharded")


# ---------------------------------------------------------------------------
# layer 2: mesh-size invariance (forced 8 devices)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
class TestMeshSizeInvariance:
    def test_mesh8_matches_mesh1_across_k(self):
        # K=8: one client per shard; K=24: uneven ucihar-style padding
        # (3 clients on every shard for enc pairs, but selection pads);
        # K=32: even 4/shard. One test so the compile caches amortize.
        for K in (8, 24, 32):
            se_1, h_1, cl_1 = _run_synth("sharded", K, mesh=1)
            se_8, h_8, cl_8 = _run_synth("sharded", K, mesh=8)
            _assert_records_match(h_1, h_8)
            _assert_server_match(se_1, se_8)
            _assert_losses_match(cl_1, cl_8)
            np.testing.assert_allclose(h_8.accuracies, h_1.accuracies,
                                       atol=1e-6)

    def test_mesh8_matches_mesh1_quantized(self):
        # 8-bit uplink: exact uploads/ledger, one-quant-step encoders
        se_1, h_1, _ = _run_synth("sharded", 24, mesh=1, quantize_bits=8)
        se_8, h_8, _ = _run_synth("sharded", 24, mesh=8, quantize_bits=8)
        _assert_records_match(h_1, h_8)
        _assert_server_match(se_1, se_8, atol=QTOL8)

    def test_mesh8_matches_engine(self):
        # transitive closure spelled out: 8-shard sharded vs the engine
        se_e, h_e, cl_e = _run_synth("engine", 16)
        se_8, h_8, cl_8 = _run_synth("sharded", 16, mesh=8)
        _assert_records_match(h_e, h_8)
        _assert_server_match(se_e, se_8)
        _assert_losses_match(cl_e, cl_8)


# ---------------------------------------------------------------------------
# layer 3: masked-psum properties (forced 8 devices)
# ---------------------------------------------------------------------------

def _psum_aggregate(values, weights, assignment, n_shards):
    """Run the sharded Eq. 21 program under an explicit client→shard
    assignment; returns the [leaf]-shaped aggregate as numpy."""
    import jax
    from repro.core.sharded import _aggregate_program
    from repro.sharding.partition import client_mesh, client_spec, shard_slots
    mesh = client_mesh(n_shards)
    slots, size = shard_slots(assignment, n_shards)
    stacked = np.zeros((size,) + values.shape[1:], np.float32)
    w = np.zeros(size, np.float32)
    stacked[np.asarray(slots)] = values
    w[np.asarray(slots)] = weights
    sharding = jax.sharding.NamedSharding(mesh, client_spec())
    out = _aggregate_program(mesh)(
        {"p": jax.device_put(stacked, sharding)},
        jax.device_put(w, sharding))
    return np.asarray(out["p"])


def _reference_aggregate(values, weights):
    w = np.asarray(weights, np.float32)
    w = w / max(w.sum(), 1e-12)
    return np.einsum("k,k...->...", w, np.asarray(values, np.float32))


@pytest.mark.multidevice
class TestMaskedPsumProperties:
    def test_assignment_invariance_seeded_sweep(self):
        # runs everywhere; the hypothesis variant below widens the search
        rng = np.random.default_rng(0)
        for trial in range(25):
            K = int(rng.integers(1, 20))
            values = rng.standard_normal((K, 3, 4)).astype(np.float32)
            weights = rng.choice([0.0, 1.0, 7.0, 40.0], size=K)
            ref = _reference_aggregate(values, weights)
            a = rng.integers(0, 8, K)
            b = rng.integers(0, 8, K)
            agg_a = _psum_aggregate(values, weights, a, 8)
            agg_b = _psum_aggregate(values, weights, b, 8)
            np.testing.assert_allclose(agg_a, agg_b, atol=TOL, rtol=0)
            np.testing.assert_allclose(agg_a, ref, atol=TOL, rtol=0)

    def test_assignment_invariance_hypothesis(self):
        hyp = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=30, deadline=None)
        @given(data=st.data())
        def prop(data):
            K = data.draw(st.integers(1, 16), label="K")
            seed = data.draw(st.integers(0, 2 ** 31 - 1), label="seed")
            rng = np.random.default_rng(seed)
            values = rng.standard_normal((K, 2, 3)).astype(np.float32)
            weights = np.array(
                data.draw(st.lists(st.sampled_from([0.0, 1.0, 5.0, 64.0]),
                                   min_size=K, max_size=K),
                          label="weights"), np.float32)
            a = np.array(data.draw(st.lists(st.integers(0, 7), min_size=K,
                                            max_size=K), label="a"))
            b = np.array(data.draw(st.lists(st.integers(0, 7), min_size=K,
                                            max_size=K), label="b"))
            agg_a = _psum_aggregate(values, weights, a, 8)
            agg_b = _psum_aggregate(values, weights, b, 8)
            np.testing.assert_allclose(agg_a, agg_b, atol=TOL, rtol=0)
            np.testing.assert_allclose(
                agg_a, _reference_aggregate(values, weights),
                atol=TOL, rtol=0)

        prop()

    def test_empty_shards_contribute_zero_not_nan(self):
        # all clients on shards {0, 1}: shards 2..7 reduce over pure
        # padding and must contribute exact zero terms
        rng = np.random.default_rng(1)
        values = rng.standard_normal((6, 5)).astype(np.float32)
        weights = np.array([3.0, 0.0, 1.0, 2.0, 0.0, 4.0], np.float32)
        agg = _psum_aggregate(values, weights, [0, 0, 0, 1, 1, 1], 8)
        assert np.isfinite(agg).all()
        np.testing.assert_allclose(agg, _reference_aggregate(values, weights),
                                   atol=TOL, rtol=0)

    def test_all_zero_weights_yield_zeros_not_nan(self):
        # nobody uploaded: the max(Σw, 1e-12) guard must hold under psum
        values = np.ones((4, 3), np.float32)
        agg = _psum_aggregate(values, np.zeros(4, np.float32),
                              [0, 2, 4, 6], 8)
        assert np.isfinite(agg).all()
        np.testing.assert_array_equal(agg, np.zeros(3, np.float32))


# ---------------------------------------------------------------------------
# layer 3b: empty shard end-to-end (trace-driven, forced 8 devices)
# ---------------------------------------------------------------------------

class _FixedTrace:
    """Deterministic §4.9 availability: the same [K] mask every round."""

    def __init__(self, mask):
        self.mask = np.asarray(mask, bool)

    def step(self, rng, k):
        assert k == len(self.mask)
        return self.mask.copy()


@pytest.mark.multidevice
class TestEmptyShardRounds:
    def test_unavailable_shard_round_end_to_end(self, monkeypatch):
        # K=16 over D=8 (round-robin: shard d holds rows {d, d+8});
        # shard 3's clients never report, so every round its block enters
        # the psum with all-zero weight — results must stay finite and
        # match the engine run under the same trace
        K = 16
        mask = np.ones(K, bool)
        mask[[3, 11]] = False
        monkeypatch.setattr("repro.core.rounds.resolve_trace",
                            lambda cfg: _FixedTrace(mask))
        se_e, h_e, cl_e = _run_synth("engine", K)
        se_8, h_8, cl_8 = _run_synth("sharded", K, mesh=8)
        _assert_records_match(h_e, h_8)
        assert all(cid not in (3, 11) for r in h_8.records
                   for cid, _ in r.uploads)
        _assert_server_match(se_e, se_8)
        for m in se_8:
            for k in se_8[m]:
                assert np.isfinite(np.asarray(se_8[m][k])).all()

    def test_nobody_available_round(self, monkeypatch):
        # an entirely empty round: explicit empty-upload record, no NaNs
        monkeypatch.setattr(
            "repro.core.rounds.resolve_trace",
            lambda cfg: _FixedTrace(np.zeros(8, bool)))
        se, hist, cl = _run_synth("sharded", 8, mesh=8,
                                  rounds=1)
        assert hist.records[0].uploads == []
        assert hist.records[0].comm_mb == 0.0
        assert se == {}

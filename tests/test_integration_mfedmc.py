"""Integration: full MFedMC rounds on the synthetic federations — the
paper's qualitative claims at miniature scale."""
import dataclasses

import numpy as np
import pytest

from repro.core import MFedMCConfig
from repro.core.baselines import run_baseline
from repro.core.rounds import build_federation, run_federation, run_mfedmc

FAST = dict(rounds=3, local_epochs=1, background_size=16, eval_size=16,
            seed=0)


@pytest.fixture(scope="module")
def actionsense_run():
    cfg = MFedMCConfig(**FAST)
    return run_mfedmc("actionsense", "natural", cfg, samples_per_client=32), \
        cfg


class TestMFedMCRounds:
    def test_learns(self, actionsense_run):
        h, _ = actionsense_run
        assert h.records[-1].accuracy > h.records[0].accuracy - 0.05
        assert h.records[-1].accuracy > 0.15      # well above 1/20 chance

    def test_comm_accounting_monotone(self, actionsense_run):
        h, _ = actionsense_run
        mb = h.comm_mb
        assert np.all(np.diff(mb) >= 0)
        assert mb[-1] > 0

    def test_gamma_delta_bound_uploads(self, actionsense_run):
        h, cfg = actionsense_run
        k = 9
        cap = int(np.ceil(cfg.delta * k)) * cfg.gamma
        for r in h.records:
            assert len(r.uploads) <= cap

    def test_shapley_recorded(self, actionsense_run):
        h, _ = actionsense_run
        assert h.records[0].shapley          # non-empty dict
        for v in h.records[0].shapley.values():
            assert np.isfinite(v)


class TestSelectionReducesComm:
    def test_less_comm_than_upload_all(self):
        cfg = MFedMCConfig(**FAST)
        sel = run_mfedmc("ucihar", "iid", cfg, samples_per_client=24)
        all_cfg = dataclasses.replace(cfg, modality_strategy="all",
                                      client_strategy="all")
        full = run_mfedmc("ucihar", "iid", all_cfg, samples_per_client=24)
        # γ/M̄·δ = (1/2)·0.2 = 0.1 -> ~10× reduction
        assert sel.comm_mb[-1] < 0.25 * full.comm_mb[-1]

    def test_quantization_shrinks_bytes(self):
        cfg = MFedMCConfig(**FAST)
        f32 = run_mfedmc("ucihar", "iid", cfg, samples_per_client=24)
        q8 = run_mfedmc("ucihar", "iid",
                        dataclasses.replace(cfg, quantize_bits=8),
                        samples_per_client=24)
        assert q8.comm_mb[-1] == pytest.approx(f32.comm_mb[-1] / 4, rel=0.01)


class TestBaselinesProtocol:
    @pytest.mark.parametrize("name", ["flfd", "flash"])
    def test_runs_and_accounts(self, name):
        cfg = MFedMCConfig(rounds=2, local_epochs=1, seed=0)
        h = run_baseline(name, "ucihar", "iid", cfg, samples_per_client=16)
        assert len(h.records) == 2
        assert h.comm_mb[-1] > 0
        assert np.isfinite(h.final_accuracy())

    def test_no_available_clients_records_empty_round(self):
        # regression: baselines used to silently force client 0 into the
        # round (`or [0]`) when nobody was available; both engines now
        # record an explicit empty-upload round — no training, no bytes
        cfg = dataclasses.replace(MFedMCConfig(rounds=2, local_epochs=1,
                                               seed=0), availability=0.0)
        h = run_baseline("flash", "ucihar", "iid", cfg,
                         samples_per_client=16)
        assert len(h.records) == 2
        assert h.comm_mb[-1] == 0.0
        assert np.isfinite(h.final_accuracy())
        h2 = run_mfedmc("ucihar", "iid", cfg, samples_per_client=16)
        assert h2.comm_mb[-1] == 0.0
        assert all(r.uploads == [] for r in h2.records)

    def test_baseline_markov_churn_trace(self):
        cfg = dataclasses.replace(
            MFedMCConfig(rounds=3, local_epochs=1, seed=0),
            availability_trace="markov:0.4,0.4")
        h = run_baseline("flash", "ucihar", "iid", cfg,
                         samples_per_client=16)
        assert len(h.records) == 3
        assert np.isfinite(h.final_accuracy())

    def test_mfedmc_much_cheaper_than_holistic(self):
        cfg = MFedMCConfig(**FAST)
        ours = run_mfedmc("actionsense", "natural", cfg,
                          samples_per_client=24)
        base = run_baseline("mmfed", "actionsense", "natural",
                            MFedMCConfig(rounds=3, local_epochs=1, seed=0),
                            samples_per_client=24)
        # the paper's headline: >20× comm reduction
        assert base.comm_mb[-1] / ours.comm_mb[-1] > 10


class TestScenarios:
    def test_modality_noniid(self):
        cfg = MFedMCConfig(**FAST)
        h = run_mfedmc("actionsense", "modality_noniid", cfg,
                       missing_rate=0.5, samples_per_client=24)
        assert np.isfinite(h.final_accuracy())

    def test_availability(self):
        cfg = dataclasses.replace(MFedMCConfig(**FAST), availability=0.5)
        h = run_mfedmc("ucihar", "iid", cfg, samples_per_client=16)
        assert len(h.records) == 3

    def test_heterogeneous_network_tiers(self):
        allowed = {k: {"eye", "emg_left", "emg_right"} for k in range(3, 9)}
        cfg = dataclasses.replace(MFedMCConfig(**FAST),
                                  allowed_modalities=allowed)
        h = run_mfedmc("actionsense", "natural", cfg, samples_per_client=24)
        # restricted clients never upload heavy modalities
        for r in h.records:
            for cid, m in r.uploads:
                if cid >= 3:
                    assert m in allowed[cid]

    def test_comm_budget_stops_run(self):
        cfg = dataclasses.replace(MFedMCConfig(**FAST), rounds=50,
                                  comm_budget_mb=0.5)
        h = run_mfedmc("ucihar", "iid", cfg, samples_per_client=16)
        assert len(h.records) < 50


class TestFusionPersonalization:
    def test_fusion_stays_local(self):
        """Fusion modules must differ across clients after federation
        (they are never aggregated)."""
        cfg = MFedMCConfig(**FAST)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg,
                                         samples_per_client=24, seed=0)
        run_federation(clients, spec, cfg)
        w0 = np.asarray(clients[0].fusion["w1"])
        w1 = np.asarray(clients[1].fusion["w1"])
        assert not np.allclose(w0, w1)

    def test_global_encoders_deployed(self):
        """After a round, clients that share a modality which was aggregated
        hold identical encoder weights (download + deploy)."""
        cfg = MFedMCConfig(**FAST)
        clients, spec = build_federation("ucihar", "iid", cfg=cfg,
                                         samples_per_client=24, seed=0)
        h = run_federation(clients, spec, cfg)
        uploaded = {m for r in h.records[-1:] for _, m in r.uploads}
        # clients train after deploy (stage 2 touches only fusion), so
        # encoders for the last round's uploaded modalities match exactly
        for m in uploaded:
            w_ref = None
            for c in clients:
                if m in c.encoders:
                    w = np.asarray(c.encoders[m]["w_fc"])
                    if w_ref is None:
                        w_ref = w
                    else:
                        np.testing.assert_allclose(w, w_ref, rtol=1e-6)

"""Quickstart: MFedMC in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py [--rounds 5]

Builds the ActionSense-shaped federation (9 clients, 6 modalities, subjects
6–9 missing tactile), runs joint modality+client selection for a few rounds,
and prints accuracy vs cumulative uplink megabytes.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--dataset", default="actionsense")
    ap.add_argument("--scenario", default="natural")
    ap.add_argument("--backend", default="loop",
                    choices=["loop", "batched", "engine", "async",
                             "sharded"],
                    help="loop: per-client reference; batched: vmapped "
                         "local learning; engine: device-resident "
                         "population + selection engine; async: "
                         "event-driven virtual-time runtime (compute/"
                         "uplink models, buffered aggregation); sharded: "
                         "population split over a client mesh, Eq. 21 as "
                         "a masked psum")
    ap.add_argument("--mesh-clients", type=int, default=0,
                    help="sharded: devices on the client mesh (0 = every "
                         "visible device; >1 forces that many host "
                         "devices)")
    ap.add_argument("--availability-trace", default=None,
                    help="async churn, e.g. 'bernoulli:0.5' or "
                         "'markov:0.2,0.5'")
    ap.add_argument("--deadline", type=float, default=None,
                    help="async per-cycle reporting deadline in virtual "
                         "seconds (stragglers past it are dropped)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async: aggregate every N client arrivals")
    ap.add_argument("--staleness-discount", type=float, default=1.0,
                    help="async buffered-flush weight *= d**staleness")
    ap.add_argument("--quantize-bits", type=int, default=32,
                    help="§4.10 uplink precision (1-16; 32 = full)")
    ap.add_argument("--comm-impl", default="fused",
                    choices=["fused", "reference"],
                    help="quantized-upload hot path: fused = one-pass "
                         "quantize+pack and reduce-from-packed-codes "
                         "(repro.kernels.comm); reference = historical "
                         "quantize_population + aggregate_quantized")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write a per-phase trace of the run to DIR: "
                         "trace.json (open in ui.perfetto.dev), "
                         "spans.jsonl, metrics.jsonl; inspect with "
                         "`python -m repro.telemetry.report DIR`")
    args = ap.parse_args()

    if args.mesh_clients > 1:
        # must land before jax initializes (first repro import below)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count="
            f"{args.mesh_clients}").strip()
    from repro.core import MFedMCConfig, run_mfedmc

    cfg = MFedMCConfig(
        rounds=args.rounds,
        local_epochs=2,            # paper: 5; reduced for a fast demo
        gamma=1, delta=0.2,        # paper's headline config
        alpha_s=1 / 3, alpha_c=1 / 3, alpha_r=1 / 3,
        background_size=32, eval_size=32,
        availability_trace=args.availability_trace,
        deadline_s=args.deadline,
        buffer_size=args.buffer_size,
        staleness_discount=args.staleness_discount,
        mesh_clients=(args.mesh_clients or None
                      if args.backend == "sharded" else None),
        quantize_bits=args.quantize_bits,
        comm_impl=args.comm_impl,
        seed=0,
    )
    if args.trace:
        from repro import telemetry
        with telemetry.tracing(args.trace):
            history = run_mfedmc(args.dataset, args.scenario, cfg,
                                 verbose=True, backend=args.backend,
                                 samples_per_client=48)
        print(f"\ntrace written to {args.trace}/ — load "
              f"{args.trace}/trace.json in https://ui.perfetto.dev or run "
              f"`python -m repro.telemetry.report {args.trace}`")
    else:
        history = run_mfedmc(args.dataset, args.scenario, cfg, verbose=True,
                             backend=args.backend, samples_per_client=48)

    print("\nround  accuracy  cumulative-MB")
    for r in history.records:
        print(f"{r.round:5d}  {r.accuracy:8.4f}  {r.comm_mb:12.3f}")
    print(f"\nfinal accuracy {history.final_accuracy():.4f} after "
          f"{history.comm_mb[-1]:.2f} MB of uplink "
          f"(vs ~10 MB/round for upload-everything baselines)")
    if args.backend == "async":
        print(f"simulated makespan {history.makespan_s:.1f}s on the "
              f"virtual clock (per-client compute + uplink time models)")


if __name__ == "__main__":
    main()

"""Fig. 11 scenario: MFedMC composed with 4/8-bit uplink quantization.

    PYTHONPATH=src python examples/quantized_uplink.py [--rounds 8] \
        [--backend batched] [--error-feedback]

Runs the same federation at 32/16/8/4-bit encoder uploads and reports
accuracy + exact wire bytes (bit-packed codes + per-tensor scale/zero
metadata) — the decoupled local fusion module absorbs quantization error
that would propagate through a holistic model's task head, and
``--error-feedback`` adds client-held residual accumulators so the lowest
precisions stay unbiased across rounds.
"""
import argparse
import dataclasses

from repro.core import MFedMCConfig
from repro.core.rounds import run_mfedmc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--backend", default="loop",
                    choices=("loop", "batched"))
    ap.add_argument("--error-feedback", action="store_true",
                    help="client-held §4.10 residual accumulators")
    args = ap.parse_args()

    base = MFedMCConfig(rounds=args.rounds, local_epochs=2,
                        background_size=32, eval_size=32, seed=0,
                        error_feedback=args.error_feedback)
    print(f"{'bits':>5} {'final-acc':>10} {'uplink-MB':>10}")
    for bits in (32, 16, 8, 4):
        cfg = dataclasses.replace(base, quantize_bits=bits)
        h = run_mfedmc("ucihar", "iid", cfg, samples_per_client=48,
                       backend=args.backend)
        print(f"{bits:>5} {h.final_accuracy():>10.4f} {h.comm_mb[-1]:>10.3f}")


if __name__ == "__main__":
    main()

"""Fig. 11 scenario: MFedMC composed with 4/8-bit uplink quantization.

    PYTHONPATH=src python examples/quantized_uplink.py [--rounds 8]

Runs the same federation at 32/8/4-bit encoder uploads and reports
accuracy + bytes — the decoupled local fusion module absorbs quantization
error that would propagate through a holistic model's task head.
"""
import argparse
import dataclasses

from repro.core import MFedMCConfig
from repro.core.rounds import run_mfedmc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    base = MFedMCConfig(rounds=args.rounds, local_epochs=2,
                        background_size=32, eval_size=32, seed=0)
    print(f"{'bits':>5} {'final-acc':>10} {'uplink-MB':>10}")
    for bits in (32, 8, 4):
        cfg = dataclasses.replace(base, quantize_bits=bits)
        h = run_mfedmc("ucihar", "iid", cfg, samples_per_client=48)
        print(f"{bits:>5} {h.final_accuracy():>10.4f} {h.comm_mb[-1]:>10.3f}")


if __name__ == "__main__":
    main()

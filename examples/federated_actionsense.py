"""End-to-end driver: full MFedMC vs its ablations vs a SOTA baseline on the
ActionSense federation — the paper's Fig. 4 experiment, runnable end to end.

    PYTHONPATH=src python examples/federated_actionsense.py \
        [--rounds 30] [--budget-mb 5] [--fast]

Runs four systems under the same communication budget:
    1. MFedMC (priority modality selection + low-loss client selection)
    2. ablation: random modality selection ("w/o Modality Sel.")
    3. ablation: all clients upload ("w/o Client Sel.")
    4. FLASH (random submodel upload, SOTA baseline)
and prints the accuracy-vs-MB trajectory for each.
"""
import argparse
import dataclasses
import time

from repro.core import MFedMCConfig
from repro.core.baselines import run_baseline
from repro.core.rounds import run_mfedmc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--budget-mb", type=float, default=5.0)
    ap.add_argument("--fast", action="store_true",
                    help="2 local epochs, 32 samples/client")
    args = ap.parse_args()

    base = MFedMCConfig(
        rounds=args.rounds,
        local_epochs=2 if args.fast else 5,
        gamma=1, delta=0.2,
        comm_budget_mb=args.budget_mb,
        background_size=32, eval_size=32,
        seed=0,
    )
    n = 32 if args.fast else 96
    runs = {}

    t0 = time.time()
    runs["MFedMC"] = run_mfedmc("actionsense", "natural", base,
                                samples_per_client=n)
    runs["w/o ModalitySel"] = run_mfedmc(
        "actionsense", "natural",
        dataclasses.replace(base, modality_strategy="random"),
        samples_per_client=n)
    runs["w/o ClientSel"] = run_mfedmc(
        "actionsense", "natural",
        dataclasses.replace(base, client_strategy="all"),
        samples_per_client=n)
    runs["FLASH"] = run_baseline("flash", "actionsense", "natural", base,
                                 samples_per_client=n)

    print(f"\n=== accuracy under {args.budget_mb} MB budget "
          f"({time.time() - t0:.0f}s) ===")
    print(f"{'system':>16} {'best-acc':>9} {'MB-used':>8} {'rounds':>7}")
    for name, h in runs.items():
        print(f"{name:>16} {h.accuracy_under_budget(args.budget_mb):9.4f} "
              f"{h.comm_mb[-1]:8.2f} {len(h.records):7d}")

    print("\ntrajectories (round: acc @ MB):")
    for name, h in runs.items():
        pts = [f"{r.round}:{r.accuracy:.2f}@{r.comm_mb:.1f}"
               for r in h.records[:: max(len(h.records) // 6, 1)]]
        print(f"  {name:>16}: " + "  ".join(pts))


if __name__ == "__main__":
    main()

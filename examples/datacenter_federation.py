"""Datacenter mapping demo: MFedMC's round as a sharded mesh program.

    PYTHONPATH=src python examples/datacenter_federation.py \
        [--devices 8] [--hierarchical]

Stacks 30 UCI-HAR clients on the mesh 'data' axis, runs vmapped local SGD
epochs, and aggregates with the masked Eq.-21 all-reduce. The same round_fn
lowers on the 512-chip production mesh (see benchmarks/roofline_federated).
"""
import argparse
import sys

from repro.launch.fed_train import main as fed_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--hierarchical", action="store_true")
    args = ap.parse_args()
    argv = ["--dataset", "ucihar", "--rounds", str(args.rounds),
            "--devices", str(args.devices)]
    if args.hierarchical:
        argv.append("--hierarchical")
    return fed_main(argv)


if __name__ == "__main__":
    sys.exit(main())

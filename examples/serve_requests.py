"""Batched request serving: the ServeEngine scheduling waves of mixed-length
prompts through a zoo model.

    PYTHONPATH=src python examples/serve_requests.py --arch xlstm-125m \
        [--requests 6] [--max-new 12]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = init_params(jax.random.key(0), cfg)
    engine = ServeEngine(params, cfg, max_batch=4, cache_len=128, bucket=8)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(3, 20))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        engine.submit(prompt, max_new_tokens=args.max_new)

    done = engine.run()
    for r in done:
        print(f"req {r.request_id}: prompt[{len(r.prompt)}] -> "
              f"{len(r.output)} tokens: {r.output[:8]}…")
    for s in engine.stats:
        print(f"wave {s.wave}: batch={s.batch} bucket={s.prompt_len} "
              f"decoded={s.decoded} -> {s.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()

"""Fig. 8 scenario: heterogeneous uplink restrictions.

    PYTHONPATH=src python examples/heterogeneous_network.py [--rounds 10]

Clients 1–2 upload anything; clients 3–5 are limited to the four light
modalities; clients 6–9 to the three lightest. MFedMC routes around the
restriction (priority selection within the allowed set); end-to-end
baselines would lock out clients 3–9 entirely.
"""
import argparse

from repro.core import MFedMCConfig
from repro.core.rounds import run_mfedmc

LIGHT4 = {"eye", "emg_left", "emg_right", "body"}
LIGHT3 = {"eye", "emg_left", "emg_right"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()

    tiers = {0: None, 1: None}                       # unrestricted
    tiers.update({k: LIGHT4 for k in (2, 3, 4)})     # moderate
    tiers.update({k: LIGHT3 for k in (5, 6, 7, 8)})  # severe
    allowed = {k: v for k, v in tiers.items() if v is not None}

    cfg = MFedMCConfig(rounds=args.rounds, local_epochs=2,
                       allowed_modalities=allowed,
                       background_size=32, eval_size=32, seed=0)
    h = run_mfedmc("actionsense", "natural", cfg, verbose=True,
                   samples_per_client=48)
    print(f"\nfinal accuracy {h.final_accuracy():.4f} with every client "
          f"participating despite tiered uplink restrictions "
          f"({h.comm_mb[-1]:.2f} MB total)")


if __name__ == "__main__":
    main()

"""Serving example: batched greedy decoding for any zoo architecture.

    PYTHONPATH=src python examples/serve_decode.py --arch minicpm3-4b \
        [--tokens 32] [--batch 4]

Demonstrates the same serve_step the multi-pod dry-run lowers for
decode_32k — KV cache for attention archs, O(1) recurrent state for
SSM/hybrid archs, absorbed-latent cache for MLA.
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm3-4b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--tokens", str(args.tokens),
                "--batch", str(args.batch)])


if __name__ == "__main__":
    main()

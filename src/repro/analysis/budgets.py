"""Host-sync, wire-byte, and dispatch budgets, measured and pinned.

Each backend pays a deliberate, *fixed* number of host synchronisation
points per round (final-epoch losses, the three modality-selection
outputs, the client mask, evaluation), moves a deterministic number of
uplink bytes (pow-2-padded §4.10 payloads make the count independent of
which modalities win a round), and launches a deterministic number of
local-training programs (``hostsync.record_dispatch`` — the count the
fused trainer exists to collapse). Those numbers ARE the communication
contract this repo exists to reproduce — so they are measured from real
``run_federation`` rounds via :func:`repro.core.hostsync.measuring` and
pinned in ``budgets.json`` next to this module.

``python -m repro.analysis.lint --backend all`` re-measures and fails on
ANY drift, printing an expected-vs-measured diff per (backend, comm_impl);
``--bless`` regenerates the manifest after an intentional change (commit
the diff with the code that caused it — the manifest is the reviewable
record of every new host round-trip).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.framework import Finding

BUDGET_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "budgets.json")

# the measured federation: small enough to run in seconds, big enough to
# exercise selection (K=8, δ=0.2 → exactly 2 kept clients), quantized
# uplink, and both epochs; seeded so every measurement is a replay
_K, _N, _SEED, _ROUNDS, _BITS = 8, 24, 0, 2, 4


def mini_federation(k: int = _K, n: int = _N, seed: int = _SEED):
    """K homogeneous UCI-HAR-shaped clients (the benchmarks' synthetic
    federation, rebuilt here because ``src`` cannot import
    ``benchmarks``)."""
    from repro.core.client import make_client
    from repro.data.registry import get_dataset_spec
    from repro.data.synthetic import ClientData
    spec = get_dataset_spec("ucihar")
    rng = np.random.default_rng(seed)
    clients = []
    for c in range(k):
        labels = np.tile(np.arange(spec.num_classes),
                         n // spec.num_classes + 1)[:n]
        rng.shuffle(labels)
        mods = {
            m.name: rng.standard_normal(
                (n, *m.feature_shape(True))).astype(np.float32)
            for m in spec.modalities
        }
        data = ClientData(c, mods, labels.astype(np.int32),
                          spec.num_classes)
        clients.append(make_client(c, spec, data, seed=seed))
    return clients, spec


def federation_config(comm_impl: str, *, bits: int = _BITS,
                      rounds: int = _ROUNDS, train_impl: str = "fused"):
    from repro.core.rounds import MFedMCConfig
    return MFedMCConfig(rounds=rounds, local_epochs=1, batch_size=8,
                        seed=_SEED, gamma=1, delta=0.2,
                        modality_strategy="priority",
                        client_strategy="low_loss",
                        quantize_bits=bits, comm_impl=comm_impl,
                        train_impl=train_impl)


def measure(backend: str, comm_impl: str, *, bits: int = _BITS,
            rounds: int = _ROUNDS, train_impl: str = "fused") -> Dict:
    """Host syncs + uplink bytes + training dispatches of a seeded
    ``rounds``-round federation, scoped atomically via
    ``hostsync.measuring``."""
    from repro.core import hostsync
    from repro.core.rounds import run_federation
    clients, spec = mini_federation()
    cfg = federation_config(comm_impl, bits=bits, rounds=rounds,
                            train_impl=train_impl)
    with hostsync.measuring() as m:
        run_federation(clients, spec, cfg, backend=backend)
    return m.as_dict()


def measure_all(backends: Tuple[str, ...] = ("batched", "engine", "async",
                                             "sharded"),
                comm_impls: Tuple[str, ...] = ("fused", "reference")
                ) -> Dict:
    out: Dict = {
        "config": {"K": _K, "n": _N, "seed": _SEED, "rounds": _ROUNDS,
                   "bits": _BITS, "local_epochs": 1, "batch_size": 8,
                   "gamma": 1, "delta": 0.2, "train_impl": "fused"},
    }
    for b in backends:
        out[b] = {}
        for ci in comm_impls:
            out[b][ci] = measure(b, ci)
    return out


def load_budgets(path: str = BUDGET_PATH) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def bless(path: str = BUDGET_PATH, **kw) -> Dict:
    budgets = measure_all(**kw)
    with open(path, "w") as f:
        json.dump(budgets, f, indent=2, sort_keys=True)
        f.write("\n")
    return budgets


def compare(measured: Dict, pinned: Optional[Dict]) -> List[Finding]:
    """Exact comparison, one actionable finding per drifted number."""
    if pinned is None:
        return [Finding("budget", "<manifest>",
                        f"no pinned budget manifest at {BUDGET_PATH} — "
                        "run `python -m repro.analysis.lint --bless`")]
    findings = []
    for backend, impls in measured.items():
        if backend == "config":
            if impls != pinned.get("config"):
                findings.append(Finding(
                    "budget", "<manifest>",
                    "measurement config drifted from the manifest "
                    f"(expected {pinned.get('config')}, measured {impls})"
                    " — re-bless"))
            continue
        for ci, m in impls.items():
            p = (pinned.get(backend) or {}).get(ci)
            if p is None:
                findings.append(Finding(
                    "budget", f"{backend}/{ci}",
                    "no pinned budget for this (backend, comm_impl) — "
                    "re-bless the manifest"))
                continue
            for key, label, hint in (
                    ("host_syncs", "host syncs/run",
                     "a new device->host fetch entered the round path"),
                    ("bytes_moved", "uplink bytes/run",
                     "the wire payload changed"),
                    ("dispatches", "training dispatches/run",
                     "the local-training launch structure changed — a "
                     "fused round program split into extra launches, or "
                     "the prediction cache stopped deduplicating the "
                     "train-split forward")):
                if key not in p:
                    findings.append(Finding(
                        "budget", f"{backend}/{ci}",
                        f"{label}: no pinned value (manifest predates "
                        "this budget) — re-bless with `python -m "
                        "repro.analysis.lint --bless`"))
                    continue
                if m[key] != p[key]:
                    sign = "+" if m[key] > p[key] else ""
                    findings.append(Finding(
                        "budget", f"{backend}/{ci}",
                        f"{label}: expected {p[key]}, measured {m[key]} "
                        f"({sign}{m[key] - p[key]}) — {hint}; if "
                        "intentional, re-bless with `python -m "
                        "repro.analysis.lint --bless` and commit the "
                        "budgets.json diff"))
    return findings

"""Donation audit: resident param stacks must be consumed in place.

The fused round programs (``repro.kernels.train``, the sharded
``_fused_round_program``) exist to stop XLA double-buffering the
``[K, ...]`` resident population: their first argument is the live param
stack and is declared with ``donate_argnums`` so the compiled program
reuses the input allocation for the output. Losing that donation — a
refactor that re-jits without the flag, a wrapper that copies the stack
first — silently doubles the trainer's peak memory and nothing in the
test suite notices.

``repro.analysis.programs`` records the donation facts of each fused
spec straight from the real lowering (``lower(...).args_info``) in
``meta["donation"]``::

    {"resident": (0,),                # which args are resident stacks
     "donated":  (True, False, ...)}  # per-arg, from the compiler

This pass cross-checks the two: every declared-resident arg must have
actually been donated. Programs without donation meta (the per-epoch
reference chain, whose launches are transient by design) are out of
scope — the pass audits the contract only where the contract exists.
"""
from __future__ import annotations

from typing import List

from repro.analysis.framework import (TRAINING, AnalysisPass, Finding,
                                      ProgramSpec)


class DonationPass(AnalysisPass):
    name = "donation"
    roles = (TRAINING,)

    def run(self, prog: ProgramSpec) -> List[Finding]:
        don = prog.meta.get("donation")
        if not don:
            return []
        findings = []
        donated = don.get("donated", ())
        for idx in don.get("resident", ()):
            if idx >= len(donated) or not donated[idx]:
                findings.append(Finding(
                    self.name, prog.name,
                    f"resident param stack (arg {idx}) is NOT donated to "
                    "the round program — the lowering keeps input and "
                    "output alive together, double-buffering the whole "
                    "[K, ...] population every launch; declare it with "
                    "donate_argnums and treat the caller's stack as "
                    "consumed"))
        return findings

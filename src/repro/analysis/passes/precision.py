"""Precision lint: the f64 decision wall and the f32 aggregation domain.

PR 4's lesson, made permanent: the Eq. 13–19 selection math is compiled in
float64 at ``xla_backend_optimization_level=0`` because a single-ulp FMA
difference flips a priority ranking. A ``decision`` program therefore may
not contain ANY narrower float value — not an f32 intermediate, not a
silent ``convert_element_type`` downcast, not a weak-typed Python-scalar
promotion that sneaks a value through f32.

The aggregation/training programs are the opposite wall: an f32 domain.
An f64 value appearing there means x64 leaked out of the decision scope —
doubling uplink bytes and halving throughput silently — so the same pass
flags f64 avals and float→float downcasts (a downcast implies the wide
value existed) outside decision programs.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis.framework import (DECISION, AnalysisPass, Finding,
                                      ProgramSpec)
from repro.analysis.ir import iter_eqns


def _float_width(dt) -> int:
    return np.dtype(dt).itemsize if np.issubdtype(dt, np.floating) else 0


class PrecisionPass(AnalysisPass):
    name = "precision"
    roles = None

    def run(self, prog: ProgramSpec) -> List[Finding]:
        return (self._check_decision(prog) if prog.role == DECISION
                else self._check_f32_domain(prog))

    def _check_decision(self, prog: ProgramSpec) -> List[Finding]:
        findings = []
        for site in iter_eqns(prog.jaxpr):
            for v in site.eqn.outvars:
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is None:
                    continue
                w = _float_width(dt)
                if 0 < w < 8:
                    findings.append(Finding(
                        self.name, prog.name,
                        f"decision-path value is {np.dtype(dt).name}, "
                        f"not float64: {site.describe()} — Eq. 13–19 "
                        "rankings are ulp-sensitive; keep the whole "
                        "program under enable_x64"))
            if site.primitive == "convert_element_type":
                src = site.eqn.invars[0].aval.dtype
                dst = site.eqn.params.get("new_dtype", src)
                if _float_width(src) > _float_width(dst) > 0:
                    findings.append(Finding(
                        self.name, prog.name,
                        "silent float downcast "
                        f"{np.dtype(src).name}->{np.dtype(dst).name} in a "
                        f"decision program: {site.describe()}"))
        return findings

    def _check_f32_domain(self, prog: ProgramSpec) -> List[Finding]:
        findings = []
        for site in iter_eqns(prog.jaxpr):
            for v in site.eqn.outvars:
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is not None and _float_width(dt) > 4:
                    findings.append(Finding(
                        self.name, prog.name,
                        f"float64 leaked into an f32-domain program: "
                        f"{site.describe()} — x64 must stay inside the "
                        "decision scope"))
            if site.primitive == "convert_element_type":
                src = site.eqn.invars[0].aval.dtype
                dst = site.eqn.params.get("new_dtype", src)
                if _float_width(src) > _float_width(dst) > 0:
                    sev = "error" if _float_width(src) > 4 else "warning"
                    findings.append(Finding(
                        self.name, prog.name,
                        "silent float downcast "
                        f"{np.dtype(src).name}->{np.dtype(dst).name}: "
                        f"{site.describe()} — the wide value should never "
                        "have existed here", severity=sev))
        return findings

"""Host-transfer detector.

A round program is the unit the backends dispatch asynchronously; a
callback primitive inside one stalls the device every round, invisibly —
exactly the class of regression PR 7's hot-path work removed. This pass
fails any round program that traces a host callback (``pure_callback``,
``io_callback``, ``debug_callback``) or a host-pinning transfer
(``infeed``/``outfeed``, or a ``device_put`` onto a host memory space).

The *budgeted* host syncs — the per-round ``hostsync.fetch`` points every
backend legitimately pays (losses, selection outputs) — are a dynamic
property and are audited against ``analysis/budgets.json`` instead; this
pass guarantees the traced programs themselves stay callback-free.
"""
from __future__ import annotations

from typing import List

from repro.analysis.framework import AnalysisPass, Finding, ProgramSpec
from repro.analysis.ir import iter_eqns

# host-callback primitives across jax versions
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call",
})
# device<->host pinning / streaming
HOST_PIN_PRIMITIVES = frozenset({"infeed", "outfeed"})


def _device_put_targets_host(eqn) -> bool:
    # device_put params carry TransferToMemoryKind / sharding objects whose
    # repr names the memory space; "host" only appears for host targets
    devices = eqn.params.get("devices", ())
    return any("host" in repr(d).lower() for d in devices)


class HostTransferPass(AnalysisPass):
    name = "host-transfer"
    roles = None                     # every round program must be clean

    def run(self, prog: ProgramSpec) -> List[Finding]:
        findings = []
        for site in iter_eqns(prog.jaxpr):
            p = site.primitive
            if p in CALLBACK_PRIMITIVES:
                findings.append(Finding(
                    self.name, prog.name,
                    f"host callback in round program: {site.describe()} — "
                    "callbacks stall the dispatch stream every round; move "
                    "the host work to a budgeted hostsync.fetch point"))
            elif p in HOST_PIN_PRIMITIVES:
                findings.append(Finding(
                    self.name, prog.name,
                    f"host streaming op in round program: {site.describe()}"))
            elif p == "device_put" and _device_put_targets_host(site.eqn):
                findings.append(Finding(
                    self.name, prog.name,
                    "device_put onto a host memory space inside a round "
                    f"program: {site.describe()}"))
        return findings

"""Collective audit: what actually crosses shards, vs what should.

The sharded backend's §4.10 contract (see ``core/sharded.py``): only
[leaf]-shaped float32 partial sums cross shards — never the ``[rows, ...]``
stacked population. This pass enumerates every collective eqn
(``psum``/``all_gather``/...) inside the ``shard_map`` round programs,
sums the tensor payload bytes per device, and checks them against two
bounds derived from the same program:

- **partial bound** — Σ over the shard-local stacked invars of one row's
  bytes (``itemsize × prod(shape[1:])``): the exact payload of a correct
  Eq. 21 contraction. Tensor psum bytes above this means per-row data is
  crossing shards (the K× blowup the fused program exists to avoid).
- **raw ceiling** — ``rows × partial`` — the uncompressed
  ``quantized_uplink_roofline``/``raw_bytes`` ceiling, cross-checked
  against the roofline module itself when the program's ``meta`` carries
  a template (mesh-wide moved bytes must stay under it).

Scalar collectives (the ``wsum`` guard psum) ride free under a small
allowance. A collective-role program with NO collective eqn is also a
finding: an aggregate that never reduces across the mesh is aggregating
nothing.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis.framework import (COLLECTIVE, AnalysisPass, Finding,
                                      ProgramSpec)
from repro.analysis.ir import close, iter_eqns, sub_jaxprs

COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "psum2", "pmax", "pmin", "all_gather", "all_to_all",
    "reduce_scatter", "ppermute", "pbroadcast",
})
# scalar control traffic per program: the wsum guard psum plus one
# zero-offset term per leaf (the fused body's Σ wn·z scalars) — 512B
# covers a ~100-leaf encoder; anything past that is a real smell
_SCALAR_ALLOWANCE = 512


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape, dtype=np.int64)) * np.dtype(
        aval.dtype).itemsize


def _shard_map_invars(jaxpr):
    """The invars of the (first) shard_map sub-jaxpr — the shard-local
    view of the round inputs."""
    for site in iter_eqns(jaxpr):
        if site.primitive == "shard_map":
            for _, sub in sub_jaxprs(site.eqn):
                return list(close(sub).invars)
    return None


class CollectiveAuditPass(AnalysisPass):
    name = "collective-audit"
    roles = (COLLECTIVE,)

    def run(self, prog: ProgramSpec) -> List[Finding]:
        findings = []
        tensor_bytes = 0
        scalar_bytes = 0
        n_collectives = 0
        for site in iter_eqns(prog.jaxpr):
            if site.primitive not in COLLECTIVE_PRIMITIVES:
                continue
            n_collectives += 1
            for v in site.eqn.invars:
                b = _aval_bytes(v)
                size = int(np.prod(getattr(v.aval, "shape", ()),
                                   dtype=np.int64))
                if size <= 1:
                    scalar_bytes += b
                else:
                    tensor_bytes += b
        if n_collectives == 0:
            findings.append(Finding(
                self.name, prog.name,
                "collective-role program contains no collective eqn — it "
                "never reduces across the mesh"))
            return findings
        if scalar_bytes > _SCALAR_ALLOWANCE:
            findings.append(Finding(
                self.name, prog.name,
                f"scalar collective traffic {scalar_bytes}B exceeds the "
                f"{_SCALAR_ALLOWANCE}B control allowance", severity="warning"))

        invars = _shard_map_invars(prog.jaxpr)
        if invars is None:
            return findings
        partial = 0
        rows = 1
        for v in invars:
            aval = getattr(v, "aval", None)
            if aval is None or len(getattr(aval, "shape", ())) < 2:
                continue
            partial += int(np.prod(aval.shape[1:], dtype=np.int64)) * \
                np.dtype(aval.dtype).itemsize
            rows = max(rows, int(aval.shape[0]))
        if partial and tensor_bytes > partial:
            findings.append(Finding(
                self.name, prog.name,
                f"tensor psum payload {tensor_bytes}B exceeds the "
                f"[leaf]-shaped partial bound {partial}B — per-row data "
                "is crossing shards (only partial sums may; see "
                "core/sharded.py §Aggregation)"))
        raw_ceiling = rows * partial * max(1, prog.mesh_devices)
        mesh_moved = tensor_bytes * max(1, prog.mesh_devices)
        if partial and mesh_moved > raw_ceiling:
            findings.append(Finding(
                self.name, prog.name,
                f"mesh-wide collective bytes {mesh_moved}B exceed the "
                f"uncompressed roofline ceiling {raw_ceiling}B"))
        bits = int(prog.meta.get("bits", 32))
        if prog.meta.get("template") is not None and bits < 32:
            from repro.roofline.federated import quantized_uplink_roofline
            rl = quantized_uplink_roofline(
                prog.meta["template"], k=rows, bits=bits)
            if mesh_moved > rl["raw_bytes"] * max(1, prog.mesh_devices):
                findings.append(Finding(
                    self.name, prog.name,
                    f"collective bytes {mesh_moved}B exceed "
                    f"roofline raw_bytes "
                    f"{rl['raw_bytes'] * max(1, prog.mesh_devices)}B"))
        return findings

"""Mask-safety: every float division must have a provably-guarded divisor.

The codebase's masking discipline makes zero-denominators *routine*, not
exceptional: an all-padding batch has ``Σw == 0``, an absent modality has
``span == 0``, a constant row quantizes with ``hi - lo == 0``. The code
guards each with one of three idioms —

- ``jnp.maximum(x, eps)``      (quantizer scale, psum weight norm),
- ``jnp.maximum(Σw, 1.0)``     (masked CE means),
- ``jnp.where(ok, span, 1.0)`` (rownorm; lowers to ``select_n``) —

and this pass proves, per ``div``/``rsqrt`` eqn, that the divisor's
producer chain ends in such a guard (or a nonzero literal, or an
intrinsically-positive op like ``exp``). The tracer is interprocedural in
the ways the real programs need and no more: it follows a value INTO a
``pjit``/``cond``/``custom_vjp`` producer (to the sub-jaxpr eqn that
computed it) and OUT across a sub-jaxpr boundary to the caller's operand
(``ir.caller_operand`` — sound for call operands, loop consts, and scanned
xs; a scan *carry* is a different value each iteration, so the hop refuses
it and the div is flagged unless guarded locally). Anything unproven is a
finding — sound by default.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

import numpy as np

from repro.analysis.framework import AnalysisPass, Finding, ProgramSpec
from repro.analysis.ir import (callee_results, caller_operand, close,
                               is_literal, iter_eqns, literal_value,
                               producers)

# ops that carry their (first) operand's safety level unchanged
_PASS_THROUGH = frozenset({
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "expand_dims",
    "slice", "dynamic_slice", "copy", "rev", "reduce_precision",
    "convert_element_type", "stop_gradient", "pbroadcast", "sqrt",
    "integer_pow",
})
# ops whose output is strictly positive regardless of input
_ALWAYS_POSITIVE = frozenset({"exp", "logistic"})

# the safety lattice: what the tracer can prove about a value
_UNKNOWN, _NONZERO, _POSITIVE = 0, 1, 2
# call-like producers the tracer steps into
_ENTERABLE = frozenset({
    "pjit", "closed_call", "remat", "remat2", "checkpoint", "cond",
    "custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
    "custom_vjp_call_jaxpr",
})


class MaskSafetyPass(AnalysisPass):
    name = "mask-safety"
    roles = None

    def run(self, prog: ProgramSpec) -> List[Finding]:
        findings = []
        self._prods: Dict[int, Dict] = {}
        for site in iter_eqns(prog.jaxpr):
            if site.primitive not in ("div", "rsqrt"):
                continue
            den = (site.eqn.invars[1] if site.primitive == "div"
                   else site.eqn.invars[0])
            dt = getattr(getattr(den, "aval", None), "dtype", None)
            if dt is None or not np.issubdtype(dt, np.floating):
                continue                      # integer index math
            if not self._guarded(den, site.jaxpr, site.frames, set()):
                findings.append(Finding(
                    self.name, prog.name,
                    f"unguarded {site.primitive} divisor: "
                    f"{site.describe()} — masked data makes zero "
                    "denominators routine; guard with max(x, eps) / "
                    "max(Σw, 1) / where(ok, x, 1)"))
        return findings

    def _producers(self, jaxpr) -> Dict:
        key = id(jaxpr)
        if key not in self._prods:
            self._prods[key] = producers(jaxpr)
        return self._prods[key]

    def _guarded(self, v, jaxpr, frames: Tuple, seen: Set) -> bool:
        return self._level(v, jaxpr, frames, seen) >= _NONZERO

    def _level(self, v, jaxpr, frames: Tuple, seen: Set) -> int:
        """What the producer chain proves about ``v`` (a value in
        ``jaxpr`` with enclosing call ``frames``): strictly positive,
        nonzero, or nothing. The distinction matters for the aggregate
        rules — a product of nonzeros is nonzero, but a SUM is only safe
        when every term is strictly positive (``Σ exp(x)`` in the softmax
        VJP; two nonzeros can cancel)."""
        val = literal_value(v)
        if val is not None and val != 0:
            return _POSITIVE if val > 0 else _NONZERO
        if is_literal(v):
            return _UNKNOWN                   # zero/array literal
        key = (id(v), id(jaxpr))
        if key in seen:
            return _UNKNOWN                   # cycle
        seen = seen | {key}
        eqn = self._producers(jaxpr).get(v)
        if eqn is None:
            # boundary value: hop out to the caller's operand
            if not frames:
                return _UNKNOWN               # program input: opaque
            outer_jaxpr, call_eqn = frames[-1]
            outer_v = caller_operand(jaxpr, v, call_eqn)
            if outer_v is None:
                return _UNKNOWN               # scan carry / unmapped
            return self._level(outer_v, outer_jaxpr, frames[:-1], seen)
        p = eqn.primitive.name
        sub = lambda u: self._level(u, jaxpr, frames, seen)  # noqa: E731
        if p == "max":
            # max(x, c>0) >= c: positive if ANY operand is positive
            if any(sub(u) == _POSITIVE for u in eqn.invars):
                return _POSITIVE
            return _UNKNOWN
        if p == "select_n":
            # the where(ok, x, fallback) idiom IS the guard: the branch
            # replacing the unsafe case is what makes the div total
            return _NONZERO
        if p in _ALWAYS_POSITIVE:
            return _POSITIVE
        if p == "abs":
            return _POSITIVE if sub(eqn.invars[0]) else _UNKNOWN
        if p in _PASS_THROUGH:
            return sub(eqn.invars[0])
        if p == "mul":
            return min(sub(u) for u in eqn.invars)
        if p in ("add", "reduce_sum"):
            # sums are safe only from strictly positive terms
            levels = [sub(u) for u in eqn.invars]
            return _POSITIVE if min(levels) == _POSITIVE else _UNKNOWN
        if p in _ENTERABLE:
            results = callee_results(eqn, v)
            if not results:
                return _UNKNOWN
            return min(
                self._level(sub_v, close(sj), frames + ((jaxpr, eqn),),
                            seen)
                for sj, sub_v in results)
        return _UNKNOWN

"""The static pass inventory.

``default_passes()`` is the set the CLI and the lint test tier run; each
pass is independently importable for targeted self-tests (the lint tier
injects one violation class per pass and asserts the finding fires).
"""
from repro.analysis.passes.collectives import CollectiveAuditPass
from repro.analysis.passes.donation import DonationPass
from repro.analysis.passes.host_transfer import HostTransferPass
from repro.analysis.passes.mask_safety import MaskSafetyPass
from repro.analysis.passes.precision import PrecisionPass

__all__ = ["CollectiveAuditPass", "DonationPass", "HostTransferPass",
           "MaskSafetyPass", "PrecisionPass", "default_passes"]


def default_passes():
    return [HostTransferPass(), PrecisionPass(), MaskSafetyPass(),
            CollectiveAuditPass(), DonationPass()]

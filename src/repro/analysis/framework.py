"""Pass framework: programs in, findings out.

A :class:`ProgramSpec` is one REAL round program — traced (never executed)
from the exact function object a backend dispatches — tagged with the
*role* that decides which invariants apply to it:

- ``decision``    — the Eq. 13–19 selection math: must be float64 end to
                    end (PR 4's 1-ulp FMA lesson);
- ``aggregation`` — Eq. 21 / §4.10 uplink programs: float32 domain, no
                    silent downcasts, no x64 leakage;
- ``training``    — the local-SGD epoch programs;
- ``collective``  — ``shard_map`` programs whose psum payloads the
                    collective audit cross-checks against the roofline.

An :class:`AnalysisPass` walks one program and returns :class:`Finding`\\ s;
:func:`run_passes` is the product loop the CLI and the lint test tier both
call. Passes are pure functions of the jaxpr — the dynamic audits
(recompilation, budget manifests) live in ``repro.analysis.recompile`` and
``repro.analysis.budgets``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

DECISION = "decision"
AGGREGATION = "aggregation"
TRAINING = "training"
COLLECTIVE = "collective"


@dataclass(frozen=True)
class Finding:
    """One lint violation, printable and machine-checkable."""
    pass_name: str           # e.g. "host-transfer"
    program: str             # ProgramSpec.name
    message: str             # what is wrong and where
    severity: str = "error"  # error | warning

    def __str__(self) -> str:
        return f"[{self.pass_name}] {self.program}: {self.message}"


@dataclass
class ProgramSpec:
    """One traced round program.

    ``jaxpr`` is a ClosedJaxpr from ``jax.make_jaxpr`` over the function
    the backend actually calls (for AOT-compiled decision programs, the
    same traced form the compile cache holds)."""
    name: str                        # "engine/uplink_fused/q4"
    backend: str                     # batched|engine|async|sharded|shared
    comm_impl: str                   # fused|reference|n/a
    role: str                        # DECISION|AGGREGATION|TRAINING|COLLECTIVE
    jaxpr: object                    # ClosedJaxpr
    mesh_devices: int = 1            # collective programs: mesh size traced
    meta: Dict = field(default_factory=dict)


class AnalysisPass:
    """Base class: subclasses set ``name``/``roles`` and implement
    :meth:`run`. ``roles=None`` means the pass sees every program."""
    name: str = "abstract"
    roles: Optional[Sequence[str]] = None

    def applies(self, prog: ProgramSpec) -> bool:
        return self.roles is None or prog.role in self.roles

    def run(self, prog: ProgramSpec) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def check(self, prog: ProgramSpec) -> List[Finding]:
        return self.run(prog) if self.applies(prog) else []


def run_passes(passes: Sequence[AnalysisPass],
               programs: Sequence[ProgramSpec]) -> List[Finding]:
    """Every applicable (pass, program) pair, findings concatenated in
    deterministic (program, pass) order."""
    findings: List[Finding] = []
    for prog in programs:
        for p in passes:
            findings.extend(p.check(prog))
    return findings

"""Jaxpr IR utilities for the static-analysis passes.

Generalizes the eqn-walker proven in ``repro.roofline.jaxpr_flops``: where
the FLOP meter folds sub-jaxprs into one scalar, the lint passes need the
*structure* — every equation with its nesting path (``scan`` bodies,
``cond`` branches, ``pjit``/``shard_map``/``custom_vjp`` calls), and
def-use maps inside each (sub-)jaxpr so a pass can walk a value's producer
chain (the mask-safety pass traces a divisor back to its ``max``/
``select_n`` guard this way).

Everything operates on open ``core.Jaxpr`` objects; :func:`close` unwraps
``ClosedJaxpr`` transparently. Nothing here executes or lowers a program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import jax

Eqn = jax.core.JaxprEqn

# eqn params that carry a nested jaxpr (single)
_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr")


def close(j):
    """ClosedJaxpr | Jaxpr -> open Jaxpr."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def sub_jaxprs(eqn: Eqn) -> Iterator[Tuple[str, object]]:
    """Yield ``(slot, open jaxpr)`` for every nested jaxpr of ``eqn``."""
    for name in _SUBJAXPR_PARAMS:
        if name in eqn.params:
            yield name, close(eqn.params[name])
    for i, b in enumerate(eqn.params.get("branches", ())):
        yield f"branch{i}", close(b)


@dataclass(frozen=True)
class EqnSite:
    """One equation plus where it sits in the nested-program tree.

    ``path`` is the chain of enclosing call primitives, e.g.
    ``("pjit", "shard_map", "scan")`` — enough for a finding to say *the
    div lives inside the scan body of the shard_map program*. ``frames``
    is the same chain with the objects themselves — ``(owning jaxpr,
    call eqn)`` outermost-first — so a dataflow pass can hop a value
    across a sub-jaxpr boundary back into the caller."""
    eqn: Eqn
    jaxpr: object                 # the (sub-)jaxpr that owns the eqn
    path: Tuple[str, ...]
    frames: Tuple[Tuple[object, Eqn], ...] = ()

    @property
    def primitive(self) -> str:
        return self.eqn.primitive.name

    def describe(self) -> str:
        loc = "/".join(self.path) or "<top>"
        outs = ", ".join(str(v.aval) for v in self.eqn.outvars[:2])
        return f"{self.primitive} -> {outs} @ {loc}"


def iter_eqns(jaxpr, path: Tuple[str, ...] = (),
              frames: Tuple = ()) -> Iterator[EqnSite]:
    """Depth-first walk over every eqn of ``jaxpr`` and all sub-jaxprs."""
    jaxpr = close(jaxpr)
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, jaxpr, path, frames)
        for slot, sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, path + (eqn.primitive.name,),
                                 frames + ((jaxpr, eqn),))


def producers(jaxpr) -> Dict[object, Eqn]:
    """``var -> eqn`` def map for ONE (sub-)jaxpr's body (not recursive —
    a sub-jaxpr's invars are opaque boundary values by design: a pass that
    cares must reason per jaxpr, which keeps guard-tracing local and
    sound)."""
    jaxpr = close(jaxpr)
    out: Dict[object, Eqn] = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out[v] = eqn
    return out


# call-like primitives whose sub-jaxpr outvars correspond 1:1 to the
# eqn's outvars (and invars positionally to the sub-jaxpr's invars)
CALL_PRIMITIVES = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "remat", "remat2",
    "checkpoint", "custom_jvp_call", "custom_vjp_call",
    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr", "shard_map",
})


def callee_results(eqn: Eqn, v) -> List[Tuple[object, object]]:
    """For ``v`` an outvar of a call-like/branching eqn, the sub-jaxpr
    value(s) it is bound to, as ``(sub_jaxpr, sub_outvar)`` pairs — one per
    branch for ``cond``, one for plain calls, empty if unmapped."""
    try:
        idx = list(eqn.outvars).index(v)
    except ValueError:
        return []
    p = eqn.primitive.name
    if p in CALL_PRIMITIVES:
        for _, sub in sub_jaxprs(eqn):
            if idx < len(sub.outvars):
                return [(sub, sub.outvars[idx])]
        return []
    if p == "cond":
        out = []
        for _, sub in sub_jaxprs(eqn):
            if idx < len(sub.outvars):
                out.append((sub, sub.outvars[idx]))
        return out
    return []


def caller_operand(sub_jaxpr, v, call_eqn: Eqn):
    """For ``v`` an invar of ``sub_jaxpr`` called by ``call_eqn``, the
    caller-side operand it is bound to — or None where the correspondence
    is not a sound value identity (a ``scan`` carry changes per iteration;
    ``while`` loop state likewise)."""
    sub_jaxpr = close(sub_jaxpr)
    try:
        idx = list(sub_jaxpr.invars).index(v)
    except ValueError:
        return None
    p = call_eqn.primitive.name
    if p in CALL_PRIMITIVES:
        if idx < len(call_eqn.invars):
            return call_eqn.invars[idx]
        return None
    if p == "cond":                      # invars = [pred, *operands]
        if idx + 1 < len(call_eqn.invars):
            return call_eqn.invars[idx + 1]
        return None
    if p == "scan":
        num_consts = call_eqn.params.get("num_consts", 0)
        num_carry = call_eqn.params.get("num_carry", 0)
        if idx < num_consts:             # consts: loop-invariant, sound
            return call_eqn.invars[idx]
        if idx < num_consts + num_carry:
            return None                  # carry: changes per iteration
        # xs element: the outer stacked xs (guard properties that survive
        # slicing — positivity, guarded-ness — carry over)
        if idx < len(call_eqn.invars):
            return call_eqn.invars[idx]
        return None
    return None


def is_literal(v) -> bool:
    return isinstance(v, jax.core.Literal)


def literal_value(v) -> Optional[float]:
    """The scalar value of a literal var, else None."""
    if not is_literal(v):
        return None
    try:
        import numpy as np
        val = np.asarray(v.val)
        if val.size == 1:
            return float(val.reshape(()))
    except (TypeError, ValueError):
        return None
    return None


def float_avals(eqn: Eqn) -> List:
    """The floating-point output avals of an eqn (empty for int/bool ops)."""
    import numpy as np
    out = []
    for v in eqn.outvars:
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None and np.issubdtype(dt, np.floating):
            out.append(aval)
    return out

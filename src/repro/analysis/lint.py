"""The lint CLI: every audit over every real round program.

    PYTHONPATH=src python -m repro.analysis.lint --backend all
    PYTHONPATH=src python -m repro.analysis.lint --backend engine \\
        --comm-impl fused --static-only
    PYTHONPATH=src python -m repro.analysis.lint --bless

Four layers, strict to slow:

1. **static passes** (seconds) — host-transfer, precision, mask-safety,
   collective-audit over the traced programs of the selected backends,
   plus the FLOP meter's unknown-primitive report (an op the roofline has
   never classified is charged 0 silently — surfacing the union here is
   what keeps the meter honest as kernels evolve);
2. **budget audit** — re-measures host syncs + uplink bytes from real
   seeded federations (``repro.analysis.budgets``) and diffs against the
   pinned ``budgets.json`` manifest;
3. **recompile audit** — warms each backend's jit caches with a real
   federation, then asserts an identically-seeded re-run compiles
   nothing;
4. **telemetry audit** — re-runs each target under an installed tracer
   and requires the reconciliation guarantee: per-span counter sums
   equal the global hostsync totals and the metrics uplink log equals
   the CommLedger exactly (``repro.analysis.telemetry_check``).

Exit 0 only when every layer is clean. ``--bless`` re-measures and
rewrites the manifest (commit the diff with the change that moved it).
"""
from __future__ import annotations

import argparse
from collections import Counter
from typing import Dict, List, Sequence, Tuple

from repro.analysis import budgets as budgets_mod
from repro.analysis.framework import Finding, run_passes
from repro.analysis.passes import default_passes
from repro.analysis.programs import BACKENDS, COMM_IMPLS, round_programs


def _targets(backend: str, comm_impl: str
             ) -> List[Tuple[str, str]]:
    bs = BACKENDS if backend == "all" else (backend,)
    cis = COMM_IMPLS if comm_impl == "all" else (comm_impl,)
    return [(b, ci) for b in bs for ci in cis]


def lint_static(targets: Sequence[Tuple[str, str]], *, bits: int = 4
                ) -> Tuple[List[Finding], Dict[str, int]]:
    """Static passes + the unknown-primitive union over the real
    programs of every (backend, comm_impl) target."""
    from repro.roofline.jaxpr_flops import jaxpr_flops_detailed
    seen: Dict[str, object] = {}
    for b, ci in targets:
        for p in round_programs(b, ci, bits=bits):
            seen.setdefault(p.name, p)
    programs = list(seen.values())
    findings = run_passes(default_passes(), programs)
    unknown: Counter = Counter()
    for p in programs:
        _, unk = jaxpr_flops_detailed(p.jaxpr.jaxpr)
        unknown.update(unk)
    for prim, count in sorted(unknown.items()):
        findings.append(Finding(
            "flop-meter", "<all programs>",
            f"primitive {prim!r} ({count} occurrence(s)) is unclassified "
            "in roofline/jaxpr_flops.py — charged 0 FLOPs; add it to "
            "_ELEMENTWISE/_REDUCE/_FREE or give it a cost model"))
    return findings, dict(unknown)


def lint_budgets(targets: Sequence[Tuple[str, str]]
                 ) -> Tuple[List[Finding], Dict]:
    backends = sorted({b for b, _ in targets})
    comm_impls = sorted({ci for _, ci in targets})
    measured = budgets_mod.measure_all(tuple(backends), tuple(comm_impls))
    pinned = budgets_mod.load_budgets()
    return budgets_mod.compare(measured, pinned), measured


def lint_recompiles(targets: Sequence[Tuple[str, str]]
                    ) -> List[Finding]:
    from repro.analysis.recompile import audit_federation
    findings: List[Finding] = []
    for b, ci in targets:
        f, _ = audit_federation(b, ci)
        findings.extend(f)
    return findings


def run_lint(backend: str = "all", comm_impl: str = "all", *,
             static_only: bool = False, bits: int = 4
             ) -> Tuple[List[Finding], Dict]:
    """All layers over the selected targets; returns (findings, report)."""
    targets = _targets(backend, comm_impl)
    findings, unknown = lint_static(targets, bits=bits)
    report: Dict = {"targets": targets, "unknown_primitives": unknown}
    if not static_only:
        budget_findings, measured = lint_budgets(targets)
        findings.extend(budget_findings)
        report["budgets"] = measured
        findings.extend(lint_recompiles(targets))
        from repro.analysis.telemetry_check import lint_telemetry
        telemetry_findings = lint_telemetry(targets)
        findings.extend(telemetry_findings)
        report["telemetry_findings"] = len(telemetry_findings)
    report["findings"] = len(findings)
    return findings, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="static + dynamic audits over the real round programs")
    ap.add_argument("--backend", default="all",
                    choices=("all",) + BACKENDS)
    ap.add_argument("--comm-impl", default="all",
                    choices=("all",) + COMM_IMPLS)
    ap.add_argument("--static-only", action="store_true",
                    help="skip the budget + recompile audits (no "
                         "federations are run; seconds instead of minutes)")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--bless", action="store_true",
                    help="re-measure and rewrite budgets.json, then exit")
    args = ap.parse_args(argv)

    if args.bless:
        budgets = budgets_mod.bless()
        print(f"blessed {budgets_mod.BUDGET_PATH}")
        for b, impls in sorted(budgets.items()):
            if b == "config":
                continue
            for ci, m in sorted(impls.items()):
                print(f"  {b:8s} {ci:10s} host_syncs={m['host_syncs']:4d} "
                      f"bytes_moved={m['bytes_moved']} "
                      f"dispatches={m['dispatches']}")
        return 0

    findings, report = run_lint(args.backend, args.comm_impl,
                                static_only=args.static_only,
                                bits=args.bits)
    n_programs = len({p for p in report.get('targets', ())})
    errors = [f for f in findings if f.severity == "error"]
    warnings = [f for f in findings if f.severity != "error"]
    for f in findings:
        print(f"{f.severity.upper()}: {f}")
    scope = (f"{len(report['targets'])} (backend, comm_impl) target(s)"
             if n_programs else "no targets")
    if not findings:
        print(f"lint clean: {scope}, 0 findings")
    else:
        print(f"lint: {len(errors)} error(s), {len(warnings)} warning(s) "
              f"over {scope}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Static + dynamic analysis over the REAL round programs.

``repro.analysis`` is the jaxpr-level counterpart of ``repro.roofline``:
where the roofline *meters* the traced round programs (FLOPs, bytes),
this package *audits* them — host-transfer freedom, the f64 decision
wall, divisor guards, collective payload bounds — and pins the dynamic
communication contract (host syncs, uplink bytes per round) in a
checked-in manifest. Entry points:

- ``python -m repro.analysis.lint --backend all`` — the CLI;
- :func:`repro.analysis.lint.run_lint` — the same audits in-process;
- ``pytest -m lint`` — the self-test tier (each violation class injected
  and caught).
"""
from repro.analysis.framework import (AnalysisPass, Finding, ProgramSpec,
                                      run_passes)
from repro.analysis.passes import default_passes
from repro.analysis.programs import all_round_programs, round_programs

__all__ = ["AnalysisPass", "Finding", "ProgramSpec", "all_round_programs",
           "default_passes", "round_programs", "run_passes"]

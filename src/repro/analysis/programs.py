"""Trace the REAL round programs every backend dispatches.

Nothing here invents a model: each :class:`ProgramSpec` comes from
``jax.make_jaxpr`` over the *same function object* ``run_federation``
executes — ``masked_batched_epoch``, ``quantize_pack_population``,
``aggregate_quantized``, the selection engine's ``_modality_program`` /
``_client_program`` (traced in f64 under ``enable_x64``, exactly as their
AOT compile cache traces them), and the sharded backend's
``jit(shard_map(...))`` programs via
``repro.roofline.federated.sharded_round_programs``. Tracing only — no
compilation, no execution, no devices touched beyond the 1-D mesh object
the sharded programs close over.

Shapes are a small representative round (LSTM shape family, K=8 uploads,
4-bit uplink by default): every lint invariant — callbacks, dtype flow,
guard idioms, collective payloads — is shape-generic, so a violation at
K=8 is the violation at K=10⁶.

Backends map to program sets as the backends map to code:

- ``batched`` / ``engine`` share the training + uplink program objects
  (they differ in where the population *lives*, not what compiles);
- ``async`` flushes through the very same ``aggregate_uploads`` programs
  (staleness discounts enter as weights, not new programs);
- ``sharded`` swaps in the ``shard_map`` epoch/psum programs and the
  shard-mapped modality ranker.

Both trainer impls appear: the per-epoch reference programs AND the
``train_impl="fused"`` all-epochs round programs, whose specs carry
donation facts read from the REAL lowering (``lower(...).args_info``)
so the donation pass can prove the resident param stacks update in
place.

The f64 decision programs are shared by all of them and appear once per
backend under the backend's name so ``--backend engine`` audits the full
set that backend runs.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.analysis.framework import (AGGREGATION, COLLECTIVE, DECISION,
                                      TRAINING, ProgramSpec)

BACKENDS = ("batched", "engine", "async", "sharded")
COMM_IMPLS = ("fused", "reference")

# representative round shapes (lint invariants are shape-generic)
_K = 8            # upload population rows
_G = 4            # training-bucket rows
_S, _B = 2, 8     # padded schedule [S, B]
_FEAT = (6, 5)    # LSTM family: [T, F]
_CLASSES = 3
_M = 2            # modalities


def _encoder_template():
    from repro.core.encoders import init_encoder
    return jax.eval_shape(
        lambda: init_encoder(jax.random.PRNGKey(0), _FEAT, _CLASSES))


def _fusion_template():
    from repro.core.fusion import init_fusion
    return jax.eval_shape(
        lambda: init_fusion(jax.random.PRNGKey(0), _M, _CLASSES))


def _stack(template, k: int):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((k,) + tuple(l.shape), l.dtype),
        template)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _trace(fn, *args, x64: bool = False, **kwargs):
    if x64:
        with enable_x64():
            return jax.make_jaxpr(fn)(*args, **kwargs)
    return jax.make_jaxpr(fn)(*args, **kwargs)


# ---------------------------------------------------------------------------
# shared program groups
# ---------------------------------------------------------------------------

def _donation_meta(jitted, *args, resident=(0,), **kw) -> Dict:
    """Donation facts straight from the REAL lowering: which positional
    args the compiled program consumes in place. ``resident`` declares
    which args are resident population stacks — the donation lint pass
    cross-checks the two."""
    arg_info, _ = jitted.lower(*args, **kw).args_info
    donated = tuple(
        bool(leaves) and all(a.donated for a in leaves)
        for leaves in (jax.tree_util.tree_leaves(arg) for arg in arg_info))
    return {"donation": {"resident": tuple(resident), "donated": donated}}


def _fused_training_programs(backend: str) -> List[ProgramSpec]:
    """The ``train_impl="fused"`` round programs: all E epochs in one
    launch, resident param stack donated."""
    from repro.kernels.train import fused_encoder_round, fused_fusion_round
    enc = _stack(_encoder_template(), _G)
    fus = _stack(_fusion_template(), _G)
    e = 2                                   # representative epoch count
    xs = _f32(_G, e, _S, _B, *_FEAT)
    ys = _i32(_G, e, _S, _B)
    ws = _f32(_G, e, _S, _B)
    preds = _f32(_G, e, _S, _B, _M, _CLASSES)
    pmask = _f32(_G, _M)
    out = []
    for suffix, fn, args in (
            ("round_encoder_fused", fused_encoder_round,
             (enc, xs, ys, ws)),
            ("round_fusion_fused", fused_fusion_round,
             (fus, preds, pmask, ys, ws))):
        out.append(ProgramSpec(
            f"{backend}/{suffix}", backend, "n/a", TRAINING,
            _trace(functools.partial(fn, lr=0.1), *args),
            meta=_donation_meta(fn, *args, lr=0.1)))
    return out


def _training_programs(backend: str) -> List[ProgramSpec]:
    from repro.core.batched import (_batched_fusion_eval, _batched_predict,
                                    masked_batched_epoch,
                                    masked_fusion_epoch)
    enc = _stack(_encoder_template(), _G)
    fus = _stack(_fusion_template(), _G)
    xs = _f32(_G, _S, _B, *_FEAT)
    ys = _i32(_G, _S, _B)
    ws = _f32(_G, _S, _B)
    preds = _f32(_G, _S, _B, _M, _CLASSES)
    pmask = _f32(_G, _M)
    epreds = _f32(_G, _B, _M, _CLASSES)
    ey = _i32(_G, _B)
    ew = _f32(_G, _B)
    return [
        ProgramSpec(f"{backend}/epoch_encoder", backend, "n/a", TRAINING,
                    _trace(lambda p, x, y, w: masked_batched_epoch(
                        p, x, y, w, 0.1), enc, xs, ys, ws)),
        ProgramSpec(f"{backend}/epoch_fusion", backend, "n/a", TRAINING,
                    _trace(lambda p, pr, mk, y, w: masked_fusion_epoch(
                        p, pr, mk, y, w, 0.1), fus, preds, pmask, ys, ws)),
        ProgramSpec(f"{backend}/predict", backend, "n/a", TRAINING,
                    _trace(_batched_predict, enc, _f32(_G, _B, *_FEAT))),
        ProgramSpec(f"{backend}/fusion_eval", backend, "n/a", TRAINING,
                    _trace(_batched_fusion_eval, fus, epreds, pmask, ey,
                           ew)),
    ]


def _uplink_programs(backend: str, comm_impl: str,
                     bits: int) -> List[ProgramSpec]:
    from repro.core.aggregation import aggregate_quantized, aggregate_stacked
    from repro.core.quantize import (quantize_population,
                                     quantize_population_with_error_feedback)
    from repro.kernels.comm import (quantize_pack_population,
                                    quantize_pack_population_ef,
                                    reduce_packed_population)
    stacked = _stack(_encoder_template(), _K)
    res = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), stacked)
    w = _f32(_K)
    out = [ProgramSpec(f"{backend}/aggregate_full", backend, comm_impl,
                       AGGREGATION, _trace(aggregate_stacked, stacked, w))]
    if comm_impl == "fused":
        up = _trace(lambda s: quantize_pack_population(s, bits=bits),
                    stacked)
        payload = jax.eval_shape(
            lambda s: quantize_pack_population(s, bits=bits), stacked)
        shapes = tuple(tuple(l.shape[1:])
                       for l in jax.tree_util.tree_leaves(stacked))
        down = _trace(
            lambda p, sc, z, ww: reduce_packed_population(
                p, sc, z, ww, bits=bits, shapes=shapes), *payload, w)
        ef = _trace(
            lambda s, r: quantize_pack_population_ef(s, r, bits=bits),
            stacked, res)
    else:
        up = _trace(lambda s: quantize_population(s, bits=bits), stacked)
        payload = jax.eval_shape(
            lambda s: quantize_population(s, bits=bits), stacked)
        down = _trace(aggregate_quantized, *payload, w)
        ef = _trace(
            lambda s, r: quantize_population_with_error_feedback(
                s, r, bits=bits), stacked, res)
    out += [
        ProgramSpec(f"{backend}/uplink_{comm_impl}/q{bits}", backend,
                    comm_impl, AGGREGATION, up),
        ProgramSpec(f"{backend}/downlink_{comm_impl}/q{bits}", backend,
                    comm_impl, AGGREGATION, down),
        ProgramSpec(f"{backend}/uplink_ef_{comm_impl}/q{bits}", backend,
                    comm_impl, AGGREGATION, ef),
    ]
    return out


def _decision_programs(backend: str) -> List[ProgramSpec]:
    from repro.core.selection_engine import (_client_program,
                                             _modality_program)
    f64 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float64)
    km = f64((_K, _M))
    b_km = jax.ShapeDtypeStruct((_K, _M), jnp.bool_)
    i_km = jax.ShapeDtypeStruct((_K, _M), jnp.int64)
    mod = functools.partial(_modality_program, gamma=1, alpha_s=1 / 3,
                            alpha_c=1 / 3, alpha_r=1 / 3)
    out = [ProgramSpec(f"{backend}/select_modalities", backend, "n/a",
                       DECISION,
                       _trace(mod, km, km, km, b_km, i_km, f64(()),
                              x64=True))]
    for crit in ("low_loss", "high_loss", "loss_recency"):
        cli = functools.partial(_client_program, criterion=crit)
        out.append(ProgramSpec(
            f"{backend}/select_clients_{crit}", backend, "n/a", DECISION,
            _trace(cli, km, b_km, f64((_K,)), f64(()), f64(()), x64=True)))
    return out


def _sharded_programs(comm_impl: str, bits: int) -> List[ProgramSpec]:
    from repro.core.sharded import client_mesh
    from repro.core.selection_engine import _modality_program
    from repro.roofline.federated import sharded_round_programs
    from jax.experimental.shard_map import shard_map
    from repro.sharding.partition import client_spec
    from jax.sharding import PartitionSpec as P
    mesh = client_mesh(1)
    progs = sharded_round_programs(
        mesh, k=_K, steps=_S, batch=_B, feat=_FEAT,
        template=_encoder_template(), lr=0.1, bits=bits)
    name_of = {"epoch": ("epoch_encoder", TRAINING),
               "epoch_fused": ("round_encoder_fused", TRAINING),
               "aggregate_full": ("aggregate_full", COLLECTIVE),
               ("aggregate_q_fused" if comm_impl == "fused" else
                "aggregate_q_reference"):
                   (f"aggregate_q_{comm_impl}/q{bits}", COLLECTIVE)}
    out = []
    for key, (suffix, role) in name_of.items():
        program, args = progs[key]
        meta = {"bits": bits if "q_" in key else 32,
                "template": _encoder_template()}
        if key == "epoch_fused":
            meta.update(_donation_meta(program, *args))
        out.append(ProgramSpec(
            f"sharded/{suffix}", "sharded", comm_impl, role,
            _trace(program, *args), mesh_devices=mesh.devices.size,
            meta=meta))
    # the shard-mapped Eqs. 12–16 ranker, traced exactly as
    # _sharded_modality_program lowers it (f64, shard_map over the mesh)
    fn = functools.partial(_modality_program, gamma=1, alpha_s=1 / 3,
                           alpha_c=1 / 3, alpha_r=1 / 3)
    spec = client_spec()
    mapped = shard_map(fn, mesh=mesh,
                       in_specs=(spec, spec, spec, spec, spec, P()),
                       out_specs=(spec, spec, spec, spec))
    f64 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float64)
    km = f64((_K, _M))
    out.append(ProgramSpec(
        "sharded/select_modalities", "sharded", comm_impl, DECISION,
        _trace(mapped, km, km, km,
               jax.ShapeDtypeStruct((_K, _M), jnp.bool_),
               jax.ShapeDtypeStruct((_K, _M), jnp.int64), f64(()),
               x64=True), mesh_devices=mesh.devices.size))
    return out


# ---------------------------------------------------------------------------
# public registry
# ---------------------------------------------------------------------------

def round_programs(backend: str, comm_impl: str = "fused", *,
                   bits: int = 4) -> List[ProgramSpec]:
    """Every program ``run_federation(backend=...)`` dispatches in a
    quantized round at the given ``comm_impl``, as traced ProgramSpecs."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}: use {BACKENDS}")
    if comm_impl not in COMM_IMPLS:
        raise ValueError(f"unknown comm_impl {comm_impl!r}")
    if backend == "sharded":
        # training/uplink swap to shard_map forms (incl. the fused encoder
        # round program); fusion stage + decision client ranking ride the
        # engine programs
        out = _sharded_programs(comm_impl, bits)
        out += [p for p in _training_programs(backend)
                if "epoch_encoder" not in p.name]
        out += [p for p in _fused_training_programs(backend)
                if "round_encoder" not in p.name]
        return out + _decision_programs(backend)[1:]  # client ranking only
    return (_training_programs(backend)
            + _fused_training_programs(backend)
            + _uplink_programs(backend, comm_impl, bits)
            + _decision_programs(backend))


def all_round_programs(backends: Sequence[str] = BACKENDS,
                       comm_impls: Sequence[str] = COMM_IMPLS, *,
                       bits: int = 4) -> List[ProgramSpec]:
    """The full program zoo, deduplicated by name (shared programs appear
    once per backend, once per comm_impl only where the impl changes the
    program)."""
    seen: Dict[str, ProgramSpec] = {}
    for b in backends:
        for ci in comm_impls:
            for p in round_programs(b, ci, bits=bits):
                seen.setdefault(p.name, p)
    return list(seen.values())

"""Recompilation auditor: steady-state rounds must hit warm jit caches.

The backends' whole performance story (PR 2's batched epochs, PR 6's
sharded programs, PR 7's fused comm path) assumes each round program
compiles ONCE and replays. A shape leak — a Python scalar promoted to a
fresh constant, an upload population that misses the pow-2 pad, an lru
cache keyed on an unhashed config — turns every round into an XLA compile,
and nothing in the test suite notices: results stay correct, only 100×
slower.

:func:`track_compiles` observes the two signals jax exposes:

- the ``/jax/core/compile/backend_compile_duration`` monitoring event,
  fired once per backend compile (the ground-truth *count*);
- ``jax_log_compiles`` log records on the pxla logger (the program
  *names*, so a finding can say WHICH program recompiled).

:func:`audit_federation` runs a real mini federation twice — a warmup run
that populates every jit cache, then an identically-seeded steady run
under the tracker. The steady run replays the exact shapes of the warmup
run, so every compile it triggers is a per-round recompile by
construction.
"""
from __future__ import annotations

import contextlib
import logging
import re
from dataclasses import dataclass, field
from typing import Iterator, List, Tuple

from repro.analysis.framework import Finding

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_PXLA_LOGGER = "jax._src.interpreters.pxla"
_NAME_RE = re.compile(r"Compiling ([\w\-<>.]+)")


@dataclass
class CompileReport:
    """What compiled while a :func:`track_compiles` scope was active."""
    count: int = 0
    names: List[str] = field(default_factory=list)


class _LogCapture(logging.Handler):
    def __init__(self, report: CompileReport):
        super().__init__(level=logging.DEBUG)
        self.report = report

    def emit(self, record):
        m = _NAME_RE.search(record.getMessage())
        if m:
            self.report.names.append(m.group(1))


@contextlib.contextmanager
def track_compiles() -> Iterator[CompileReport]:
    """Count (and name) every XLA backend compile inside the scope."""
    import jax
    from jax._src import monitoring
    report = CompileReport()

    def _on_event(event: str, duration: float, **kw) -> None:
        if event == _COMPILE_EVENT:
            report.count += 1

    monitoring.register_event_duration_secs_listener(_on_event)
    logger = logging.getLogger(_PXLA_LOGGER)
    handler = _LogCapture(report)
    logger.addHandler(handler)
    prev_level, prev_prop = logger.level, logger.propagate
    logger.setLevel(logging.DEBUG)
    logger.propagate = False             # capture, don't spam the console
    dispatch = logging.getLogger("jax._src.dispatch")
    prev_dispatch = dispatch.level
    dispatch.setLevel(logging.ERROR)     # log_compiles elevates it too
    prev_log_compiles = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    try:
        yield report
    finally:
        jax.config.update("jax_log_compiles", prev_log_compiles)
        logger.removeHandler(handler)
        logger.setLevel(prev_level)
        logger.propagate = prev_prop
        dispatch.setLevel(prev_dispatch)
        monitoring._unregister_event_duration_listener_by_callback(_on_event)


def audit_rounds(round_fn, rounds: int, *, program: str,
                 warmup: int = 1) -> Tuple[List[Finding], CompileReport]:
    """Generic N-round audit: run ``round_fn(i)`` ``warmup`` times cold,
    then ``rounds`` times under the tracker; any steady-state compile is a
    finding."""
    for i in range(warmup):
        round_fn(i)
    with track_compiles() as report:
        for i in range(warmup, warmup + rounds):
            round_fn(i)
    findings = []
    if report.count > 0:
        names = ", ".join(sorted(set(report.names))[:6]) or "<unnamed>"
        findings.append(Finding(
            "recompile", program,
            f"{report.count} XLA compile(s) during {rounds} post-warmup "
            f"round(s) (programs: {names}) — steady-state rounds must "
            "replay warm jit caches; look for shape leaks or unhashed "
            "cache keys"))
    return findings, report


def audit_federation(backend: str, comm_impl: str, *, bits: int = 4,
                     rounds: int = 3, train_impl: str = "fused"
                     ) -> Tuple[List[Finding], CompileReport]:
    """Warm a real mini federation, then assert an identically-seeded
    re-run compiles nothing. ``train_impl="fused"`` (the default) puts
    the donated all-epochs round programs under the tracker — donation
    must not defeat jit-cache reuse across rounds."""
    from repro.analysis.budgets import federation_config, mini_federation

    def one_run(_):
        clients, spec = mini_federation()
        cfg = federation_config(comm_impl, bits=bits, rounds=rounds,
                                train_impl=train_impl)
        from repro.core.rounds import run_federation
        run_federation(clients, spec, cfg, backend=backend)

    return audit_rounds(one_run, rounds=1, warmup=1,
                        program=f"{backend}/{comm_impl}/"
                                f"{train_impl}-train/federation")

"""Lint-tier telemetry audit: the reconciliation guarantee, re-proven.

For every (backend, comm_impl, train_impl) target this module runs the
seeded mini-federation (``repro.analysis.budgets``) under an installed
tracer AND a ``hostsync.measuring`` window, then requires, exactly:

1. the tracer's run totals equal the measuring window's counters — the
   trace explains ALL the host syncs / uplink bytes / dispatches the
   budget manifest pins, not a subset;
2. :func:`repro.telemetry.reconcile` is clean — root spans sum to the run
   totals, children never exceed their parent, and the metrics uplink log
   equals the CommLedger byte for byte.

A failure prints an expected-vs-measured diff per counter, in the style
of ``repro.analysis.budgets.compare`` — e.g. an instrumentation gap (a
new fetch outside every round span) shows up here before it silently
skews any per-phase attribution a benchmark stamps.

Wired into ``python -m repro.analysis.lint`` (not ``--static-only``) and
exercised by the ``lint``-marked tier of ``tests/test_telemetry.py``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.analysis.framework import Finding

# the audited matrix: the loop backend has no (backend-specific) program
# tier but is traced all the same — it joins when the target set spans
# every backend (lint --backend all)
TRAIN_IMPLS = ("fused", "reference")


def check(backend: str, comm_impl: str, train_impl: str = "fused", *,
          rounds: int = 2) -> List[Finding]:
    """Findings for one traced (backend, comm_impl, train_impl) run."""
    from repro import telemetry
    from repro.analysis import budgets as budgets_mod
    from repro.core import hostsync
    from repro.core.rounds import run_federation
    tag = f"{backend}/{comm_impl}/{train_impl}"
    clients, spec = budgets_mod.mini_federation()
    cfg = budgets_mod.federation_config(comm_impl, rounds=rounds,
                                        train_impl=train_impl)
    with hostsync.measuring() as m:
        tracer = telemetry.Tracer()
        with telemetry.install(tracer):
            run_federation(clients, spec, cfg, backend=backend)
        totals = tracer.finish()
    findings: List[Finding] = []
    for key, want in m.as_dict().items():
        got = int(totals[key])
        if got != want:
            findings.append(Finding(
                "telemetry", tag,
                f"{key}: tracer run total is {got}, hostsync measured "
                f"{want} ({got - want:+d}) — counter activity outside the "
                "tracer's lifetime, or a span straddling a measuring() "
                "window"))
    findings.extend(Finding("telemetry", tag, d)
                    for d in telemetry.reconcile(tracer))
    return findings


def check_all(backends: Sequence[str],
              comm_impls: Sequence[str] = ("fused", "reference"),
              train_impls: Sequence[str] = TRAIN_IMPLS, *,
              rounds: int = 2) -> List[Finding]:
    findings: List[Finding] = []
    for b in backends:
        for ci in comm_impls:
            for ti in train_impls:
                findings.extend(check(b, ci, ti, rounds=rounds))
    return findings


def lint_telemetry(targets: Sequence[Tuple[str, str]]) -> List[Finding]:
    """The lint layer: audit every (backend, comm_impl) target at both
    trainer impls; when the targets span every backend, the loop
    reference joins the matrix (it has no traced-program tier of its own
    but must reconcile all the same)."""
    from repro.analysis.programs import BACKENDS
    backends = sorted({b for b, _ in targets})
    comm_impls = tuple(sorted({ci for _, ci in targets}))
    if set(backends) >= set(BACKENDS):
        backends = ["loop"] + backends
    return check_all(backends, comm_impls)

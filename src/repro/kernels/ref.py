"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests).

Each oracle is the straightforward / already-validated XLA implementation of
the same math:
- flash attention   → unblocked softmax attention (GQA-aware)
- RG-LRU scan       → gate projections + ``jax.lax.associative_scan``
- mLSTM chunk scan  → ``repro.models.ssm.mlstm_chunked`` (chunkwise jnp)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import mlstm_chunked

NEG_INF = -1e30
RGLRU_C = 8.0


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, H, S, d]; k, v: [B, KV, T, d]. Returns [B, H, S, d]."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = d ** -0.5
    qg = q.reshape(b, kv, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg * scale, k
                        ).astype(jnp.float32)
    t = k.shape[2]
    rel = jnp.arange(s)[:, None] - jnp.arange(t)[None, :]
    if causal:
        scores = jnp.where(rel >= 0, scores, NEG_INF)
    if window > 0:
        scores = jnp.where(rel < window, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p.astype(v.dtype), v)
    return out.reshape(b, h, s, d)


def rglru_scan_ref(x, w_a, w_x, lam):
    """x: [B, S, W]. Returns (h [B, S, W], h_last [B, W] f32)."""
    r = jax.nn.sigmoid((x @ w_a).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ w_x).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x.astype(jnp.float32)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def mlstm_scan_ref(q, k, v, i_pre, f_pre, *, chunk: int = 64):
    """Same layout as the kernel: q,k [B,H,S,dk]; v [B,H,S,dv]; gates [B,H,S].

    Returns (h [B,H,S,dv], (C, n, m))."""
    h, state = mlstm_chunked(q, k, v, i_pre, f_pre, chunk)
    return h, state

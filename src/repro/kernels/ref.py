"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests).

Each oracle is the straightforward / already-validated XLA implementation of
the same math:
- flash attention   → unblocked softmax attention (GQA-aware)
- RG-LRU scan       → gate projections + ``jax.lax.associative_scan``
- mLSTM chunk scan  → ``repro.models.ssm.mlstm_chunked`` (chunkwise jnp)
- comm uplink       → per-row ``quantize_tensor`` + ``pack_codes`` (§4.10)
- comm downlink     → unpack, dequantize the full [K, n] stack, weighted mean
- fusion SGD step   → jitted manual softmax-CE backward + SGD update
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import pack_codes, quantize_tensor, unpack_codes
from repro.models.ssm import mlstm_chunked

NEG_INF = -1e30
RGLRU_C = 8.0


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, H, S, d]; k, v: [B, KV, T, d]. Returns [B, H, S, d]."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = d ** -0.5
    qg = q.reshape(b, kv, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg * scale, k
                        ).astype(jnp.float32)
    t = k.shape[2]
    rel = jnp.arange(s)[:, None] - jnp.arange(t)[None, :]
    if causal:
        scores = jnp.where(rel >= 0, scores, NEG_INF)
    if window > 0:
        scores = jnp.where(rel < window, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p.astype(v.dtype), v)
    return out.reshape(b, h, s, d)


def rglru_scan_ref(x, w_a, w_x, lam):
    """x: [B, S, W]. Returns (h [B, S, W], h_last [B, W] f32)."""
    r = jax.nn.sigmoid((x @ w_a).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ w_x).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x.astype(jnp.float32)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def mlstm_scan_ref(q, k, v, i_pre, f_pre, *, chunk: int = 64):
    """Same layout as the kernel: q,k [B,H,S,dk]; v [B,H,S,dv]; gates [B,H,S].

    Returns (h [B,H,S,dv], (C, n, m))."""
    h, state = mlstm_chunked(q, k, v, i_pre, f_pre, chunk)
    return h, state


def quantize_pack_ref(x, bits: int):
    """Oracle for the fused uplink: the reference §4.10 pipeline applied
    row-by-row to a ``[K, ...]`` stack — ``quantize_tensor`` then
    ``pack_codes``. Returns ``(packed [K, W], scale [K], zero [K])``; the
    fused kernel must match all three bit-for-bit. Jitted like the
    production ``quantize_population`` so the scale's constant division
    lowers identically (XLA's compiled divide-by-constant is a
    reciprocal-multiply, 1 ulp off the eager correctly-rounded divide)."""
    def one(row):
        codes, scale, zero = quantize_tensor(row, bits)
        return pack_codes(codes, bits), scale, zero
    return jax.jit(jax.vmap(one))(x.reshape(x.shape[0], -1))


def fusion_sgd_step_ref(params, preds, mask, y, w, *, lr: float):
    """Oracle for the fused fusion-MLP SGD step: the same hand-derived
    softmax-CE backward the kernel runs, written in plain jnp and jitted so
    both execute through XLA on this backend — the kernel must match
    bit-for-bit. ``tests/test_train_fused.py`` separately pins this closed
    form against the autodiff step at float tolerance.

    params: {"w1","b1","w2","b2"} with leading K axis; preds: [K, B, M, C];
    mask: [K, M]; y: [K, B]; w: [K, B]. Returns (params, loss [K])."""
    def one(p, bp, mk, by, bw):
        bb, mm, cc = bp.shape
        x = jnp.concatenate(
            [(bp * mk[None, :, None]).reshape(bb, mm * cc),
             jnp.broadcast_to(mk[None], (bb, mm))], axis=-1)
        z1 = x @ p["w1"] + p["b1"]
        h = jnp.maximum(z1, 0.0)
        logits = h @ p["w2"] + p["b2"]
        logp = jax.nn.log_softmax(logits)
        onehot = (by[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (bb, cc), 1)).astype(jnp.float32)
        ce = -jnp.sum(onehot * logp, axis=-1)
        denom = jnp.maximum(jnp.sum(bw), 1.0)
        loss = jnp.sum(bw * ce) / denom
        dlogits = (jnp.exp(logp) - onehot) * (bw / denom)[:, None]
        dw2 = h.T @ dlogits
        db2 = jnp.sum(dlogits, axis=0)
        dh = (dlogits @ p["w2"].T) * (z1 > 0.0).astype(jnp.float32)
        dw1 = x.T @ dh
        db1 = jnp.sum(dh, axis=0)
        return {"w1": p["w1"] - lr * dw1, "b1": p["b1"] - lr * db1,
                "w2": p["w2"] - lr * dw2, "b2": p["b2"] - lr * db2}, loss
    return jax.jit(jax.vmap(one))(
        jax.tree.map(lambda l: l.astype(jnp.float32), params),
        preds.astype(jnp.float32), mask.astype(jnp.float32),
        y.astype(jnp.int32), w.astype(jnp.float32))


def dequantize_weight_reduce_ref(packed, scale, zero, weights, *,
                                 bits: int, n: int):
    """Oracle for the fused downlink: materialize the full dequantized
    ``[K, n]`` stack (exactly what the fused path avoids) and take the
    Eq. 21 weighted mean. Flat ``[n]`` float32."""
    codes = jax.vmap(lambda p: unpack_codes(p, bits, n, (n,)))(packed)
    deq = codes.astype(jnp.float32) * scale[:, None].astype(jnp.float32) \
        + zero[:, None].astype(jnp.float32)
    w = weights.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    return jnp.einsum("k,kn->n", wn, deq)

"""Fused local-training hot path: masked-SGD round programs with donated
resident buffers, plus a one-kernel fusion-MLP SGD step.

The reference trainer (``repro.core.batched``) dispatches Local Learning as
a chain of separate jitted programs — one ``masked_batched_epoch`` /
``masked_fusion_epoch`` launch per epoch per bucket — and every launch
re-reads and re-writes the whole ``[K, ...]`` population param stack. This
module collapses each bucket's epoch chain into ONE program:

- :func:`fused_encoder_round` / :func:`fused_fusion_round` — all E epochs
  of per-client masked SGD in a single jitted program,
  ``scan(epochs) ∘ scan(steps)`` of exactly the reference step body
  (``value_and_grad`` of the same masked loss, same update arithmetic), so
  the fused trainer stays within float tolerance of the reference path and
  selection outcomes never move. ``donate_argnums=(0,)`` donates the
  resident param stack: XLA updates the population in place instead of
  allocating a second copy per launch (the caller must treat its input
  stack as consumed — ``tests/test_train_fused.py`` pins the deletion).
  Encoder gradients (BPTT through the LSTM scan / conv) stay XLA autodiff
  *inside* the fused program: for the encoders the win is dispatch
  collapse + donation, not a hand-written backward.
- :func:`fusion_sgd_step_pallas` — the fusion MLP's masked-SGD step as ONE
  Pallas kernel per client: forward, closed-form softmax-CE backward, and
  the parameter update in a single pass, gated by both the [M] presence
  mask and the [B] sample mask so padded lanes are exact no-ops. Runs in
  ``interpret=True`` on CPU like the other kernels in this package and
  compiles through Mosaic on TPU; :func:`fusion_sgd_step` routes through
  it when ``use_pallas()`` and otherwise falls back to the XLA autodiff
  step. The jitted manual-backward oracle lives in ``kernels/ref.py``
  (``fusion_sgd_step_ref``); the kernel must match it bit-for-bit and the
  oracle must match autodiff to float tolerance.

Parity contract (pinned in ``tests/test_train_fused.py``): fused round
programs ≡ the reference per-epoch chain at 1e-5 on params with identical
final-epoch losses to float tolerance; kernel ≡ oracle bit-identical over
odd shapes; ledger/selection outcomes of a fused run ≡ a reference run
exactly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.encoders import masked_encoder_loss
from repro.core.fusion import masked_fusion_loss

__all__ = ["fused_encoder_round", "fused_fusion_round", "fusion_sgd_step",
           "fusion_sgd_step_pallas"]


# ---------------------------------------------------------------------------
# fused multi-epoch round programs (the production path, all backends)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("lr",), donate_argnums=(0,))
def fused_encoder_round(params, xs, ys, ws, lr: float):
    """All E encoder epochs for one bucket in ONE donated program.

    params: pytree with leading K axis (donated — the caller's stack is
    consumed); xs: [K, E, S, B, ...]; ys/ws: [K, E, S, B] with 0/1 sample
    masks. Returns (new params, final-epoch per-step losses [K, S]) — the
    same pair E chained ``masked_batched_epoch`` calls produce, in one
    launch."""
    def client_round(p, ex, ey, ew):
        def epoch(pp, xyw):
            def step(q, s):
                x, y, w = s
                loss, g = jax.value_and_grad(masked_encoder_loss)(q, x, y, w)
                return jax.tree.map(lambda a, b: a - lr * b, q, g), loss
            return jax.lax.scan(step, pp, xyw)
        pe, losses = jax.lax.scan(epoch, p, (ex, ey, ew))   # losses [E, S]
        return pe, losses[-1]

    return jax.vmap(client_round)(params, xs, ys, ws)


@functools.partial(jax.jit, static_argnames=("lr",), donate_argnums=(0,))
def fused_fusion_round(params, preds, mask, ys, ws, lr: float):
    """All E fusion epochs for one bucket in ONE donated program.

    params: pytree with leading K axis (donated); preds: [K, E, S, B, M, C]
    per-epoch shuffled prediction schedules; mask: [K, M] presence;
    ys/ws: [K, E, S, B]."""
    def client_round(p, ep, mk, ey, ew):
        def epoch(pp, pyw):
            def step(q, s):
                bp, y, w = s
                loss, g = jax.value_and_grad(masked_fusion_loss)(
                    q, bp, mk, y, w)
                return jax.tree.map(lambda a, b: a - lr * b, q, g), loss
            return jax.lax.scan(step, pp, pyw)
        pe, losses = jax.lax.scan(epoch, p, (ep, ey, ew))
        return pe, losses[-1]

    return jax.vmap(client_round)(params, preds, mask, ys, ws)


# ---------------------------------------------------------------------------
# Pallas fusion-MLP SGD step (interpret=True on CPU; Mosaic on TPU)
# ---------------------------------------------------------------------------

def _fusion_sgd_kernel(w1_ref, b1_ref, w2_ref, b2_ref, p_ref, m_ref, y_ref,
                       sw_ref, ow1_ref, ob1_ref, ow2_ref, ob2_ref, loss_ref,
                       *, lr: float):
    """One client per grid step: forward, closed-form backward, update.

    The backward is the hand-derived softmax-CE chain — dlogits folds the
    normalized sample mask, so padded rows (w = 0) contribute neither loss
    nor gradient and a fully-padded step is an exact no-op update."""
    w1 = w1_ref[0].astype(jnp.float32)                  # [in_dim, H]
    b1 = b1_ref[0].astype(jnp.float32)                  # [H]
    w2 = w2_ref[0].astype(jnp.float32)                  # [H, C]
    b2 = b2_ref[0].astype(jnp.float32)                  # [C]
    preds = p_ref[0].astype(jnp.float32)                # [B, M, C]
    mk = m_ref[0].astype(jnp.float32)                   # [M]
    y = y_ref[0]                                        # [B] int32
    sw = sw_ref[0].astype(jnp.float32)                  # [B]
    bb, mm, cc = preds.shape

    x = jnp.concatenate([(preds * mk[None, :, None]).reshape(bb, mm * cc),
                         jnp.broadcast_to(mk[None], (bb, mm))], axis=-1)
    z1 = x @ w1 + b1
    h = jnp.maximum(z1, 0.0)
    logits = h @ w2 + b2
    logp = jax.nn.log_softmax(logits)
    onehot = (y[:, None] == lax.broadcasted_iota(jnp.int32, (bb, cc), 1)
              ).astype(jnp.float32)
    ce = -jnp.sum(onehot * logp, axis=-1)
    denom = jnp.maximum(jnp.sum(sw), 1.0)
    loss_ref[0, 0] = jnp.sum(sw * ce) / denom

    dlogits = (jnp.exp(logp) - onehot) * (sw / denom)[:, None]
    dw2 = h.T @ dlogits
    db2 = jnp.sum(dlogits, axis=0)
    dh = (dlogits @ w2.T) * (z1 > 0.0).astype(jnp.float32)
    dw1 = x.T @ dh
    db1 = jnp.sum(dh, axis=0)
    ow1_ref[0] = w1 - lr * dw1
    ob1_ref[0] = b1 - lr * db1
    ow2_ref[0] = w2 - lr * dw2
    ob2_ref[0] = b2 - lr * db2


def fusion_sgd_step_pallas(params, preds, mask, y, w, *, lr: float,
                           interpret: bool = True):
    """Fused masked-SGD step for a stacked fusion-MLP population.

    params: {"w1" [K, in_dim, H], "b1" [K, H], "w2" [K, H, C], "b2" [K, C]};
    preds: [K, B, M, C]; mask: [K, M]; y: [K, B] int32; w: [K, B] sample
    mask. Returns (updated params, per-client loss [K]) — bit-identical to
    ``ref.fusion_sgd_step_ref``."""
    kk, bb, mm, cc = preds.shape
    in_dim, hh = params["w1"].shape[1:]
    f32 = jnp.float32
    one = lambda *t: pl.BlockSpec((1,) + t, lambda k: (k,) + (0,) * len(t))
    nw1, nb1, nw2, nb2, loss = pl.pallas_call(
        functools.partial(_fusion_sgd_kernel, lr=float(lr)),
        grid=(kk,),
        in_specs=[one(in_dim, hh), one(hh), one(hh, cc), one(cc),
                  one(bb, mm, cc), one(mm), one(bb), one(bb)],
        out_specs=[one(in_dim, hh), one(hh), one(hh, cc), one(cc), one(1)],
        out_shape=[jax.ShapeDtypeStruct((kk, in_dim, hh), f32),
                   jax.ShapeDtypeStruct((kk, hh), f32),
                   jax.ShapeDtypeStruct((kk, hh, cc), f32),
                   jax.ShapeDtypeStruct((kk, cc), f32),
                   jax.ShapeDtypeStruct((kk, 1), f32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(params["w1"], params["b1"], params["w2"], params["b2"],
      preds, mask, y.astype(jnp.int32), w)
    return {"w1": nw1, "b1": nb1, "w2": nw2, "b2": nb2}, loss[:, 0]


@functools.partial(jax.jit, static_argnames=("lr",))
def _fusion_sgd_step_xla(params, preds, mask, y, w, lr: float):
    """XLA autodiff fallback: the reference per-client step, vmapped."""
    def one(p, bp, mk, by, bw):
        loss, g = jax.value_and_grad(masked_fusion_loss)(p, bp, mk, by, bw)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), loss
    return jax.vmap(one)(params, preds, mask, y, w)


def fusion_sgd_step(params, preds, mask, y, w, *, lr: float,
                    use_kernel: Optional[bool] = None
                    ) -> Tuple[dict, jnp.ndarray]:
    """Public fused step: Pallas on TPU, XLA autodiff elsewhere (override
    with ``use_kernel``). Same (params, loss [K]) contract either way."""
    from repro.kernels.ops import _interpret, use_pallas
    if use_kernel is None:
        use_kernel = use_pallas()
    if use_kernel:
        return fusion_sgd_step_pallas(params, preds, mask, y, w, lr=lr,
                                      interpret=_interpret())
    return _fusion_sgd_step_xla(params, preds, mask, y, w, lr)

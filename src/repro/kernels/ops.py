"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs in Python per grid step, validating the tiling and math.
On a real TPU backend they compile through Mosaic. ``use_pallas()`` gates
model-integration call sites (models default to the XLA path on CPU; tests
exercise the kernels explicitly).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mlstm_scan import mlstm_scan_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_pallas() -> bool:
    """Whether model code should route hot spots through the kernels."""
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 256):
    """q: [B,H,S,d]; k, v: [B,KV,T,d] (GQA via index maps). -> [B,H,S,d]."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_t", "block_w"))
def rglru_scan(x, w_a, w_x, lam, *, block_t: int = 128, block_w: int = 256):
    """Fused RG-LRU gates + time scan. x: [B,S,W] -> (h, h_last)."""
    return rglru_scan_pallas(x, w_a, w_x, lam, block_t=block_t,
                             block_w=block_w, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, i_pre, f_pre, *, chunk: int = 64):
    """Chunkwise-parallel mLSTM. Returns (h, (C, n, m))."""
    return mlstm_scan_pallas(q, k, v, i_pre, f_pre, chunk=chunk,
                             interpret=_interpret())

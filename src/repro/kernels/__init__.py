"""Pallas TPU kernels for the model-zoo compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
ops.py (jit wrappers, interpret=True on CPU), ref.py (pure-jnp oracles).

The paper itself (MFedMC) has no GPU-kernel contribution — its hot spot is
Shapley estimation on CPU-class clients, which is a fully-vectorized jnp
batched fusion forward (see DESIGN.md §6). These kernels serve the assigned
architectures' hot paths — attention, RG-LRU scan, mLSTM scan — plus the
federation's §4.10 communication hot path (comm.py: fused quantize+pack
uplink and dequantize+weight+reduce downlink) and its local-training hot
path (train.py: donated multi-epoch masked-SGD round programs and the
one-kernel fusion-MLP SGD step).
"""
from jax.experimental.pallas import tpu as _pltpu

# jax<0.5 names this TPUCompilerParams; the kernels use the modern name
if not hasattr(_pltpu, "CompilerParams"):          # pragma: no cover
    _pltpu.CompilerParams = _pltpu.TPUCompilerParams

from repro.kernels.comm import (dequantize_weight_reduce, payload_nbytes,
                                quantize_pack, quantize_pack_population,
                                quantize_pack_population_ef,
                                reduce_packed_population)
from repro.kernels.ops import (flash_attention, mlstm_scan, rglru_scan,
                               use_pallas)
from repro.kernels.train import (fused_encoder_round, fused_fusion_round,
                                 fusion_sgd_step)

__all__ = ["dequantize_weight_reduce", "flash_attention",
           "fused_encoder_round", "fused_fusion_round", "fusion_sgd_step",
           "mlstm_scan", "payload_nbytes", "quantize_pack",
           "quantize_pack_population", "quantize_pack_population_ef",
           "reduce_packed_population", "rglru_scan", "use_pallas"]

"""Pallas TPU mLSTM chunkwise-parallel scan.

TPU adaptation of the xLSTM matrix-memory recurrence (the paper ships a
fused CUDA *step* kernel; a per-timestep kernel would leave the MXU idle on
TPU). Within a chunk of L timesteps the recurrence unrolls into a masked,
decay-weighted attention-form matmul (MXU work); across chunks the kernel
carries the stabilized state (C [dk, dv], n [dk], m [1]) in VMEM scratch,
with the chunk axis sequential in the grid.

Tiling: grid = (B·H, S/L). Per grid step the kernel holds q/k/v chunk tiles
[L, d], two [L, L] weight tiles, and the [dk, dv] state — at L=64, d=128
that is ≈ 0.4 MiB of VMEM. All accumulation in f32.

Validated on CPU (interpret=True) against ``ref.mlstm_chunked_ref``
(== repro.models.ssm.mlstm_chunked).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
                  c_ref, n_ref, m_ref, *, chunk: int, eps: float = 1e-6):
    """The (C, n, m) state is carried in the *output* refs: their index maps
    revisit the same block every sequential chunk step, so the block stays
    resident in VMEM and the final visit leaves the end-of-sequence state."""
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32)                       # [L, dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                       # [L, dv]
    i_pre = i_ref[0, :, 0].astype(jnp.float32)             # [L]
    f_pre = f_ref[0, :, 0].astype(jnp.float32)

    C = c_ref[0]                                           # [dk, dv]
    n = n_ref[0]                                           # [dk, 1]
    m = m_ref[0, 0, 0]                                     # scalar

    logf = jax.nn.log_sigmoid(f_pre)
    b = jnp.cumsum(logf)                                   # [L]
    a = i_pre - b
    bL = b[-1]

    a_run_max = jax.lax.cummax(a, axis=0)
    m_loc = jnp.maximum(b + a_run_max, b + m)              # [L]

    # intra-chunk decay matrix D[t, s] = exp(b_t + a_s − m_loc_t), s ≤ t
    expo = b[:, None] + a[None, :] - m_loc[:, None]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >=
           jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    D = jnp.where(tri, jnp.exp(expo), 0.0)                 # [L, L]
    scale = q.shape[-1] ** -0.5
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    wgt = scores * D
    h_intra = jax.lax.dot_general(wgt, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: carried-state contribution
    inter_w = jnp.exp(b + m - m_loc)                       # [L]
    qf = q * scale
    qC = jax.lax.dot_general(qf, C, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, dv]
    qn = jax.lax.dot_general(qf, n, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)[:, 0]  # [L]
    h_num = h_intra + inter_w[:, None] * qC
    denom = jnp.maximum(jnp.abs(jnp.sum(wgt, axis=-1) + inter_w * qn),
                        jnp.exp(-m_loc)) + eps
    o_ref[0] = (h_num / denom[:, None]).astype(o_ref.dtype)

    # state update to end of chunk
    m_new = bL + jnp.maximum(m, jnp.max(a))
    state_w = jnp.exp(bL + a - m_new)                      # [L]
    decay = jnp.exp(bL + m - m_new)
    kw = k * state_w[:, None]                              # [L, dk]
    c_ref[0] = decay * C + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [dk, dv]
    n_ref[0] = decay * n + jnp.sum(kw, axis=0)[:, None]
    m_ref[0, 0, 0] = m_new


def mlstm_scan_pallas(q, k, v, i_pre, f_pre, *, chunk: int = 64,
                      interpret: bool = True):
    """q, k: [B, H, S, dk]; v: [B, H, S, dv]; gates: [B, H, S].

    Returns (h [B, H, S, dv], (C, n, m) final state). S % chunk must be 0
    (callers pad); falls back to the largest divisor otherwise.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk

    qf = q.reshape(b * h, s, dk)
    kf = k.reshape(b * h, s, dk)
    vf = v.reshape(b * h, s, dv)
    i_f = i_pre.reshape(b * h, s, 1)
    f_f = f_pre.reshape(b * h, s, 1)

    def tmap(bh, ic):
        return (bh, ic, 0)

    out, c_out, n_out, m_out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), tmap),
            pl.BlockSpec((1, chunk, dk), tmap),
            pl.BlockSpec((1, chunk, dv), tmap),
            pl.BlockSpec((1, chunk, 1), tmap),
            pl.BlockSpec((1, chunk, 1), tmap),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), tmap),
            pl.BlockSpec((1, dk, dv), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((1, dk, 1), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, dv), v.dtype),
            jax.ShapeDtypeStruct((b * h, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((b * h, dk, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * h, 1, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, i_f, f_f)

    return (out.reshape(b, h, s, dv),
            (c_out.reshape(b, h, dk, dv),
             n_out.reshape(b, h, dk, 1)[..., 0],
             m_out.reshape(b, h)))

"""Fused communication hot path: one-pass quantize+pack (uplink) and
dequantize+weight+reduce (downlink) for the §4.10/Eq. 21 round.

The reference upload path executes as separate programs that hand each
other *unpacked* code containers: ``quantize_population`` materializes
``[K, ...]`` codes in ``code_dtype`` (1 byte per parameter even at 4-bit
precision), and ``aggregate_quantized`` reads them back. The bit-packed
wire format (``pack_codes``) is *accounted* by the ledger but never
executed. This module closes both gaps:

- **Uplink** ``quantize_pack``: min/max → affine codes → bit-packed wire
  words in ONE pass over a flattened ``[K, n]`` leaf stack. The program
  boundary then carries exactly the wire format — ``ceil(n·bits/8)``
  packed bytes plus one (scale, zero) pair per tensor — instead of the
  unpacked container (2× smaller at 4 bits, 4× at 2 bits).
- **Downlink** ``dequantize_weight_reduce``: the Eq. 21 weighted mean
  straight from the packed words, per-tensor (scale, zero) and per-client
  weights (the async backend's staleness-discounted weights included):
  ``agg = Σ_k wn_k·(c_k·s_k + z_k) = einsum(wn·s, codes) + Σ_k wn_k·z_k``
  — no ``[K, ...]`` dequantized payload is ever materialized.

Both exist twice, same numerics:

- Pallas kernels (``*_pallas``), tiled BlockSpecs, run in
  ``interpret=True`` on CPU like the other kernels in this package and
  compile through Mosaic on TPU; pure-jnp oracles live in ``ref.py``.
- XLA population programs (``quantize_pack_population`` /
  ``reduce_packed_population``) — the production path the federation
  backends call on CPU, where a Python-interpreted kernel would lose to
  XLA's fused loops. Two deliberate CPU wins over the reference path:
  the row min/max is a single ``lax.reduce`` pass computing both bounds
  at once (min/max are exact reductions, so codes stay bit-identical to
  ``quantize_tensor``), and packing stays in the uint8 domain (a uint32
  intermediate would quadruple the pack traffic).

Parity contract (pinned in ``tests/test_comm_kernels.py``): packed words
bit-identical to ``quantize_pytree`` + ``pack_codes``, scales/zeros
bit-identical, aggregates within 1e-5 of ``aggregate_quantized``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import TENSOR_METADATA_BYTES, _check_bits, code_dtype

__all__ = ["quantize_pack", "dequantize_weight_reduce",
           "quantize_pack_population", "quantize_pack_population_ef",
           "reduce_packed_population", "payload_nbytes", "packed_width"]

_ROWS, _LANES = 8, 128
_TILE = _ROWS * _LANES           # flat elements per kernel tile


def _per(bits: int) -> int:
    """Codes per packed byte (1 = the code container IS the wire format)."""
    return 8 // bits if 8 % bits == 0 and bits < 8 else 1


def _wire_dtype(bits: int):
    return jnp.uint8 if _per(bits) > 1 else code_dtype(bits)


def packed_width(n: int, bits: int) -> int:
    """Wire words per row for an ``n``-element tensor (``ceil(n/per)``)."""
    _check_bits(bits)
    return -(-n // _per(bits))


# ---------------------------------------------------------------------------
# Pallas kernels (interpret=True on CPU; Mosaic on TPU)
# ---------------------------------------------------------------------------

def _quantize_pack_kernel(x_ref, packed_ref, scale_ref, zero_ref, *,
                          bits: int, per: int, n: int):
    """Two-phase pass over one row: grid = (K, 2, nt), tiles innermost.

    Phase 0 carries the running (min, max) in the (1, 1) scale/zero output
    blocks (their index maps revisit the same block every tile, the
    ``mlstm_scan`` state idiom) and finalizes ``scale = max((hi−lo)/levels,
    1e-12)`` on the last tile. Phase 1 re-reads each tile, encodes with the
    final affine, zero-masks the padded tail, and packs ``per`` codes per
    byte into the wire-word block."""
    p = pl.program_id(1)
    i = pl.program_id(2)
    nt = pl.num_programs(2)
    levels = 2 ** bits - 1
    tile_x = x_ref[0, 0].astype(jnp.float32)            # [ROWS, LANES]

    @pl.when((p == 0) & (i == 0))
    def _init():
        zero_ref[0, 0] = jnp.float32(jnp.inf)
        scale_ref[0, 0] = jnp.float32(-jnp.inf)

    @pl.when(p == 0)
    def _minmax():
        zero_ref[0, 0] = jnp.minimum(zero_ref[0, 0], jnp.min(tile_x))
        scale_ref[0, 0] = jnp.maximum(scale_ref[0, 0], jnp.max(tile_x))

    @pl.when((p == 0) & (i == nt - 1))
    def _finalize():
        scale_ref[0, 0] = jnp.maximum(
            (scale_ref[0, 0] - zero_ref[0, 0]) / levels, 1e-12)

    @pl.when(p == 1)
    def _encode_pack():
        lo = zero_ref[0, 0]
        sc = scale_ref[0, 0]
        codes = jnp.clip(jnp.round((tile_x - lo) / sc), 0, levels)
        rr = lax.broadcasted_iota(jnp.int32, tile_x.shape, 0)
        ll = lax.broadcasted_iota(jnp.int32, tile_x.shape, 1)
        pos = i * _TILE + rr * _LANES + ll              # flat row position
        codes = jnp.where(pos < n, codes, 0.0).astype(jnp.int32)
        lanes = codes.reshape(-1, per)                  # [TILE/per, per]
        word = lanes[:, 0]
        for l in range(1, per):
            word = word | (lanes[:, l] << (l * bits))
        packed_ref[0, 0] = word.astype(packed_ref.dtype)


def quantize_pack_pallas(x: jnp.ndarray, bits: int, *,
                         interpret: bool = True
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused uplink for a ``[K, ...]`` leaf stack.

    Returns ``(packed [K, W], scale [K], zero [K])`` with
    ``W = ceil(n/per)`` — exactly ``pack_codes``'s wire buffer per row
    (bit-identical, including its zero-padded tail)."""
    _check_bits(bits)
    kk = x.shape[0]
    flat = x.reshape(kk, -1)
    n = flat.shape[1]
    per = _per(bits)
    nt = max(-(-n // _TILE), 1)
    # edge-replicated pad: the tail never perturbs the row min/max, so no
    # masking is needed in the reduction phase (the encode phase masks)
    flat = jnp.pad(flat, ((0, 0), (0, nt * _TILE - n)), mode="edge")
    x3 = flat.reshape(kk, nt, _ROWS, _LANES)
    bp = _TILE // per

    packed, scale, zero = pl.pallas_call(
        functools.partial(_quantize_pack_kernel, bits=int(bits), per=per,
                          n=n),
        grid=(kk, 2, nt),
        in_specs=[pl.BlockSpec((1, 1, _ROWS, _LANES),
                               lambda k, p, i: (k, i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, bp), lambda k, p, i: (k, i, 0)),
            pl.BlockSpec((1, 1), lambda k, p, i: (k, 0)),
            pl.BlockSpec((1, 1), lambda k, p, i: (k, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kk, nt, bp), _wire_dtype(bits)),
            jax.ShapeDtypeStruct((kk, 1), jnp.float32),
            jax.ShapeDtypeStruct((kk, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x3)
    return (packed.reshape(kk, nt * bp)[:, :packed_width(n, bits)],
            scale[:, 0], zero[:, 0])


def _deq_reduce_kernel(packed_ref, a_ref, zsum_ref, out_ref, *,
                       bits: int, per: int):
    """grid = (nt, K), clients innermost: the (1, ROWS, LANES) output block
    is revisited for every k, initialized to the position-independent zero
    term ``Σ_k wn_k·z_k`` and accumulated with ``a_k = wn_k·s_k`` times the
    tile's unpacked codes — the Eq. 21 mean without a [K, ...] payload."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[0] = jnp.full(out_ref.shape[1:], zsum_ref[0, 0],
                              out_ref.dtype)

    words = packed_ref[0, 0].astype(jnp.int32)          # [TILE/per]
    mask = (1 << bits) - 1
    lanes = [(words >> (l * bits)) & mask for l in range(per)]
    codes = jnp.stack(lanes, axis=1).reshape(_ROWS, _LANES)
    out_ref[0] = out_ref[0] + a_ref[0, 0] * codes.astype(jnp.float32)


def dequantize_weight_reduce_pallas(packed: jnp.ndarray, scale: jnp.ndarray,
                                    zero: jnp.ndarray, weights: jnp.ndarray,
                                    *, bits: int, n: int,
                                    interpret: bool = True) -> jnp.ndarray:
    """Fused downlink: Eq. 21 weighted mean from the packed wire buffers.

    ``packed [K, W]``, per-row ``scale``/``zero``/``weights`` ``[K]`` →
    flat ``[n]`` float32 aggregate. Weights are sum-normalized with the
    aggregation guard ``max(Σw, 1e-12)`` (all-zero weight vectors — padded
    slots only — reduce to zeros, never NaN)."""
    _check_bits(bits)
    kk = packed.shape[0]
    per = _per(bits)
    bp = _TILE // per
    nt = max(-(-packed.shape[1] // bp), 1)
    p3 = jnp.pad(packed, ((0, 0), (0, nt * bp - packed.shape[1]))
                 ).reshape(kk, nt, bp)
    w = weights.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    a = (wn * scale.astype(jnp.float32)).reshape(kk, 1)
    zsum = jnp.sum(wn * zero.astype(jnp.float32)).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_deq_reduce_kernel, bits=int(bits), per=per),
        grid=(nt, kk),
        in_specs=[
            pl.BlockSpec((1, 1, bp), lambda i, k: (k, i, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _ROWS, _LANES), lambda i, k: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, _ROWS, _LANES), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(p3, a, zsum)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# XLA fused programs — same numerics, the CPU production path
# ---------------------------------------------------------------------------

def _minmax_rows(x2: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (min, max) in ONE reduce pass. min/max are exact reductions
    (no rounding, order-independent), so the results — and every code
    derived from them — are bit-identical to separate jnp.min/jnp.max."""
    def comp(acc, val):
        return (jnp.minimum(acc[0], val[0]), jnp.maximum(acc[1], val[1]))
    return lax.reduce((x2, x2),
                      (jnp.float32(jnp.inf), jnp.float32(-jnp.inf)),
                      comp, (1,))


def _quantize_rows(x2: jnp.ndarray, bits: int):
    """quantize_tensor's affine per row of a [K, n] stack (bit-identical)."""
    levels = 2 ** int(bits) - 1
    xf = x2.astype(jnp.float32)
    lo, hi = _minmax_rows(xf)
    scale = jnp.maximum((hi - lo) / levels, 1e-12)
    codes = jnp.clip(jnp.round((xf - lo[:, None]) / scale[:, None]),
                     0, levels)
    return codes.astype(code_dtype(bits)), scale, lo


def _pack_rows(codes2: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Row-wise pack_codes in the uint8 domain (no uint32 intermediates —
    on CPU those quadruple the pack traffic and erase the fused win)."""
    per = _per(bits)
    if per <= 1:
        return codes2
    kk, n = codes2.shape
    pad = (-n) % per
    lanes = jnp.pad(codes2, ((0, 0), (0, pad))).reshape(kk, -1, per)
    word = lanes[:, :, 0]
    for l in range(1, per):
        word = word | (lanes[:, :, l] << jnp.uint8(l * bits))
    return word


def _unpack_rows(packed2: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    per = _per(bits)
    if per <= 1:
        return packed2
    kk = packed2.shape[0]
    mask = jnp.uint8(2 ** bits - 1)
    lanes = [(packed2 >> jnp.uint8(l * bits)) & mask for l in range(per)]
    return jnp.stack(lanes, axis=2).reshape(kk, -1)[:, :n]


def _uplink_leaf(leaf: jnp.ndarray, bits: int):
    codes, scale, zero = _quantize_rows(
        leaf.reshape(leaf.shape[0], -1), bits)
    return _pack_rows(codes, bits), scale, zero


def _tree_uplink(stacked, bits: int):
    flat, treedef = jax.tree_util.tree_flatten(stacked)
    ps, ss, zs = [], [], []
    for leaf in flat:
        p, s, z = _uplink_leaf(leaf, bits)
        ps.append(p)
        ss.append(s)
        zs.append(z)
    unflat = functools.partial(jax.tree_util.tree_unflatten, treedef)
    return unflat(ps), unflat(ss), unflat(zs)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_pack_population(stacked, *, bits: int):
    """Fused uplink over a stacked ``[K, ...]`` pytree, one program:
    single-pass min/max, affine encode, and the bit-packed wire format per
    leaf. Returns ``(packed, scales, zeros)`` pytrees — packed leaves are
    ``[K, ceil(n·bits/8)]`` wire buffers (bit-identical to
    ``vmap(pack_codes)`` over ``quantize_pytree`` codes), scales/zeros
    ``[K]``. Only the wire format crosses the program boundary."""
    return _tree_uplink(stacked, bits)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_pack_population_ef(stacked, residuals, *, bits: int):
    """Fused uplink with error feedback: quantize ``params + residual``,
    pack, and return the new residual ``compensated − dequantized`` (what
    the wire could not carry). Same math as
    ``quantize_population_with_error_feedback`` — codes and residuals stay
    bit-identical — but only packed wire buffers leave the program."""
    comp = jax.tree.map(lambda a, b: a.astype(jnp.float32) + b,
                        stacked, residuals)
    flat, treedef = jax.tree_util.tree_flatten(comp)
    ps, ss, zs, rs = [], [], [], []
    for leaf in flat:
        kk = leaf.shape[0]
        codes, scale, zero = _quantize_rows(leaf.reshape(kk, -1), bits)
        sent = (codes.astype(jnp.float32) * scale[:, None] + zero[:, None])
        ps.append(_pack_rows(codes, bits))
        ss.append(scale)
        zs.append(zero)
        rs.append((leaf.reshape(kk, -1) - sent).reshape(leaf.shape))
    unflat = functools.partial(jax.tree_util.tree_unflatten, treedef)
    return unflat(ps), unflat(ss), unflat(zs), unflat(rs)


@functools.partial(jax.jit, static_argnames=("bits", "shapes"))
def reduce_packed_population(packed, scales, zeros, weights, *, bits: int,
                             shapes: Tuple[Tuple[int, ...], ...]):
    """Fused downlink over the whole payload pytree: per leaf, unpack and
    contract ``einsum(wn·s, codes) + Σ_k wn_k·z_k`` — the Eq. 21 weighted
    mean with the affine applied to the reduced sums, never materializing a
    ``[K, ...]`` dequantized stack. ``shapes`` restores each leaf's
    per-client shape (static; the packed width alone is ambiguous)."""
    w = weights.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    flat_p, treedef = jax.tree_util.tree_flatten(packed)
    flat_s = treedef.flatten_up_to(scales)
    flat_z = treedef.flatten_up_to(zeros)
    out = []
    for p, s, z, shp in zip(flat_p, flat_s, flat_z, shapes):
        n = 1
        for d in shp:
            n *= d
        codes = _unpack_rows(p, bits, n).astype(jnp.float32)
        agg = (jnp.einsum("k,kn->n", wn * s.astype(jnp.float32), codes)
               + jnp.sum(wn * z.astype(jnp.float32)))
        out.append(agg.reshape(shp))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# public single-leaf wrappers (kernel on TPU / by request; XLA otherwise)
# ---------------------------------------------------------------------------

def quantize_pack(x: jnp.ndarray, bits: int, *, use_kernel=None):
    """Fused uplink for one ``[K, ...]`` leaf stack →
    ``(packed [K, W], scale [K], zero [K])``. ``use_kernel=None`` routes
    through the Pallas kernel only on TPU (the interpret-mode kernel is a
    correctness artifact on CPU, not a fast path); tests pass ``True``."""
    from repro.kernels.ops import _interpret, use_pallas
    if use_kernel is None:
        use_kernel = use_pallas()
    if use_kernel:
        return quantize_pack_pallas(x, bits, interpret=_interpret())
    return _jit_uplink_leaf(x, bits=int(bits))


def dequantize_weight_reduce(packed, scale, zero, weights, *, bits: int,
                             n: int, use_kernel=None):
    """Fused downlink for one leaf: Eq. 21 mean ``[n]`` from packed words,
    (scale, zero) and client weights — staleness-discounted weights plug in
    unchanged (they are just ``w_k``)."""
    from repro.kernels.ops import _interpret, use_pallas
    if use_kernel is None:
        use_kernel = use_pallas()
    if use_kernel:
        return dequantize_weight_reduce_pallas(packed, scale, zero, weights,
                                               bits=bits, n=n,
                                               interpret=_interpret())
    return _jit_reduce_leaf(packed, scale, zero, weights, bits=int(bits),
                            n=int(n))


@functools.partial(jax.jit, static_argnames=("bits",))
def _jit_uplink_leaf(x, *, bits: int):
    return _uplink_leaf(x, bits)


@functools.partial(jax.jit, static_argnames=("bits", "n"))
def _jit_reduce_leaf(packed, scale, zero, weights, *, bits: int, n: int):
    w = weights.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    codes = _unpack_rows(packed, bits, n).astype(jnp.float32)
    return (jnp.einsum("k,kn->n", wn * scale.astype(jnp.float32), codes)
            + jnp.sum(wn * zero.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# bytes-moved accounting
# ---------------------------------------------------------------------------

def payload_nbytes(*trees) -> int:
    """Bytes of every device buffer in the given payload pytrees — what
    actually crosses the uplink program boundary. For the fused path that
    is the bit-packed wire buffers + [K] scale/zero vectors; for the
    reference path, the unpacked code containers. Feeds the
    ``repro.core.hostsync`` bytes-moved counter."""
    import numpy as np
    total = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            nbytes = getattr(leaf, "nbytes", None)
            if nbytes is None:      # ShapeDtypeStruct (roofline metering)
                nbytes = int(np.prod(leaf.shape, dtype=np.int64)
                             * np.dtype(leaf.dtype).itemsize)
            total += int(nbytes)
    return total


def wire_payload_bytes(template, bits: int, k: int) -> int:
    """Roofline lower bound for a K-client upload of ``template``: exact
    §4.10 wire bytes (packed codes + per-tensor metadata) — the fewest
    bytes any uplink implementation can move at this precision."""
    from repro.core.quantize import pytree_wire_bytes
    return k * pytree_wire_bytes(template, bits)


def container_payload_bytes(template, bits: int, k: int) -> int:
    """What the reference path moves instead: unpacked ``code_dtype``
    containers (+ the same per-tensor metadata)."""
    import numpy as np
    if bits >= 32:
        return k * sum(int(np.prod(np.shape(l), dtype=np.int64) or 1) * 4
                       for l in jax.tree_util.tree_leaves(template))
    total = 0
    for leaf in jax.tree_util.tree_leaves(template):
        n = int(np.prod(np.shape(leaf), dtype=np.int64)) \
            if np.shape(leaf) else 1
        total += n * np.dtype(code_dtype(bits)).itemsize \
            + TENSOR_METADATA_BYTES
    return k * total

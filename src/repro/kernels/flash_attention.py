"""Pallas TPU flash attention: blocked causal/windowed attention with
online-softmax accumulators in VMEM.

Tiling: grid = (B·H, S/block_q, T/block_k); the (block_q × block_k) score
tile lives in VREGs, the f32 accumulators (o, m, l) persist in VMEM scratch
across the sequential k-block axis. GQA is handled in the *index maps*:
query head h reads kv head h // G, so grouped K/V are never materialized
per-head in HBM. block_q/block_k default to 128/256 — MXU-aligned (128
lanes) with the f32 working set (q + k + v + o tiles ≈
(bq·d + 2·bk·d + bq·d)·4B ≈ 0.5 MiB at d=128) comfortably inside the
~16 MiB/core VMEM budget.

Validated on CPU with interpret=True against ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, causal: bool, window: int,
                 num_k_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # [bq, d]
    k = k_ref[0].astype(jnp.float32)                     # [bk, d]
    v = v_ref[0].astype(jnp.float32)                     # [bk, d]
    scale = q.shape[-1] ** -0.5

    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    rel = q_pos - k_pos
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= rel >= 0
    if window > 0:
        mask &= rel < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)                        # [bq, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 256,
                           interpret: bool = True):
    """q: [B, H, S, d]; k, v: [B, KV, T, d] with H a multiple of KV.

    Returns [B, H, S, d]. Ragged S/T fall back to the largest divisor tile.
    """
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    t = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    while s % block_q:
        block_q //= 2
    while t % block_k:
        block_k //= 2
    nq, nk = s // block_q, t // block_k

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * kv, t, d)
    vf = v.reshape(b * kv, t, d)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        # GQA: query head bh = b·H + h reads kv row b·KV + h//G
        return ((bh // h) * kv + (bh % h) // g, ik, 0)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)

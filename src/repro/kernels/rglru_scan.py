"""Pallas TPU RG-LRU fused gate + scan kernel.

The XLA path (repro.models.hybrid) computes the two gate projections, the
log-decay, and the associative scan as separate HLO ops — three extra
HBM round-trips of the [B, S, W] activations. This kernel fuses them:

    r_t = σ(x_t · W_a)        i_t = σ(x_t · W_x)
    log a_t = −c · softplus(Λ) · r_t
    h_t = a_t · h_{t−1} + sqrt(1 − a_t²) · (i_t · x_t)

Tiling: grid = (B, W/block_w, S/block_t) with the time axis sequential.
Per step the kernel loads one full-width x tile [block_t, W] (needed for the
gate matmuls) plus the [W, block_w] slices of W_a/W_x, computes the gates on
the MXU, and runs the recurrence over the tile's rows with the carried state
h [1, block_w] resident in VMEM scratch. VMEM at W=2560, block_t=128,
block_w=256: x 1.3 MiB + 2 weight slices 5.2 MiB + tile outputs ≈ 7 MiB.

The hidden state recurrence is done with a size-block_t unrolled loop of
vector ops (diagonal recurrence — no MXU work), which is the TPU-idiomatic
replacement for the CUDA per-timestep kernel in the Griffin paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RGLRU_C = 8.0


def _rglru_kernel(x_full_ref, x_ref, wa_ref, wx_ref, lam_ref, o_ref, h_ref,
                  *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x_full = x_full_ref[...][0].astype(jnp.float32)       # [bt, W]
    x_blk = x_ref[...][0].astype(jnp.float32)             # [bt, bw]
    wa = wa_ref[...].astype(jnp.float32)                  # [W, bw]
    wx = wx_ref[...].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)                # [1, bw]

    r = jax.nn.sigmoid(jax.lax.dot_general(
        x_full, wa, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32))              # [bt, bw]
    i = jax.nn.sigmoid(jax.lax.dot_general(
        x_full, wx, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(lam) * r           # [bt, bw]
    a = jnp.exp(log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * i * x_blk

    def row(tt, h):
        h = a[tt] * h + gx[tt]
        pl.store(o_ref, (pl.dslice(0, 1), pl.dslice(tt, 1), pl.dslice(None)),
                 h[None, None, :].astype(o_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, block_t, row, h_ref[...][0])
    h_ref[...] = h[None]


def rglru_scan_pallas(x, w_a, w_x, lam, *, block_t: int = 128,
                      block_w: int = 256, interpret: bool = True):
    """x: [B, S, W]; w_a, w_x: [W, W]; lam: [W]. Returns (h [B,S,W], h_last)."""
    b, s, w = x.shape
    block_t = min(block_t, s)
    block_w = min(block_w, w)
    while s % block_t:
        block_t //= 2
    while w % block_w:
        block_w //= 2
    nt, nw = s // block_t, w // block_w

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=(b, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, w), lambda ib, iw, it: (ib, it, 0)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((w, block_w), lambda ib, iw, it: (0, iw)),
            pl.BlockSpec((w, block_w), lambda ib, iw, it: (0, iw)),
            pl.BlockSpec((1, block_w), lambda ib, iw, it: (0, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct((b, s, w), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, x, w_a, w_x, lam.reshape(1, w))
    return out, out[:, -1].astype(jnp.float32)

from repro.optim.optimizers import (Optimizer, adamw, apply_updates, sgd,
                                    sgd_momentum)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = ["Optimizer", "adamw", "apply_updates", "sgd", "sgd_momentum",
           "constant", "cosine_decay", "warmup_cosine"]

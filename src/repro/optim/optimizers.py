"""Pure-pytree optimizers (no external deps): SGD(+momentum), AdamW.

An ``Optimizer`` is a pair of pure functions over pytrees; moments are kept
in f32 regardless of param dtype (mixed-precision safe) and get their own
ZeRO-1 sharding via ``repro.sharding.opt_state_pspecs``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]   # (grads, state, params)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def _f32_like(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def sgd(lr) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"]
        eta = lr_fn(step)
        updates = jax.tree.map(lambda g: -eta * g.astype(jnp.float32), grads)
        return updates, {"step": step + 1}

    return Optimizer(init, update)


def sgd_momentum(lr, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": _f32_like(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"]
        eta = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        updates = jax.tree.map(lambda m: -eta * m, mu)
        return updates, {"mu": mu, "step": step + 1}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype=jnp.float32) -> Optimizer:
    """``moment_dtype=bf16`` halves optimizer-state HBM (§Perf: arctic's
    Adam state is 15 GiB/chip in f32 — the largest args contribution);
    update math still runs in f32."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def moments_like(tree):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, moment_dtype), tree)

    def init(params):
        return {"m": moments_like(params), "v": moments_like(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = lr_fn(step)
        m = jax.tree.map(
            lambda m_, g: b1 * m_.astype(jnp.float32)
            + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_.astype(jnp.float32)
            + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            return -eta * (m_ / c1 / (jnp.sqrt(v_ / c2) + eps)
                           + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, m, v, params)
        store = jax.tree.map(lambda x: x.astype(moment_dtype), (m, v))
        return updates, {"m": store[0], "v": store[1], "step": step}

    return Optimizer(init, update)

"""PartitionSpec rules for params, optimizer state, batches, and caches.

Axis conventions (see ``launch/mesh.py``):
    pod   — cross-pod axis (multi-pod mesh only)
    data  — within-pod data parallelism (batch / FSDP / context-parallel)
    model — tensor/expert parallelism

Param rules are matched on (leaf name, ndim). Leading stack axes (layer /
period stacks) map to ``None`` by right-aligning the rule with the shape.

A fourth, *federation-level* axis lives here too:
    clients — the population axis of the sharded federation backend
              (``repro.core.sharded``): resident ``[G, ...]`` encoder /
              fusion stacks and ``[K, M]`` decision blocks split row-wise
              across devices of a 1-D client mesh.
"""
from __future__ import annotations

import contextvars
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

# ---------------------------------------------------------------------------
# client axis (sharded federation population)
# ---------------------------------------------------------------------------

CLIENT_AXIS = "clients"


def client_mesh(n_shards: Optional[int] = None) -> Mesh:
    """A 1-D mesh over the first ``n_shards`` devices (all devices when
    ``None``/0) with the federation's ``clients`` axis."""
    devices = jax.devices()
    n = len(devices) if not n_shards else int(n_shards)
    if not 1 <= n <= len(devices):
        raise ValueError(f"mesh_clients={n_shards} needs 1..{len(devices)} "
                         f"devices (have {len(devices)}; force more with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    return Mesh(np.asarray(devices[:n]), (CLIENT_AXIS,))


def client_spec() -> P:
    """Leading-axis client sharding — rows of the [K, M] decision blocks and
    the resident [G, ...] parameter stacks."""
    return P(CLIENT_AXIS)


def shard_rows(tree, mesh: Mesh):
    """Pin every leaf's leading axis to the client axis. Used after host
    scatters (`.at[idx].set`) whose output sharding XLA would otherwise
    choose freely."""
    sharding = NamedSharding(mesh, client_spec())
    return jax.tree.map(lambda v: jax.device_put(v, sharding), tree)


def shard_slots(shard_ids: Sequence[int], n_shards: int
                ) -> Tuple[List[int], int]:
    """Shard-major slot layout for an uneven client→shard assignment.

    Item i (living on ``shard_ids[i]``) gets slot ``d * G + j`` where G is
    the *largest* per-shard group (so every shard's block is the same size —
    the uniform-block layout ``shard_map`` requires) and j counts the item's
    shard-local position in input order. Returns (slots, padded total G·D);
    unassigned slots are padding rows that callers must mask to weight 0.
    With one shard the layout degenerates to the identity (no padding), so
    a 1×1 mesh reproduces the engine backend's bucket layout exactly."""
    per: List[List[int]] = [[] for _ in range(n_shards)]
    for i, d in enumerate(shard_ids):
        per[int(d)].append(i)
    group = max([len(p) for p in per] + [1])
    slots = [0] * len(list(shard_ids))
    for d, items in enumerate(per):
        for j, i in enumerate(items):
            slots[i] = d * group + j
    return slots, group * n_shards


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# name -> (rule for trailing dims). ndim disambiguates MoE (3D) from FFN (2D).
_RULES_2D = {
    # embed is sharded on D (not V): the token-gather gradient is a scatter
    # over the V dim, which XLA materializes unsharded f32 when V is the
    # sharded dim (measured: 1.9 GiB/chip on phi3). D-sharding keeps the
    # scatter local; the small all-gather of [B,S,D/16] after lookup is cheap.
    "embed": (None, "model"),
    "lm_head": (None, "model"),
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wo": ("model", None),
    "w_in": (None, "model"), "w_gate": (None, "model"),
    "w_out": ("model", None),
    "w_up": (None, "model"), "w_down": ("model", None),
    "w_rec": (None, "model"), "w_zifo": (None, "model"),
    "frame_proj": (None, "model"),
    "w_uq": (None, "model"), "w_uk": (None, "model"), "w_uv": (None, "model"),
    "w_dq": (None, None), "w_dkv": (None, None), "w_kr": (None, None),
    "router": (None, None),
    "w": (None, "model"),          # depthwise conv [width, dim]
}
_RULES_3D = {
    # MoE expert tensors [E, D, F] / [E, F, D]: expert-parallel on model,
    # FSDP-style second shard on data (Arctic would not fit otherwise).
    "w_in": ("model", None, "data"),
    "w_gate": ("model", None, "data"),
    "w_out": ("model", "data", None),
    # sLSTM recurrent block-diag [H, hd, hd]: small, replicated
    "r_z": (None, None, None), "r_i": (None, None, None),
    "r_f": (None, None, None), "r_o": (None, None, None),
}


def _leaf_rule(name: str, ndim: int, in_moe: bool) -> Tuple:
    if (in_moe or name.startswith("r_")) and name in _RULES_3D:
        return _RULES_3D[name]
    if name in _RULES_2D:
        return _RULES_2D[name]
    return ()                       # replicate (norms, biases, scalars)


def _right_align(rule: Tuple, ndim: int) -> P:
    pad = (None,) * (ndim - len(rule))
    return P(*(pad + tuple(rule)))


def param_pspecs(param_tree) -> Any:
    """PartitionSpec pytree mirroring ``param_tree`` (arrays or SDS)."""
    def spec(path, leaf):
        name = None
        keys = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        in_moe = "moe" in keys and name not in ("w",)  # dense_residual ffn keeps 2D rule
        in_moe = in_moe and "dense_residual" not in keys
        rule = _leaf_rule(name or "", leaf.ndim, in_moe)
        if len(rule) > leaf.ndim:
            rule = rule[-leaf.ndim:] if leaf.ndim else ()
        return _right_align(rule, leaf.ndim)

    return jax.tree_util.tree_map_with_path(spec, param_tree)


def opt_state_pspecs(param_tree, mesh) -> Any:
    """ZeRO-1 style: optimizer moments additionally sharded over ``data``
    on the largest dim the param rule leaves replicated (when divisible)."""
    data = mesh.shape.get("data", 1)
    base = param_pspecs(param_tree)

    def zero1(leaf, spec):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        if "data" in dims:
            return P(*dims)
        # choose the largest replicated dim divisible by the data axis
        best, best_size = None, 0
        for i, d in enumerate(dims):
            if d is None and leaf.shape[i] % data == 0 and leaf.shape[i] > best_size:
                best, best_size = i, leaf.shape[i]
        if best is not None and best_size >= data:
            dims[best] = "data"
        return P(*dims)

    return jax.tree.map(zero1, param_tree, base)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def batch_pspec(shape: InputShape, cfg: ModelConfig, multi_pod: bool) -> Any:
    dp = dp_axes(multi_pod)
    specs = {}
    if shape.kind == "train":
        specs = {"tokens": P(dp, None), "targets": P(dp, None)}
    elif shape.kind == "prefill":
        specs = {"tokens": P(dp, None)}
    else:
        if shape.global_batch == 1:
            specs = {"tokens": P(None, None)}
        else:
            specs = {"tokens": P(dp, None)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeddings"] = P(dp, None, None)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = P(dp, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, cache_tree, shape: InputShape,
                 multi_pod: bool, *, seq_shard: bool = True) -> Any:
    """Sharding for the decode cache.

    batch > 1: shard the batch dim over (pod, data); by default (the §Perf
    hillclimb winner, 'cache_seq_sharded') the cache *sequence* dim is
    additionally sharded over 'model' — KV heads rarely divide the model
    axis, so the context dim is the only way the cache uses those chips'
    HBM. Measured on granite-moe-1b decode_32k: collective bytes −99.9%,
    peak 36 → 2.8 GiB. ``seq_shard=False`` restores the replicated-cache
    baseline for comparison.
    batch == 1 (long_500k): context parallelism — shard the cache sequence
    dim over every available axis so the 500k context fits; attention then
    contracts a sharded dim (XLA inserts the combine collective).
    """
    dp = dp_axes(multi_pod)
    ctx_axes = dp + ("model",)
    b = shape.global_batch

    def spec(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        if name == "pos" or leaf.ndim == 0:
            return P()
        if name == "kv_pos":
            return P(None)
        dims = [None] * leaf.ndim
        # find the batch dim: first dim of size b after leading stack dims
        batch_dim = None
        for i, s in enumerate(leaf.shape):
            if s == b and i <= 2:
                batch_dim = i
                break
        if name in ("k", "v", "c_kv", "k_rope"):
            # [*stack, B, T, ...]
            if batch_dim is None:
                batch_dim = leaf.ndim - 3 if name in ("k", "v") else leaf.ndim - 2
            t_dim = batch_dim + 1
            if b > 1:
                dims[batch_dim] = dp if len(dp) > 1 else dp[0]
                if seq_shard and \
                        leaf.shape[t_dim] % _axes_size(("model",)) == 0 and \
                        leaf.shape[t_dim] >= 4 * _axes_size(("model",)):
                    dims[t_dim] = "model"
            elif leaf.shape[t_dim] % _axes_size(ctx_axes) == 0 and \
                    leaf.shape[t_dim] >= 4 * _axes_size(ctx_axes):
                dims[t_dim] = ctx_axes
            return P(*dims)
        # recurrent states: [*stack, B, ...] — shard batch if possible
        if batch_dim is not None and b > 1:
            dims[batch_dim] = dp if len(dp) > 1 else dp[0]
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


_MESH_SIZES = {}


def _axes_size(axes) -> int:
    n = 1
    for a in axes:
        n *= _MESH_SIZES.get(a, 1)
    return n


def register_mesh(mesh) -> None:
    """Record axis sizes so cache_pspecs can reason about divisibility."""
    global _MESH_SIZES
    _MESH_SIZES = dict(mesh.shape)


# ---------------------------------------------------------------------------
# activation sharding constraint hook (used inside model forward)
# ---------------------------------------------------------------------------

_ACT_SPEC: contextvars.ContextVar[Optional[P]] = \
    contextvars.ContextVar("act_spec", default=None)


def set_activation_spec(spec: Optional[P]):
    """Set the residual-stream constraint, e.g. P(("pod","data"), None, "model")
    for Megatron-style sequence-sharded activations. Returns a token for reset."""
    return _ACT_SPEC.set(spec)


def constrain(h):
    spec = _ACT_SPEC.get()
    if spec is None:
        return h
    return jax.lax.with_sharding_constraint(h, spec)


# MoE dispatch buffer [E, C, D]: experts on 'model', capacity on 'data'
# (§Perf: without this XLA replicates the buffer — arctic's 49 GiB temp)
_MOE_SPEC: contextvars.ContextVar[Optional[P]] = \
    contextvars.ContextVar("moe_spec", default=None)


def set_moe_buffer_spec(spec: Optional[P]):
    return _MOE_SPEC.set(spec)


def constrain_moe_buffer(buf):
    spec = _MOE_SPEC.get()
    if spec is None:
        return buf
    return jax.lax.with_sharding_constraint(buf, spec)

from repro.sharding.partition import (batch_pspec, cache_pspecs, constrain,
                                      param_pspecs, set_activation_spec,
                                      opt_state_pspecs)

__all__ = ["batch_pspec", "cache_pspecs", "constrain", "param_pspecs",
           "set_activation_spec", "opt_state_pspecs"]

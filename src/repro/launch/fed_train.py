"""Datacenter federated training: MFedMC's multi-modality round as one
jit'd mesh program.

    PYTHONPATH=src python -m repro.launch.fed_train --dataset ucihar \
        --rounds 3 [--devices 8] [--gamma 1] [--hierarchical]

The K-client population is stacked and sharded over the mesh 'data' axis,
*per modality*: every modality's encoder population trains E·steps of
vmapped local SGD and aggregates through its own masked weighted all-reduce
(Eq. 21), all inside a single XLA program
(``repro.core.distributed.make_multimodal_federated_round``). The
per-(client, modality) selection mask is the joint modality-and-client
selection (Eq. 20), so the collectives' useful traffic shrinks by the
paper's γ/M̄·δ factor per modality.

Selection itself stays host-side — it consumes K·M scalars, not tensors.
The modality-impact criterion uses the per-round loss improvement as a
cheap Shapley proxy (the exact interventional Shapley of the simulator
needs the fusion module, which never leaves the edge); size and recency
criteria are the paper's Eqs. 10–11 unchanged.

This launcher is the bridge between the paper-faithful simulator
(``repro.core.rounds``) and the multi-pod dry-run: the same round lowers
on the production mesh.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ucihar")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--gamma", type=int, default=1,
                    help="modality uploads per client (top-γ, Eq. 16)")
    ap.add_argument("--modalities", default="all",
                    help="comma-separated subset (default: every modality)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use what exists)")
    ap.add_argument("--hierarchical", action="store_true")
    args = ap.parse_args(argv)
    if args.gamma < 1:
        ap.error("--gamma must be >= 1")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.aggregation import CommLedger
    from repro.core.distributed import (make_multimodal_federated_round,
                                        selection_masks)
    from repro.core.encoders import encoder_bytes, encoder_eval, init_encoder
    from repro.core.selection import (modality_priority, select_clients,
                                      select_top_gamma)
    from repro.data import get_dataset_spec, make_federation

    spec = get_dataset_spec(args.dataset)
    clients = make_federation(args.dataset, "iid",
                              samples_per_client=args.batch * args.steps)
    if args.modalities == "all":
        modalities = list(spec.modality_names)
    else:
        modalities = [m.strip() for m in args.modalities.split(",")]
        unknown = set(modalities) - set(spec.modality_names)
        if unknown:
            raise SystemExit(f"unknown modalities: {sorted(unknown)}")
    K, M = len(clients), len(modalities)

    n_dev = len(jax.devices())
    data_ax = 1
    for d in range(min(n_dev, K), 0, -1):
        if K % d == 0 and n_dev % d == 0:
            data_ax = d
            break
    mesh = jax.make_mesh((data_ax, n_dev // data_ax), ("data", "model"))
    print(f"{K} clients x {M} modalities on mesh {dict(mesh.shape)}")

    # ---- stack the federation: {modality: [K, ...]} pytrees/batches ----
    params, batches, weight, sizes = {}, {}, {}, {}
    for i, m in enumerate(modalities):
        feat = clients[0].modalities[m].shape[1:]
        enc = init_encoder(jax.random.key(i), feat, spec.num_classes)
        sizes[m] = encoder_bytes(enc)
        params[m] = jax.tree.map(lambda x: jnp.stack([x] * K), enc)
        batches[m] = {
            "x": jnp.stack([c.modalities[m].reshape(
                args.steps, args.batch, *feat) for c in clients]),
            "y": jnp.stack([c.labels.reshape(args.steps, args.batch)
                            for c in clients]),
        }
        weight[m] = jnp.asarray([c.num_samples for c in clients],
                                jnp.float32)

    round_fn = jax.jit(make_multimodal_federated_round(
        mesh, local_steps=args.steps, lr=0.1,
        hierarchical=args.hierarchical))
    size_vec = np.array([sizes[m] for m in modalities], np.float64)
    ledger = CommLedger()
    with mesh:
        # round 1 is the cold start: everyone uploads everything
        select = {m: jnp.ones((K,), jnp.float32) for m in modalities}
        last_upload = np.full((K, M), -1, np.int64)      # Eq. 11 state
        prev_loss = None                                  # [K, M]
        for t in range(1, args.rounds + 1):
            t0 = time.time()
            params, agg, losses = round_fn(params, batches, select, weight)

            # ---- per-modality uplink accounting for THIS round's mask ----
            # (recency marks the round a pair actually uploads, Eq. 11)
            per_mod_bytes = {}
            for i, m in enumerate(modalities):
                mask = np.asarray(select[m])
                n_up = int(mask.sum())
                per_mod_bytes[m] = n_up * sizes[m]
                ledger.record(per_mod_bytes[m], n_up)
                last_upload[mask > 0, i] = t
            ledger.rounds = t

            # ---- joint selection for the next round (Eqs. 13-20) ----
            cur = np.stack([np.asarray(losses[m]) for m in modalities],
                           axis=1)                        # [K, M]
            impact = (np.zeros_like(cur) if prev_loss is None
                      else np.maximum(prev_loss - cur, 0.0))
            choices = {}
            for k in range(K):
                rec = (t - last_upload[k] - 1).astype(np.float64)
                prio = modality_priority(impact[k], size_vec, rec, t,
                                         1 / 3, 1 / 3, 1 / 3)
                choices[k] = select_top_gamma(prio, modalities, args.gamma)
            rep_loss = {k: float(min(cur[k, modalities.index(m)]
                                     for m in choices[k]))
                        for k in range(K)}
            chosen = select_clients(rep_loss, args.delta)
            select = selection_masks(choices, chosen, K, modalities)
            prev_loss = cur

            mb = " ".join(f"{m}={per_mod_bytes[m] / 1e6:.2f}MB"
                          for m in modalities)
            accs = []
            for m in modalities:
                _, a = encoder_eval(agg[m],
                                    jnp.asarray(clients[0].modalities[m]),
                                    jnp.asarray(clients[0].labels))
                accs.append(float(a))
            print(f"[round {t}] mean-loss={float(np.mean(cur)):.4f} "
                  f"global-enc acc(client0)={np.mean(accs):.3f} "
                  f"selected={len(chosen)}/{K} uplink[{mb}] "
                  f"cum={ledger.megabytes:.2f}MB ({time.time() - t0:.1f}s)")
        for m in modalities:
            assert bool(jnp.isfinite(losses[m]).all())
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

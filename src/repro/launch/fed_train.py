"""Datacenter federated training: MFedMC's round as a jit'd mesh program.

    PYTHONPATH=src python -m repro.launch.fed_train --dataset ucihar \
        --rounds 3 [--devices 8] [--hierarchical]

The K-client population is stacked and sharded over the mesh 'data' axis;
each round runs E·steps of vmapped local SGD per modality encoder, then the
joint-selection mask gates Eq. 21's weighted all-reduce
(``repro.core.distributed``). Selection itself (Shapley priority + loss
ranking) stays host-side — it consumes scalars, not tensors.

This launcher is the bridge between the paper-faithful simulator
(``repro.core.rounds``) and the multi-pod dry-run: the same ``round_fn``
lowers on the production mesh.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ucihar")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use what exists)")
    ap.add_argument("--hierarchical", action="store_true")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.distributed import make_federated_round
    from repro.core.encoders import encoder_eval, init_encoder
    from repro.core.selection import select_clients
    from repro.data import get_dataset_spec, make_federation

    spec = get_dataset_spec(args.dataset)
    clients = make_federation(args.dataset, "iid",
                              samples_per_client=args.batch * args.steps)
    modality = spec.modality_names[0]
    K = len(clients)

    n_dev = len(jax.devices())
    data_ax = 1
    for d in range(min(n_dev, K), 0, -1):
        if K % d == 0 and n_dev % d == 0:
            data_ax = d
            break
    mesh = jax.make_mesh((data_ax, n_dev // data_ax), ("data", "model"))
    print(f"{K} clients on mesh {dict(mesh.shape)}; modality={modality!r}")

    feat = clients[0].modalities[modality].shape[1:]
    enc = init_encoder(jax.random.key(0), feat, spec.num_classes)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * K), enc)
    xs = jnp.stack([c.modalities[modality].reshape(
        args.steps, args.batch, *feat) for c in clients])
    ys = jnp.stack([c.labels.reshape(args.steps, args.batch)
                    for c in clients])
    weight = jnp.asarray([c.num_samples for c in clients], jnp.float32)

    round_fn = jax.jit(make_federated_round(
        mesh, local_steps=args.steps, lr=0.1,
        hierarchical=args.hierarchical))
    prev = jax.sharding.get_mesh()
    jax.sharding.set_mesh(mesh)
    try:
        select = jnp.ones((K,), jnp.float32)
        for t in range(1, args.rounds + 1):
            t0 = time.time()
            stacked, agg, losses = round_fn(stacked, {"x": xs, "y": ys},
                                            select, weight)
            # host-side client selection for the next round (Eqs. 17-19)
            chosen = select_clients(
                {i: float(l) for i, l in enumerate(np.asarray(losses))},
                args.delta)
            select = jnp.zeros((K,)).at[jnp.asarray(chosen)].set(1.0)
            loss0, acc0 = encoder_eval(
                agg, jnp.asarray(clients[0].modalities[modality]),
                jnp.asarray(clients[0].labels))
            print(f"[round {t}] mean-loss={float(jnp.mean(losses)):.4f} "
                  f"global-enc acc(client0)={float(acc0):.3f} "
                  f"selected={len(chosen)}/{K} ({time.time()-t0:.1f}s)")
        assert bool(jnp.isfinite(losses).all())
    finally:
        jax.sharding.set_mesh(prev)
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Datacenter federated training: MFedMC's multi-modality round as one
jit'd mesh program.

    PYTHONPATH=src python -m repro.launch.fed_train --dataset ucihar \
        --rounds 3 [--devices 8] [--gamma 1] [--scenario natural] \
        [--hierarchical] [--quantize-bits 8] \
        [--backend mesh|async|sharded] [--mesh-clients D]

The K-client population is stacked and sharded over the mesh 'data' axis,
*per modality*: every modality's encoder population trains E·steps of
vmapped local SGD and aggregates through its own masked weighted all-reduce
(Eq. 21), all inside a single XLA program
(``repro.core.distributed.make_multimodal_federated_round``). The
per-(client, modality) selection mask is the joint modality-and-client
selection (Eq. 20), so the collectives' useful traffic shrinks by the
paper's γ/M̄·δ factor per modality.

Ragged federations (``--scenario natural | longtail | modality_noniid``)
use the padded population layout shared with the Tier-2 simulator
(``repro.core.batched.padded_population_batches``): each client's samples
fill the head of a common [S, B] step schedule under a 0/1 sample mask, a
client that lacks a modality trains a no-op dummy slot with an all-zero
mask and zero Eq. 21 weight, and host-side selection only ranks the
modalities a client actually owns. Heterogeneous populations therefore run
the same mesh program as the homogeneous case — no per-client path.

Joint selection runs through the same device-resident engine as the
simulator backends (``repro.core.selection_engine``): the whole
population's Eqs. 12–19 execute as two [K, M] programs per round, with
recency kept as the Eq. 11 last-upload matrix. The modality-impact
criterion uses the per-round loss improvement as a cheap Shapley proxy
(the exact interventional Shapley of the simulator needs the fusion
module, which never leaves the edge); size and recency criteria are the
paper's Eqs. 10–11 unchanged. ``--client-strategy loss_recency
--loss-weight w`` exposes the §4.8 hybrid ablation on the mesh tier.

This launcher is the bridge between the paper-faithful simulator
(``repro.core.rounds``) and the multi-pod dry-run: the same round lowers
on the production mesh.
"""
from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ucihar")
    ap.add_argument("--scenario", default="iid",
                    help="client partition: iid | natural | class_noniid | "
                         "modality_noniid | longtail")
    ap.add_argument("--missing-rate", type=float, default=0.5,
                    help="modality_noniid: per-modality drop rate")
    ap.add_argument("--imbalance-factor", type=float, default=10.0,
                    help="longtail: n_max / n_min")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--gamma", type=int, default=1,
                    help="modality uploads per client (top-γ, Eq. 16)")
    ap.add_argument("--modalities", default="all",
                    help="comma-separated subset (default: every modality)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use what exists)")
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--quantize-bits", type=int, default=32,
                    help="§4.10 uplink precision: 1..16 quantize every "
                         "client payload on device before Eq. 21's masked "
                         "all-reduce; 32 = full precision")
    ap.add_argument("--client-strategy", default="low_loss",
                    choices=["low_loss", "high_loss", "random",
                             "loss_recency", "all"],
                    help="Eqs. 17-19 server-side client criterion "
                         "(loss_recency: the §4.8 hybrid)")
    ap.add_argument("--loss-weight", type=float, default=1.0,
                    help="loss_recency blend w: "
                         "score = w*loss_rank + (1-w)*recency_rank")
    ap.add_argument("--seed", type=int, default=0,
                    help="rng seed for --client-strategy random")
    ap.add_argument("--backend", default="mesh",
                    choices=["mesh", "async", "sharded"],
                    help="mesh: one jit'd multi-modality round sharded "
                         "over the device mesh; async: the event-driven "
                         "virtual-time runtime (repro.core.scheduler) on "
                         "the same federation; sharded: the paper-faithful "
                         "simulator with its population split row-wise "
                         "over a client mesh (repro.core.sharded)")
    ap.add_argument("--mesh-clients", type=int, default=0,
                    help="sharded: number of devices on the 1-D client "
                         "mesh (0 = every visible device); forces that "
                         "many host devices if --devices is unset")
    ap.add_argument("--availability-trace", default=None,
                    help="§4.9 churn trace: 'bernoulli:RATE' or "
                         "'markov:P_DROP,P_JOIN' (async backend)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="async per-cycle reporting deadline in virtual "
                         "seconds; stragglers past it are dropped")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async: aggregate every N client arrivals "
                         "(default: one flush of all arrivals)")
    ap.add_argument("--staleness-discount", type=float, default=1.0,
                    help="async buffered-flush weight *= d**staleness "
                         "(1.0 = off)")
    ap.add_argument("--straggler-fraction", type=float, default=0.0,
                    help="async: fraction of clients at 10x compute")
    ap.add_argument("--link-sigma", type=float, default=0.0,
                    help="async: log-normal per-client bandwidth spread "
                         "(0 = one shared link)")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write a per-phase trace to DIR (trace.json for "
                         "ui.perfetto.dev + spans.jsonl/metrics.jsonl; "
                         "`python -m repro.telemetry.report DIR`); the "
                         "async/sharded simulator backends trace every "
                         "phase, the mesh backend per-round")
    args = ap.parse_args(argv)
    if not 0.0 <= args.loss_weight <= 1.0:
        ap.error("--loss-weight must be in [0, 1]")
    if args.gamma < 1:
        ap.error("--gamma must be >= 1")
    if args.quantize_bits < 32 and not 1 <= args.quantize_bits <= 16:
        ap.error("--quantize-bits must be 1..16 or 32")

    n_force = args.devices or (args.mesh_clients
                               if args.backend == "sharded" else 0)
    if n_force:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_force}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.aggregation import CommLedger
    from repro.core.batched import padded_population_batches
    from repro.core.distributed import (make_multimodal_federated_round,
                                        selection_masks_from_matrix)
    from repro.core.encoders import encoder_bytes, encoder_eval, init_encoder
    from repro.core.selection import select_clients
    from repro.core.selection_engine import (lexicographic_rank,
                                             select_clients_arrays,
                                             select_modalities_arrays)
    from repro.data import get_dataset_spec, make_federation
    from repro.data.partition import PARTITIONERS

    if args.scenario not in PARTITIONERS:
        raise SystemExit(f"unknown scenario {args.scenario!r}; choose from "
                         f"{sorted(PARTITIONERS)}")
    spec = get_dataset_spec(args.dataset)
    n_base = args.batch * args.steps
    if args.scenario == "longtail":
        part_kw = {"max_samples": n_base,
                   "imbalance_factor": args.imbalance_factor}
    elif args.scenario == "modality_noniid":
        part_kw = {"samples_per_client": n_base,
                   "missing_rate": args.missing_rate}
    else:
        part_kw = {"samples_per_client": n_base}
    clients = make_federation(args.dataset, args.scenario, **part_kw)
    if args.modalities == "all":
        modalities = list(spec.modality_names)
    else:
        modalities = [m.strip() for m in args.modalities.split(",")]
        unknown = set(modalities) - set(spec.modality_names)
        if unknown:
            raise SystemExit(f"unknown modalities: {sorted(unknown)}")

    if args.backend in ("async", "sharded"):
        # Same partition, but through the simulator: async runs the
        # event-driven virtual-time runtime (an event heap schedules each
        # client's compute/uplink completion, buffered arrivals aggregate
        # with staleness-discounted weights, a reporting deadline preempts
        # stragglers); sharded runs the synchronous round with the
        # population split row-wise over a 1-D client mesh and Eq. 21 as a
        # masked psum (repro.core.sharded).
        from repro.core.rounds import (MFedMCConfig, build_federation,
                                       run_federation)
        # --modalities restricts every client's uplink candidates, the
        # same way the mesh path's masks do
        allowed = (None if args.modalities == "all"
                   else {c.client_id: set(modalities) for c in clients})
        extra = {}
        if args.backend == "async":
            # async-only knobs: run_federation rejects them elsewhere
            extra = dict(deadline_s=args.deadline,
                         buffer_size=args.buffer_size,
                         staleness_discount=args.staleness_discount,
                         straggler_fraction=args.straggler_fraction,
                         link_sigma=args.link_sigma)
        else:
            extra = dict(mesh_clients=args.mesh_clients or None)
        cfg = MFedMCConfig(
            rounds=args.rounds, local_epochs=1, batch_size=args.batch,
            gamma=args.gamma, delta=args.delta,
            client_strategy=args.client_strategy,
            loss_weight=args.loss_weight, seed=args.seed,
            quantize_bits=args.quantize_bits,
            allowed_modalities=allowed,
            availability_trace=args.availability_trace,
            background_size=24, eval_size=24, **extra)
        sim_clients, sim_spec = build_federation(
            args.dataset, args.scenario, cfg=cfg, seed=args.seed,
            client_datasets=clients)
        if args.backend == "async":
            print(f"{len(sim_clients)} clients on the virtual clock "
                  f"(scenario={args.scenario}, "
                  f"trace={args.availability_trace or 'always'}, "
                  f"deadline={args.deadline}, buffer={args.buffer_size})")
        else:
            print(f"{len(sim_clients)} clients sharded over "
                  f"{args.mesh_clients or len(jax.devices())} devices "
                  f"(scenario={args.scenario}, "
                  f"trace={args.availability_trace or 'always'})")
        if args.trace:
            from repro import telemetry
            with telemetry.tracing(args.trace):
                h = run_federation(sim_clients, sim_spec, cfg, verbose=True,
                                   backend=args.backend)
            print(f"trace written to {args.trace}/ — load "
                  f"{args.trace}/trace.json in https://ui.perfetto.dev or "
                  f"run `python -m repro.telemetry.report {args.trace}`")
        else:
            h = run_federation(sim_clients, sim_spec, cfg, verbose=True,
                               backend=args.backend)
        tail = ""
        if args.backend == "async":
            dropped = sum(len(r.dropped) for r in h.records)
            tail = f" makespan={h.makespan_s:.1f}s dropped={dropped}"
        print(f"done: acc={h.final_accuracy():.4f} "
              f"comm={h.comm_mb[-1]:.2f}MB" + tail)
        return 0

    K, M = len(clients), len(modalities)

    n_dev = len(jax.devices())
    data_ax = 1
    for d in range(min(n_dev, K), 0, -1):
        if K % d == 0 and n_dev % d == 0:
            data_ax = d
            break
    mesh = jax.make_mesh((data_ax, n_dev // data_ax), ("data", "model"))
    print(f"{K} clients x {M} modalities on mesh {dict(mesh.shape)} "
          f"(scenario={args.scenario}, uplink="
          f"{'f32' if args.quantize_bits >= 32 else f'{args.quantize_bits}b'})")

    # ---- stack the federation: the shared padded population layout -----
    # per-(client, modality) presence — Eq. 20/21's [K, M] mask layout
    presence = np.array([[1.0 if m in c.modalities else 0.0
                          for m in modalities] for c in clients], np.float32)
    params, batches, weight, sizes = {}, {}, {}, {}
    for i, m in enumerate(modalities):
        feat = spec.modality(m).feature_shape(True)
        enc = init_encoder(jax.random.key(i), feat, spec.num_classes)
        # exact compressed-uplink size: what a --quantize-bits wire ships
        # (bit-packed codes + per-tensor scale/zero metadata); this is also
        # Eq. 10's communication-cost criterion for the joint selection
        sizes[m] = encoder_bytes(enc, args.quantize_bits)
        params[m] = jax.tree.map(lambda x: jnp.stack([x] * K), enc)
        b = padded_population_batches(
            [c.modalities.get(m) for c in clients],
            [c.labels for c in clients], args.batch, feature_shape=feat)
        batches[m] = {k: jnp.asarray(v) for k, v in b.items()}
        weight[m] = jnp.asarray(
            [c.num_samples if m in c.modalities else 0 for c in clients],
            jnp.float32)

    round_fn = jax.jit(make_multimodal_federated_round(
        mesh, local_steps=args.steps, lr=0.1,
        hierarchical=args.hierarchical,
        quantize_bits=args.quantize_bits))
    size_vec = np.array([sizes[m] for m in modalities], np.float64)
    name_rank = lexicographic_rank(modalities)
    sel_rng = np.random.default_rng(args.seed)
    ledger = CommLedger()
    import contextlib

    from repro import telemetry
    trace_ctx = (telemetry.tracing(args.trace) if args.trace
                 else contextlib.nullcontext())
    with trace_ctx, mesh:
        tr = telemetry.get()
        # round 1 is the cold start: everyone uploads everything they own
        select = {m: jnp.asarray(presence[:, i])
                  for i, m in enumerate(modalities)}
        last_upload = np.full((K, M), -1, np.int64)      # Eq. 11 state
        prev_loss = None                                  # [K, M]
        for t in range(1, args.rounds + 1):
          with telemetry.span("round", round=t, backend="mesh"):
            t0 = time.time()
            params, agg, losses = round_fn(params, batches, select, weight)

            # ---- per-modality uplink accounting for THIS round's mask ----
            # (recency marks the round a pair actually uploads, Eq. 11)
            per_mod_bytes = {}
            uplink_log = []
            for i, m in enumerate(modalities):
                mask = np.asarray(select[m])
                n_up = int(mask.sum())
                per_mod_bytes[m] = n_up * sizes[m]
                ledger.record(per_mod_bytes[m], n_up, modality=m)
                uplink_log.append({"clients": n_up, "modality": m,
                                   "bytes": float(per_mod_bytes[m])})
                last_upload[mask > 0, i] = t
            ledger.rounds = t

            # ---- joint selection for the next round (Eqs. 13-20) ----
            # the whole population ranks in two device [K, M] programs
            # (repro.core.selection_engine); only the modalities a client
            # actually owns are candidates (presence mask)
            cur = np.stack([np.asarray(losses[m]) for m in modalities],
                           axis=1)                        # [K, M]
            impact = (np.zeros_like(cur) if prev_loss is None
                      else np.maximum(prev_loss - cur, 0.0))
            rec = (t - last_upload - 1).astype(np.float64)
            dec = select_modalities_arrays(
                impact, np.broadcast_to(size_vec, (K, M)), rec,
                presence > 0, name_rank, t=t, gamma=args.gamma,
                alpha_s=1 / 3, alpha_c=1 / 3, alpha_r=1 / 3)
            choices = {k: dec.choices(k, modalities)
                       for k in range(K) if dec.counts[k] > 0}
            if args.client_strategy == "all":
                chosen = sorted(choices)
            elif args.client_strategy == "random":
                chosen = select_clients({k: 0.0 for k in choices},
                                        args.delta, criterion="random",
                                        rng=sel_rng)
            else:
                crec = None
                if args.client_strategy == "loss_recency":
                    own_last = np.where(presence > 0, last_upload,
                                        -1).max(axis=1)
                    crec = (t - 1 - own_last).astype(np.float64)
                cmask = select_clients_arrays(
                    cur.astype(np.float64), dec.mask, delta=args.delta,
                    criterion=args.client_strategy, client_recency=crec,
                    loss_weight=args.loss_weight)
                chosen = [k for k in range(K) if cmask[k]]
            upload_mask = dec.mask & np.isin(np.arange(K),
                                             list(chosen))[:, None]
            select = selection_masks_from_matrix(upload_mask, modalities)
            prev_loss = cur

            mb = " ".join(f"{m}={per_mod_bytes[m] / 1e6:.2f}MB"
                          for m in modalities)
            accs = []
            for m in modalities:
                ref = next(c for c in clients if m in c.modalities)
                _, a = encoder_eval(agg[m],
                                    jnp.asarray(ref.modalities[m]),
                                    jnp.asarray(ref.labels))
                accs.append(float(a))
            mean_loss = float(cur[presence > 0].mean())   # real pairs only
            print(f"[round {t}] mean-loss={mean_loss:.4f} "
                  f"global-enc acc(ref)={np.mean(accs):.3f} "
                  f"selected={len(chosen)}/{K} uplink[{mb}] "
                  f"cum={ledger.megabytes:.2f}MB ({time.time() - t0:.1f}s)")
            if tr is not None:
                tr.metrics.record_round(
                    round=t, mean_loss=mean_loss,
                    accuracy=float(np.mean(accs)),
                    comm_mb=ledger.megabytes, uplink=uplink_log,
                    selected=sorted(int(k) for k in chosen))
        if tr is not None:
            tr.metrics.set_run(
                backend="mesh", rounds=args.rounds,
                ledger_bytes=float(ledger.uploaded_bytes),
                ledger_uploads=int(ledger.uploads),
                ledger_by_modality={m: float(v) for m, v
                                    in ledger.by_modality.items()})
        for m in modalities:
            assert bool(jnp.isfinite(losses[m]).all())
    if args.trace:
        print(f"trace written to {args.trace}/ — run "
              f"`python -m repro.telemetry.report {args.trace}`")
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", ""))

"""Multi-pod dry-run: ``lower().compile()`` every (arch × input shape) on the
single-pod (16, 16) and multi-pod (2, 16, 16) production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
        --shape train_4k [--multi-pod] [--all] [--json out.json]

This is the ONLY entry point that forces 512 host-platform devices; smoke
tests and benchmarks see the single real CPU device.
"""
import argparse
import json
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import (INPUT_SHAPES, get_config, get_shape, list_archs,
                           shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.model import cache_specs, input_specs, param_specs
from repro.optim import adamw
from repro.roofline import (analytic_hbm_bytes, collective_bytes,
                            count_step_flops)
from repro.sharding.partition import (batch_pspec, cache_pspecs,
                                      opt_state_pspecs, param_pspecs,
                                      register_mesh, set_activation_spec)

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


def model_flops(cfg, shape) -> float:
    """6·N·D with N = active params (MoE counts routed-active experts)."""
    specs = param_specs(cfg)
    total = sum(int(x.size) for x in jax.tree.leaves(specs))
    if cfg.is_moe:
        # subtract inactive expert params
        e, k = cfg.num_experts, cfg.experts_per_token
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.num_layers
        total = total - expert * e + expert * k
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * total * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * total * tokens
    return 2.0 * total * shape.global_batch        # decode: one token


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               mode: str = "baseline", verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped (see DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    # §Perf: logical remap of the same 256 chips, e.g. --mode mesh32x8
    for m in mode.split("+"):
        if m.startswith("mesh") and "x" in m:
            d, mm = m[4:].split("x")
            mesh = jax.make_mesh((int(d), int(mm)), ("data", "model"))
    register_mesh(mesh)
    n_chips = mesh.size
    t0 = time.time()

    def shardings(tree, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)

    p_specs = param_specs(cfg)
    p_pspecs = param_pspecs(p_specs)
    if "params_replicated" in mode:
        # small-model decode: drop tensor parallelism, replicate params —
        # trades per-layer activation collectives for redundant compute
        p_pspecs = jax.tree.map(lambda s: P(*(None,) * len(s)), p_pspecs)
    p_sharding = shardings(p_specs, p_pspecs)
    in_specs = input_specs(cfg, shape)
    b_sharding = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              batch_pspec(shape, cfg, multi_pod))

    # ---- §Perf modes (combinable with '+') --------------------------------
    # train: seq_sharded_acts | microbatch<N> | remat_dots | no_remat
    # decode: cache_seq_sharded | params_replicated
    modes = set(mode.split("+"))
    act_token = None
    microbatch = 1
    if "seq_sharded_acts" in modes and shape.kind == "train":
        dp = ("pod", "data") if multi_pod else ("data",)
        act_token = set_activation_spec(P(dp, "model", None))
    for m in modes:
        if m.startswith("microbatch"):
            microbatch = int(m[len("microbatch"):] or 2)
    if "remat_dots" in modes:
        cfg = cfg.with_overrides(remat_policy="dots")
    if "no_remat" in modes:
        cfg = cfg.with_overrides(remat=False)
    moe_token = None
    if "moe_sharded_dispatch" in modes:
        from repro.sharding.partition import set_moe_buffer_spec
        dp = ("pod", "data") if multi_pod else ("data",)
        moe_token = set_moe_buffer_spec(P("model", dp, None))

    try:
        with mesh:
            if shape.kind == "train":
                opt = adamw(1e-4, moment_dtype=(
                    jnp.bfloat16 if "bf16_moments" in modes
                    else jnp.float32))
                o_specs = jax.eval_shape(opt.init, p_specs)
                o_sharding = _opt_shardings(p_specs, o_specs, mesh)
                step = make_train_step(cfg, opt, shape,
                                       microbatch=microbatch)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_sharding, o_sharding, b_sharding),
                    out_shardings=(p_sharding, o_sharding, None),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(p_specs, o_specs, in_specs)
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg, shape)
                jitted = jax.jit(step, in_shardings=(p_sharding, b_sharding),
                                 out_shardings=None)
                lowered = jitted.lower(p_specs, in_specs)
            else:
                step = make_serve_step(cfg, shape)
                mem_len = cfg.vision_tokens if cfg.family == "vlm" else \
                    (shape.seq_len // cfg.encoder_frame_ratio
                     if cfg.family == "audio" else 0)
                c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                      memory_len=mem_len)
                c_sharding = jax.tree.map(
                    lambda s: NamedSharding(mesh, s),
                    cache_pspecs(cfg, c_specs, shape, multi_pod,
                                 seq_shard=("cache_replicated" not in modes)))
                jitted = jax.jit(step,
                                 in_shardings=(p_sharding, c_sharding,
                                               b_sharding),
                                 out_shardings=(None, c_sharding),
                                 donate_argnums=(1,))
                lowered = jitted.lower(p_specs, c_specs, in_specs)

            compiled = lowered.compile()
    finally:
        if act_token is not None:
            set_activation_spec(None)
        if moe_token is not None:
            from repro.sharding.partition import set_moe_buffer_spec
            set_moe_buffer_spec(None)

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # while-aware (trip-count-weighted) collective bytes — whole module
    coll = collective_bytes(hlo)
    coll_total = sum(coll.values())

    # exact FLOPs from the jaxpr (scan trip counts multiplied in); the raw
    # cost_analysis figure is kept for reference — it undercounts scanned
    # layer stacks by ~L× (EXPERIMENTS.md §Roofline)
    if shape.kind == "train":
        flops_total = count_step_flops(step, p_specs, o_specs, in_specs)
    elif shape.kind == "prefill":
        flops_total = count_step_flops(step, p_specs, in_specs)
    else:
        flops_total = count_step_flops(step, p_specs, c_specs, in_specs)
    flops = flops_total / n_chips

    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    mem_model = analytic_hbm_bytes(cfg, shape, n_chips, dp)
    bytes_accessed = mem_model["total"]

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = (coll_total / n_chips) / ICI_BW
    mf = model_flops(cfg, shape)

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mode": mode, "status": "ok", "chips": n_chips,
        "compile_s": round(time.time() - t0, 1),
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": bytes_accessed,
        "hbm_breakdown": {k: v for k, v in mem_model.items() if k != "total"},
        "raw_cost_flops_per_chip": float(cost.get("flops", 0.0)),
        "raw_cost_bytes_per_chip": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_total": coll_total,
        "collective_breakdown": coll,
        "per_device_memory": _mem_summary(mem),
        "peak_gib_per_chip": _peak_gib(mem),
        "fits_hbm_16g": (_peak_gib(mem) or 1e9) < 16.0,
        "compute_s_term": compute_s,
        "memory_s_term": memory_s,
        "collective_s_term": collective_s,
        "dominant": max([("compute", compute_s), ("memory", memory_s),
                         ("collective", collective_s)], key=lambda kv: kv[1])[0],
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / flops_total) if flops_total else 0.0,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'multi-pod(512)' if multi_pod else 'single-pod(256)'} "
              f"({mode}): OK in {result['compile_s']}s")
        print(f"  memory: {result['per_device_memory']} "
              f"fits16G={result['fits_hbm_16g']}")
        print(f"  flops/chip={flops:.3e} hbm_bytes/chip={bytes_accessed:.3e} "
              f"collective={coll_total:.3e}B")
        print(f"  roofline terms (s): compute={compute_s:.4e} "
              f"memory={memory_s:.4e} collective={collective_s:.4e} "
              f"-> {result['dominant']}-bound; "
              f"useful-FLOPs ratio={result['useful_flops_ratio']:.3f}")
    return result


def _peak_gib(mem) -> float:
    try:
        gb = 1024 ** 3
        return round((mem.argument_size_in_bytes
                      + mem.temp_size_in_bytes) / gb, 2)
    except Exception:
        return None


def _opt_shardings(p_specs, o_specs, mesh):
    """Optimizer-state shardings: moments get ZeRO-1 specs, counters P()."""
    moment_spec = opt_state_pspecs(p_specs, mesh)

    def build(o_leaf_path, o_leaf):
        return None

    # structure: {"m": tree, "v": tree, "step": scalar}
    out = {}
    for k, sub in o_specs.items():
        if k == "step":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = jax.tree.map(lambda s: NamedSharding(mesh, s), moment_spec)
    return out


def _mem_summary(mem) -> str:
    try:
        gb = 1024 ** 3
        return (f"args={mem.argument_size_in_bytes/gb:.2f}GiB "
                f"out={mem.output_size_in_bytes/gb:.2f}GiB "
                f"temp={mem.temp_size_in_bytes/gb:.2f}GiB "
                f"peak~{(mem.argument_size_in_bytes+mem.temp_size_in_bytes)/gb:.2f}GiB")
    except Exception:
        return str(mem)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) combination")
    ap.add_argument("--mode", default="baseline")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(dryrun_one(arch, shape, multi_pod=mp,
                                              mode=args.mode))
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures += 1
                    print(f"[dryrun] {arch} × {shape} × multi_pod={mp}: "
                          f"FAILED: {type(e).__name__}: {e}")
                    results.append({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "status": f"FAILED: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    ok = sum(1 for r in results if r.get("status") == "ok")
    skipped = sum(1 for r in results if "skipped" in str(r.get("status")))
    print(f"\n[dryrun] done: {ok} ok, {skipped} skipped, {failures} failed "
          f"of {len(results)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing jax.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])

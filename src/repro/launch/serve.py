"""Batched decode server loop for any zoo architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm3-4b \
        --preset smoke --tokens 32 --batch 2

Prefills a short prompt, then decodes ``--tokens`` new tokens with the
KV / recurrent cache, printing tokens/s. The cache layout and serve_step are
exactly the ones the multi-pod dry-run lowers for decode_32k / long_500k.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.configs.base import InputShape
from repro.launch.steps import make_serve_step
from repro.models.model import init_cache, init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--preset", default="smoke", choices=["smoke"])
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke()
    shape = InputShape("serve", args.cache_len, args.batch, "decode")
    params = init_params(jax.random.key(0), cfg)
    mem_len = cfg.vision_tokens if cfg.family == "vlm" else \
        (max(args.cache_len // cfg.encoder_frame_ratio, 1)
         if cfg.family == "audio" else 0)
    cache = init_cache(cfg, args.batch, args.cache_len, memory_len=mem_len)
    step = jax.jit(make_serve_step(cfg, shape))

    rng = jax.random.key(1)
    tokens = jax.random.randint(rng, (args.batch, 1), 0, cfg.vocab_size)
    # warm-up / compile
    logits, cache = step(params, cache, {"tokens": tokens})
    t0 = time.time()
    generated = []
    for _ in range(args.tokens):
        nxt = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(np.asarray(nxt[:, 0]))
        logits, cache = step(params, cache, {"tokens": nxt})
        assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    dt = time.time() - t0
    print(f"{args.arch}: decoded {args.tokens} tokens × batch {args.batch} "
          f"in {dt:.2f}s -> {args.tokens * args.batch / dt:.1f} tok/s")
    print("sample token ids:", np.stack(generated)[:8, 0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""jit-able train / prefill / serve step factories for the model zoo.

``make_train_step`` returns ``(params, opt_state, batch) -> (params,
opt_state, metrics)``; ``make_serve_step`` returns ``(params, cache, batch)
-> (logits, cache)``. Both are pure functions of pytrees, suitable for
``jax.jit`` with explicit in/out shardings (see ``launch/dryrun.py``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import decode_step, forward_hidden, logits_from_hidden
from repro.optim import Optimizer, apply_updates


def cross_entropy(logits, targets):
    """Memory-lean CE: logsumexp + take_along_axis, f32 accumulation."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tgt)


def chunked_cross_entropy(h, w, targets, chunk: int = 512):
    """CE computed per sequence chunk so full [B,S,V] logits never
    materialize; the checkpointed body recomputes chunk logits in backward.

    h: [B,S,D] final hidden states; w: [D,V]; targets: [B,S].
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    if s % chunk:
        return cross_entropy((h @ w), targets)
    nc = s // chunk
    hs = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, xs):
        hc, tc = xs
        logits = (hc @ w).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts))
    return total / (b * s)


def shape_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding window used for this (arch, shape): 0 = full attention."""
    if shape.requires_subquadratic and not cfg.subquadratic:
        return cfg.long_context_window
    return cfg.sliding_window


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    shape: Optional[InputShape] = None,
                    microbatch: int = 1):
    """``microbatch > 1`` enables gradient accumulation: the global batch is
    split on the batch axis and scanned, trading a smaller activation
    working set (peak HBM) for `microbatch`× more, smaller steps (§Perf)."""
    window = shape_window(cfg, shape) if shape is not None else cfg.sliding_window

    def loss_fn(params, batch):
        h, aux = forward_hidden(params, cfg, batch, window=window)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return chunked_cross_entropy(h, w, batch["targets"]) + aux

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig, shape: Optional[InputShape] = None):
    window = shape_window(cfg, shape) if shape is not None else cfg.sliding_window

    def prefill_step(params, batch):
        h, _ = forward_hidden(params, cfg, batch, window=window)
        return logits_from_hidden(params, cfg, h[:, -1:])[:, 0]

    return prefill_step


def make_serve_step(cfg: ModelConfig, shape: Optional[InputShape] = None):
    window = shape_window(cfg, shape) if shape is not None else cfg.sliding_window

    def serve_step(params, cache, batch):
        return decode_step(params, cfg, cache, batch, window=window)

    return serve_step

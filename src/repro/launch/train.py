"""Standalone trainer for any zoo architecture.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --preset smoke --steps 20 [--data 2 --model 1] [--ckpt out.npz]

On this CPU container ``--preset smoke`` (the default) trains the reduced
config on synthetic token streams; ``--preset full`` is only meaningful under
the dry-run (it would not fit host memory). The mesh is built over however
many local devices exist; sharding rules are identical to the production
mesh so the same code path scales to the pod.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import save_pytree
from repro.configs import get_config, list_archs, smoke_shape
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models.model import init_params, input_specs
from repro.optim import adamw
from repro.sharding.partition import batch_pspec, param_pspecs


def synthetic_batch(rng, cfg, shape):
    specs = input_specs(cfg, shape)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            rng, sub = jax.random.split(rng)
            out[k] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size)
        else:
            rng, sub = jax.random.split(rng)
            out[k] = 0.1 * jax.random.normal(sub, s.shape, s.dtype)
    return rng, out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.smoke()
        shape = smoke_shape("train")
    else:
        from repro.configs import INPUT_SHAPES
        shape = INPUT_SHAPES["train_4k"]

    mesh = make_local_mesh(args.data, args.model)
    params = init_params(jax.random.key(0), cfg)
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt, shape)

    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params))
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_pspec(shape, cfg, False))
    jitted = jax.jit(step_fn, in_shardings=(p_sh, None, b_sh),
                     out_shardings=(p_sh, None, None))

    rng = jax.random.key(1)
    with mesh:
        t0 = time.time()
        for i in range(args.steps):
            rng, batch = synthetic_batch(rng, cfg, shape)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"({time.time() - t0:.1f}s)")
        loss = float(metrics["loss"])
        assert np.isfinite(loss), "training diverged"
    if args.ckpt:
        save_pytree(args.ckpt, params, meta={"arch": args.arch,
                                             "steps": args.steps})
        print(f"saved checkpoint to {args.ckpt}")
    print(f"done: {args.arch} ({args.preset}) final loss {loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""The reconciliation guarantee: trace totals equal the global counters.

A trace that disagrees with the accounting it claims to explain is worse
than no trace. :func:`reconcile` therefore requires, EXACTLY (integer
counters; ledger bytes are integer-valued floats far below 2^53, so float
sums are exact too):

1. the root spans' inclusive counter deltas sum to the tracer's run
   totals for every counter (host syncs, bytes moved, dispatches) — no
   counter activity escapes the round spans;
2. no span's children sum past the span itself — inclusive deltas nest,
   so double counting (e.g. a phase recorded under two spans at once)
   cannot hide;
3. the metrics registry's per-round uplink log sums — total and
   per-modality — equal the run's CommLedger snapshot byte for byte.

Returns a list of human-readable diff strings, empty when clean. The
same checks run from a written trace directory via
``python -m repro.telemetry.report`` and, over every
backend × comm_impl × train_impl, in the lint tier
(``repro.analysis.telemetry_check``).
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from repro.telemetry.tracer import COUNTER_KEYS, Tracer


def reconcile_records(run_totals: Dict[str, Any],
                      spans: Iterable[Dict[str, Any]],
                      metrics_rounds: Iterable[Dict[str, Any]] = (),
                      metrics_run: Optional[Dict[str, Any]] = None
                      ) -> List[str]:
    """Run all checks over plain record dicts (the ``spans.jsonl`` /
    ``metrics.jsonl`` schema); see the module docstring."""
    spans = list(spans)
    diffs: List[str] = []

    # 1. root spans cover the run totals exactly
    for key in COUNTER_KEYS:
        got = sum(s[key] for s in spans if s["parent"] < 0)
        want = int(run_totals[key])
        if got != want:
            diffs.append(
                f"{key}: root spans sum to {got}, run total is {want} "
                f"({got - want:+d}) — counter activity outside every root "
                "span, or a span straddling a measuring() window")

    # 2. children never exceed their parent (inclusive deltas nest)
    child_sums: Dict[int, Dict[str, int]] = {}
    by_index = {s["index"]: s for s in spans}
    for s in spans:
        p = s["parent"]
        if p >= 0:
            acc = child_sums.setdefault(p, dict.fromkeys(COUNTER_KEYS, 0))
            for key in COUNTER_KEYS:
                acc[key] += s[key]
    for p, acc in sorted(child_sums.items()):
        parent = by_index[p]
        for key in COUNTER_KEYS:
            if acc[key] > parent[key]:
                diffs.append(
                    f"{key}: children of span #{p} ({parent['name']!r}) "
                    f"sum to {acc[key]}, parent recorded {parent[key]} "
                    f"({acc[key] - parent[key]:+d}) — double counting")

    # 3. the metrics uplink log equals the CommLedger snapshot
    metrics_run = metrics_run or {}
    if "ledger_bytes" in metrics_run:
        total = 0.0
        by_modality: Dict[str, float] = {}
        for r in metrics_rounds:
            for u in r.get("uplink", ()):
                b = float(u["bytes"])
                total += b
                by_modality[u["modality"]] = \
                    by_modality.get(u["modality"], 0.0) + b
        want_total = float(metrics_run["ledger_bytes"])
        if total != want_total:
            diffs.append(
                f"uplink bytes: metrics log sums to {total:.0f}, "
                f"CommLedger recorded {want_total:.0f} "
                f"({total - want_total:+.0f})")
        want_mod = {k: float(v) for k, v in
                    (metrics_run.get("ledger_by_modality") or {}).items()}
        if by_modality != want_mod:
            diffs.append(
                f"uplink bytes by modality: metrics log {by_modality}, "
                f"CommLedger {want_mod}")
    return diffs


def reconcile(tracer: Tracer) -> List[str]:
    """All checks over a live tracer (finishes it if needed)."""
    totals = tracer.finish()
    return reconcile_records(totals,
                             (r.as_dict() for r in tracer.records),
                             tracer.metrics.rounds, tracer.metrics.run)

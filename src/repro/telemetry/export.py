"""Trace exporters: JSONL event logs + Chrome/Perfetto ``trace_event``.

:func:`write_trace` lays a finished tracer down as a directory:

- ``spans.jsonl``   — one ``{"kind": "run", ...}`` header with the frozen
  run totals, then one line per span (wall offsets in µs + inclusive
  counter deltas);
- ``metrics.jsonl`` — one line per round record, then a ``"run"`` footer
  with the CommLedger snapshot;
- ``trace.json``    — Chrome ``trace_event`` JSON loadable in
  https://ui.perfetto.dev (and ``chrome://tracing``): spans as complete
  ``"X"`` slices on pid 1 ("federation (wall clock)"), async scheduler
  events on pid 2 ("scheduler (virtual time)") with one thread lane per
  client — virtual seconds map to trace microseconds, so both timelines
  zoom sensibly even though their units differ.

Every event carries the keys the CI schema check requires: ``ph``,
``ts``, ``pid``, ``tid``, ``name``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

from repro.telemetry.tracer import Tracer

SPANS_FILE = "spans.jsonl"
METRICS_FILE = "metrics.jsonl"
TRACE_FILE = "trace.json"

WALL_PID = 1        # spans: real wall clock
VIRTUAL_PID = 2     # async scheduler: virtual clock (1 virtual s = 1e6 ts)


def perfetto_trace(tracer: Tracer) -> Dict[str, Any]:
    """The tracer as a Chrome ``trace_event`` JSON object."""
    ev: List[Dict[str, Any]] = [
        {"ph": "M", "pid": WALL_PID, "tid": 0, "ts": 0,
         "name": "process_name",
         "args": {"name": "federation (wall clock)"}},
        {"ph": "M", "pid": WALL_PID, "tid": 0, "ts": 0,
         "name": "thread_name", "args": {"name": "round loop"}},
    ]
    for r in tracer.records:
        ev.append({"ph": "X", "pid": WALL_PID, "tid": 0, "cat": "phase",
                   "name": r.name, "ts": round(r.t0_us, 3),
                   "dur": round(r.dur_us, 3),
                   "args": {**r.args, **r.counters()}})
    if tracer.events:
        ev.append({"ph": "M", "pid": VIRTUAL_PID, "tid": 0, "ts": 0,
                   "name": "process_name",
                   "args": {"name": "scheduler (virtual time)"}})
        for tid in sorted({e.tid for e in tracer.events}):
            ev.append({"ph": "M", "pid": VIRTUAL_PID, "tid": tid, "ts": 0,
                       "name": "thread_name",
                       "args": {"name": "server" if tid == 0
                                else f"client {tid}"}})
        for e in tracer.events:
            base = {"pid": VIRTUAL_PID, "tid": e.tid, "cat": "virtual",
                    "name": e.name, "ts": round(e.t0_s * 1e6, 3),
                    "args": dict(e.args)}
            if e.dur_s is None:
                ev.append({"ph": "i", "s": "t", **base})
            else:
                ev.append({"ph": "X", "dur": round(e.dur_s * 1e6, 3),
                           **base})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_trace(tracer: Tracer, trace_dir: str) -> Dict[str, str]:
    """Finish the tracer and write all three artifacts into
    ``trace_dir`` (created if missing). Returns the file paths."""
    os.makedirs(trace_dir, exist_ok=True)
    totals = tracer.finish()
    paths = {k: os.path.join(trace_dir, v) for k, v in
             (("spans", SPANS_FILE), ("metrics", METRICS_FILE),
              ("trace", TRACE_FILE))}
    with open(paths["spans"], "w") as f:
        f.write(json.dumps({"kind": "run", **totals}) + "\n")
        for r in tracer.records:
            f.write(json.dumps(r.as_dict()) + "\n")
    with open(paths["metrics"], "w") as f:
        for rec in tracer.metrics.rounds:
            f.write(json.dumps(rec) + "\n")
        f.write(json.dumps({"kind": "run", **tracer.metrics.run}) + "\n")
    with open(paths["trace"], "w") as f:
        json.dump(perfetto_trace(tracer), f)
        f.write("\n")
    return paths

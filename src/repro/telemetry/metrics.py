"""Per-round metrics registry: the run's quantitative record, as data.

One :class:`MetricsRegistry` per :class:`~repro.telemetry.tracer.Tracer`.
Each round the federation loop appends a round record — the per-upload
byte log (client, modality, exact wire bytes, in ledger order), the joint
selection decision, losses/accuracy, and on the async backend the
staleness discounts, flush count, deadline-dropped ids and virtual
clock — and at run end :meth:`set_run` stamps the CommLedger snapshot the
reconciliation check compares the uplink log against
(``repro.telemetry.reconcile``). ``repro.telemetry.export`` emits the
whole registry as ``metrics.jsonl``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple


class MetricsRegistry:
    """Append-only round records plus one run-level record."""

    def __init__(self):
        self.rounds: List[Dict[str, Any]] = []
        self.run: Dict[str, Any] = {}

    def record_round(self, **kw) -> Dict[str, Any]:
        """Append one round's record. Conventional keys: ``round``,
        ``accuracy``, ``mean_loss``, ``comm_mb``, ``uplink`` (a list of
        ``{"client", "modality", "bytes"}`` in ledger order), ``selected``,
        ``choices``, ``shapley``; async adds ``staleness``, ``flushes``,
        ``dropped``, ``sim_time``."""
        rec = {"kind": "round", **kw}
        self.rounds.append(rec)
        return rec

    def set_run(self, **kw) -> None:
        """Merge run-level facts (backend, the final CommLedger snapshot:
        ``ledger_bytes``/``ledger_uploads``/``ledger_by_modality``)."""
        self.run.update(kw)

    def uplink_totals(self) -> Tuple[float, Dict[str, float]]:
        """(total bytes, per-modality bytes) summed over every round's
        uplink log, accumulated in record order — the same float-add
        sequence the CommLedger performed, so equality is exact."""
        total = 0.0
        by_modality: Dict[str, float] = {}
        for r in self.rounds:
            for u in r.get("uplink", ()):
                b = float(u["bytes"])
                total += b
                m = u["modality"]
                by_modality[m] = by_modality.get(m, 0.0) + b
        return total, by_modality

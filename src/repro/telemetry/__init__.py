"""Structured tracing + metrics for the federation round, all backends.

The repo can *count* (``repro.core.hostsync``, ``budgets.json``,
rooflines); this package makes it *explain*: every
``run_federation`` phase — local training, the Shapley enumeration,
joint selection, quantize/pack uplink, aggregation, deploy, evaluation,
and the async scheduler's virtual-time events — records a span with its
wall time and its share of the host-sync / uplink-byte / dispatch
counters, per round, per backend.

Usage — module-level, ``measuring()``-style scoping:

    from repro import telemetry
    with telemetry.tracing("trace_dir") as tracer:
        run_federation(clients, spec, cfg, backend="engine")
    # trace_dir/trace.json   -> open in https://ui.perfetto.dev
    # trace_dir/spans.jsonl  -> per-span wall + counter deltas
    # trace_dir/metrics.jsonl-> per-round uplink/selection/loss record
    # python -m repro.telemetry.report trace_dir

Instrumentation points call :func:`span`, which returns a shared no-op
context manager while no tracer is installed — disabled cost is one
global ``None`` check, and tracing never changes a round outcome
(``tests/test_telemetry.py`` pins bit-identical uploads/selection).
The reconciliation contract — span sums equal the global hostsync
counters and the metrics uplink log equals the CommLedger, exactly — is
enforced by :func:`reconcile`, the report CLI, and the lint tier
(``repro.analysis.telemetry_check``).
"""
from __future__ import annotations

import contextlib
from typing import Optional

from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.reconcile import reconcile, reconcile_records
from repro.telemetry.timer import Timer, interleaved_min
from repro.telemetry.tracer import SpanRecord, Tracer, VirtualEvent

__all__ = [
    "MetricsRegistry", "SpanRecord", "Timer", "Tracer", "VirtualEvent",
    "get", "install", "interleaved_min", "phase_table", "reconcile",
    "reconcile_records", "span", "tracer_phase_table", "tracing",
]

_tracer: Optional[Tracer] = None


class _NullSpan:
    """Shared do-nothing span for the disabled path."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


def get() -> Optional[Tracer]:
    """The installed tracer, or None when tracing is off."""
    return _tracer


def span(name: str, **args):
    """A span on the installed tracer — or the shared no-op context
    manager when tracing is off (near-zero disabled overhead)."""
    if _tracer is None:
        return _NULL_SPAN
    return _tracer.span(name, **args)


@contextlib.contextmanager
def install(tracer: Tracer):
    """Install ``tracer`` as the process-global collector for the block;
    restores the previous tracer on exit. Does not finish or export —
    callers that want the artifacts use :func:`tracing`."""
    global _tracer
    prev = _tracer
    _tracer = tracer
    try:
        yield tracer
    finally:
        _tracer = prev


@contextlib.contextmanager
def tracing(trace_dir: Optional[str] = None):
    """Trace the block with a fresh :class:`Tracer`; on exit the tracer
    is finished and, when ``trace_dir`` is given, exported there
    (``trace.json`` + ``spans.jsonl`` + ``metrics.jsonl``)."""
    from repro.telemetry.export import write_trace
    tracer = Tracer()
    with install(tracer):
        try:
            yield tracer
        finally:
            tracer.finish()
            if trace_dir is not None:
                write_trace(tracer, trace_dir)


def phase_table(spans, depth: int = 1):
    from repro.telemetry.report import phase_table as _pt
    return _pt(spans, depth=depth)


def tracer_phase_table(tracer: Tracer, depth: int = 1):
    from repro.telemetry.report import tracer_phase_table as _tpt
    return _tpt(tracer, depth=depth)

"""Span tracer: wall-time + hostsync counter deltas per round phase.

A :class:`Tracer` collects three kinds of observations from one
``run_federation`` call:

- **spans** — nested wall-clock intervals (``round`` → ``train.local`` /
  ``select.joint`` / ``comm.uplink`` / …) that also snapshot the three
  process-global :mod:`repro.core.hostsync` counters (host syncs, uplink
  bytes moved, training dispatches) on entry and record the *inclusive*
  deltas on exit — the same ``measuring()``-style scoping the budget
  manifest uses, so span sums reconcile exactly against the global
  counters (``repro.telemetry.reconcile``);
- **virtual events** — the async scheduler's per-client lifecycle on the
  VIRTUAL clock (local-compute and upload slices, flush and
  deadline-drop instants), kept separate from wall time so the Perfetto
  export can show both timelines side by side;
- **metrics** — the per-round :class:`~repro.telemetry.metrics.
  MetricsRegistry` (uplink log, selection decisions, losses, staleness).

Counter deltas stay correct when a ``hostsync.measuring()`` window is
fully nested inside a span, or encloses the tracer's whole lifetime:
``measuring`` folds its totals back into the enclosing scope on exit, so
the counters look continuous from outside the window. A window that
straddles a span boundary (entered inside, exited outside) is
unsupported — don't do that.

When no tracer is installed, ``repro.telemetry.span`` returns a shared
no-op context manager: the disabled cost of every instrumentation point
is one module-global ``None`` check, and no round outcome ever depends
on whether a tracer is present (pinned by ``tests/test_telemetry.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core import hostsync

COUNTER_KEYS = ("host_syncs", "bytes_moved", "dispatches")


@dataclass
class SpanRecord:
    """One span: a wall-clock interval plus the *inclusive* hostsync
    counter deltas (everything that ran while the span was open, children
    included). ``t0_us`` is the offset from the tracer's start."""
    name: str
    index: int
    parent: int                  # records index of the enclosing span; -1
    depth: int                   # 0 = root
    t0_us: float
    dur_us: float = 0.0
    host_syncs: int = 0
    bytes_moved: int = 0
    dispatches: int = 0
    args: Dict[str, Any] = field(default_factory=dict)

    def counters(self) -> Dict[str, int]:
        return {"host_syncs": self.host_syncs,
                "bytes_moved": self.bytes_moved,
                "dispatches": self.dispatches}

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": "span", "name": self.name, "index": self.index,
                "parent": self.parent, "depth": self.depth,
                "t0_us": round(self.t0_us, 3),
                "dur_us": round(self.dur_us, 3),
                "host_syncs": self.host_syncs,
                "bytes_moved": self.bytes_moved,
                "dispatches": self.dispatches, "args": self.args}


@dataclass
class VirtualEvent:
    """One async-scheduler event on the VIRTUAL clock (seconds).
    ``dur_s=None`` marks an instant; ``tid`` is the timeline lane —
    a client id for per-client slices, 0 for server-side events."""
    name: str
    tid: int
    t0_s: float
    dur_s: Optional[float] = None
    args: Dict[str, Any] = field(default_factory=dict)


class _Span:
    """Context manager for one live span; created by :meth:`Tracer.span`.
    The record is appended on ``__enter__`` (when nesting is known) and
    finalized on ``__exit__``."""
    __slots__ = ("_tracer", "_name", "_args", "_rec", "_c0", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> SpanRecord:
        tr = self._tracer
        rec = SpanRecord(
            name=self._name, index=len(tr.records),
            parent=tr._stack[-1] if tr._stack else -1,
            depth=len(tr._stack),
            t0_us=(time.perf_counter() - tr._wall0) * 1e6,
            args=self._args)
        tr.records.append(rec)
        tr._stack.append(rec.index)
        self._rec = rec
        self._c0 = (hostsync.count(), hostsync.bytes_moved(),
                    hostsync.dispatches())
        self._t0 = time.perf_counter()
        return rec

    def __exit__(self, *exc) -> bool:
        rec = self._rec
        rec.dur_us = (time.perf_counter() - self._t0) * 1e6
        rec.host_syncs = hostsync.count() - self._c0[0]
        rec.bytes_moved = hostsync.bytes_moved() - self._c0[1]
        rec.dispatches = hostsync.dispatches() - self._c0[2]
        self._tracer._stack.pop()
        return False


class Tracer:
    """One run's trace: spans, scheduler virtual events, metrics, and the
    frozen run totals (:meth:`finish`)."""

    def __init__(self):
        from repro.telemetry.metrics import MetricsRegistry
        self._wall0 = time.perf_counter()
        self._c0 = (hostsync.count(), hostsync.bytes_moved(),
                    hostsync.dispatches())
        self.records: List[SpanRecord] = []
        self.events: List[VirtualEvent] = []
        self.metrics = MetricsRegistry()
        self._stack: List[int] = []
        self.totals: Optional[Dict[str, Any]] = None

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def virtual_slice(self, name: str, tid: int, t0_s: float, t1_s: float,
                      **args) -> None:
        self.events.append(VirtualEvent(
            name, int(tid), float(t0_s),
            dur_s=max(float(t1_s) - float(t0_s), 0.0), args=args))

    def virtual_instant(self, name: str, tid: int, t_s: float,
                        **args) -> None:
        self.events.append(VirtualEvent(name, int(tid), float(t_s),
                                        args=args))

    def finish(self) -> Dict[str, Any]:
        """Freeze the run totals as the counter deltas since this tracer
        was constructed (idempotent — later calls return the first
        snapshot). Construct and finish on the same side of any
        ``hostsync.measuring()`` window."""
        if self.totals is None:
            self.totals = {
                "wall_s": time.perf_counter() - self._wall0,
                "host_syncs": hostsync.count() - self._c0[0],
                "bytes_moved": hostsync.bytes_moved() - self._c0[1],
                "dispatches": hostsync.dispatches() - self._c0[2],
                "spans": len(self.records),
            }
        return self.totals

    def roots(self) -> List[SpanRecord]:
        return [r for r in self.records if r.parent < 0]

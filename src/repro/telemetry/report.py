"""Report CLI: per-phase breakdown + reconciliation of a trace directory.

    PYTHONPATH=src python -m repro.telemetry.report TRACE_DIR

Reads the ``spans.jsonl`` / ``metrics.jsonl`` a traced run wrote (see
``repro.telemetry.export``), prints the per-phase wall-time / host-sync /
byte / dispatch breakdown, and re-runs the reconciliation checks from the
files alone — exit 1 on any diff, so CI can gate on a written trace.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, Iterable, List, Tuple

from repro.telemetry.export import METRICS_FILE, SPANS_FILE
from repro.telemetry.reconcile import reconcile_records
from repro.telemetry.tracer import COUNTER_KEYS, Tracer


def phase_table(spans: Iterable[Dict[str, Any]], depth: int = 1
                ) -> Dict[str, Dict[str, Any]]:
    """Aggregate span records at ``depth`` by name: count, wall seconds,
    and every hostsync counter, in first-seen order."""
    out: Dict[str, Dict[str, Any]] = {}
    for s in spans:
        if s["depth"] != depth:
            continue
        e = out.setdefault(s["name"], {"count": 0, "seconds": 0.0,
                                       **dict.fromkeys(COUNTER_KEYS, 0)})
        e["count"] += 1
        e["seconds"] += s["dur_us"] / 1e6
        for key in COUNTER_KEYS:
            e[key] += s[key]
    return out


def tracer_phase_table(tracer: Tracer, depth: int = 1
                       ) -> Dict[str, Dict[str, Any]]:
    """:func:`phase_table` over a live tracer."""
    return phase_table((r.as_dict() for r in tracer.records), depth=depth)


def load_trace_dir(trace_dir: str
                   ) -> Tuple[Dict, List[Dict], List[Dict], Dict]:
    """(run totals, span records, round metrics, run metrics) from a
    trace directory."""
    run_totals: Dict[str, Any] = {}
    spans: List[Dict[str, Any]] = []
    with open(os.path.join(trace_dir, SPANS_FILE)) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("kind") == "run":
                run_totals = rec
            else:
                spans.append(rec)
    rounds: List[Dict[str, Any]] = []
    metrics_run: Dict[str, Any] = {}
    with open(os.path.join(trace_dir, METRICS_FILE)) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("kind") == "run":
                metrics_run = rec
            else:
                rounds.append(rec)
    return run_totals, spans, rounds, metrics_run


def print_report(run_totals: Dict, spans: List[Dict], rounds: List[Dict],
                 metrics_run: Dict) -> List[str]:
    """Print the breakdown, return the reconciliation diffs."""
    n_rounds = sum(1 for s in spans if s["parent"] < 0
                   and s["name"] == "round")
    print(f"{len(spans)} spans over {n_rounds} round(s), backend="
          f"{metrics_run.get('backend', '?')}, wall "
          f"{run_totals.get('wall_s', 0.0):.3f}s")
    header = (f"{'phase':16s} {'count':>5s} {'seconds':>9s} "
              f"{'syncs':>6s} {'bytes':>12s} {'dispatches':>10s}")
    print(header)
    print("-" * len(header))
    for name, e in phase_table(spans).items():
        print(f"{name:16s} {e['count']:5d} {e['seconds']:9.3f} "
              f"{e['host_syncs']:6d} {e['bytes_moved']:12d} "
              f"{e['dispatches']:10d}")
    print("-" * len(header))
    print(f"{'run totals':16s} {'':5s} {run_totals.get('wall_s', 0.0):9.3f} "
          f"{run_totals['host_syncs']:6d} "
          f"{run_totals['bytes_moved']:12d} "
          f"{run_totals['dispatches']:10d}")
    if "ledger_bytes" in metrics_run:
        print(f"ledger: {metrics_run['ledger_bytes']:.0f} B over "
              f"{metrics_run.get('ledger_uploads', '?')} upload(s) "
              f"{ {k: int(v) for k, v in (metrics_run.get('ledger_by_modality') or {}).items()} }")
    diffs = reconcile_records(run_totals, spans, rounds, metrics_run)
    if diffs:
        for d in diffs:
            print(f"RECONCILE: {d}")
    else:
        print("reconciled: span sums == hostsync totals, "
              "uplink log == CommLedger")
    return diffs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.telemetry.report",
        description="per-phase breakdown + reconciliation of a --trace dir")
    ap.add_argument("trace_dir", help="directory written by a --trace run")
    args = ap.parse_args(argv)
    diffs = print_report(*load_trace_dir(args.trace_dir))
    return 1 if diffs else 0


if __name__ == "__main__":
    raise SystemExit(main())

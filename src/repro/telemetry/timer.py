"""The benchmarks' shared wall-clock timing utilities.

One implementation of the two idioms every ``benchmarks/bench_*.py``
repeated by hand:

- :class:`Timer` — the ``with Timer() as t: ...; t.us`` block
  (previously defined in ``benchmarks/common.py``, re-exported there);
- :func:`interleaved_min` — strictly interleaved min-of-reps over a set
  of labeled thunks. This host's wall clock drifts between process
  phases (throttling windows, shared CPU), so timing all reps of one
  variant then all reps of another biases whichever ran during the slow
  window; alternating variants inside each rep is the only fair
  comparison, and min-of-reps is the steady-state estimate every bench
  reports.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Mapping, Optional


class Timer:
    """``with Timer() as t: ...`` → ``t.us`` (wall microseconds)."""

    def __enter__(self) -> "Timer":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a) -> None:
        self.us = (time.perf_counter() - self.t0) * 1e6

    @property
    def s(self) -> float:
        return self.us / 1e6


def interleaved_min(thunks: Mapping[str, Callable], *, reps: int = 3,
                    prepare: Optional[Mapping[str, Callable]] = None
                    ) -> Dict[str, float]:
    """Best-of-``reps`` wall seconds per labeled thunk, strictly
    interleaved: each rep runs every label once, in insertion order, so
    clock drift hits all variants alike.

    ``prepare[label]`` (optional) runs UNtimed before each timed call and
    its return value is passed to the thunk — the hook for per-rep state
    rebuilds (e.g. a fresh federation) that must stay outside the timed
    region. Labels without a prepare hook are called with no argument.
    """
    best = {k: float("inf") for k in thunks}
    for _ in range(max(int(reps), 1)):
        for k, fn in thunks.items():
            if prepare is not None and k in prepare:
                arg = prepare[k]()
                with Timer() as t:
                    fn(arg)
            else:
                with Timer() as t:
                    fn()
            best[k] = min(best[k], t.us / 1e6)
    return best

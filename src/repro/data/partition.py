"""Client partitioners for the paper's four distribution scenarios (§4.1)
plus the long-tail Imbalance Factor protocol (§4.8).

Each partitioner returns ``List[ClientData]`` — the federation's local
datasets — given a :class:`SyntheticDataset` source.

- ``iid``            — uniform class priors, all modalities, equal sizes
- ``natural``        — per-client skewed class priors, structural missing
                       modalities, skewed sample counts (PTB-XL/MELD style)
- ``class_noniid``   — Dirichlet(β) class allocation (smaller β = more skew)
- ``modality_noniid``— drop modalities at a given missing rate (each client
                       keeps ≥1 modality; rate=0.8 keeps ≥2 where possible)
- ``longtail``       — sample counts follow an exponential long-tail with
                       Imbalance Factor IF = n_max / n_min
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.data.synthetic import ClientData, SyntheticDataset


def _uniform_labels(rng, n: int, c: int) -> np.ndarray:
    """Balanced-ish uniform labels (every class present when n >= c)."""
    base = np.tile(np.arange(c), n // c + 1)[:n]
    rng.shuffle(base)
    return base


def partition_iid(ds: SyntheticDataset, *, seed: int = 0,
                  samples_per_client: Optional[int] = None) -> List[ClientData]:
    spec = ds.spec
    n = samples_per_client or spec.samples_per_client
    rng = np.random.default_rng(seed)
    return [ds.sample_client(k, _uniform_labels(rng, n, spec.num_classes),
                             spec.modality_names)
            for k in range(spec.num_clients)]


def partition_natural(ds: SyntheticDataset, *, seed: int = 0,
                      samples_per_client: Optional[int] = None
                      ) -> List[ClientData]:
    """Original client division: biased class priors, structural missing
    modalities, and (for PTB-XL/MELD) heavily skewed sample counts."""
    spec = ds.spec
    base_n = samples_per_client or spec.samples_per_client
    rng = np.random.default_rng(seed + 1)
    clients = []
    if spec.natural_skew > 0:
        # exponential skew: client k gets base_n * skew^(−k/K) style decay,
        # normalized so the head clients dominate (≈ PTB-XL's 93% in 3 sites)
        ranks = rng.permutation(spec.num_clients)
        weights = np.exp(-spec.natural_skew * ranks / max(spec.num_clients - 1, 1))
        weights = weights / weights.sum()
        counts = np.maximum(8, (weights * base_n * spec.num_clients)).astype(int)
    else:
        counts = np.full(spec.num_clients, base_n)
    for k in range(spec.num_clients):
        # biased class prior per client (individual/group heterogeneity)
        prior = rng.dirichlet(np.full(spec.num_classes, 2.0))
        labels = rng.choice(spec.num_classes, size=counts[k], p=prior)
        mods = [m for m in spec.modality_names
                if m not in spec.natural_missing.get(k, ())]
        clients.append(ds.sample_client(k, labels, mods, extra_noise=0.1))
    return clients


def partition_class_noniid(ds: SyntheticDataset, *, beta: float = 0.5,
                           seed: int = 0,
                           samples_per_client: Optional[int] = None
                           ) -> List[ClientData]:
    spec = ds.spec
    n = samples_per_client or spec.samples_per_client
    rng = np.random.default_rng(seed + 2)
    clients = []
    for k in range(spec.num_clients):
        prior = rng.dirichlet(np.full(spec.num_classes, beta))
        labels = rng.choice(spec.num_classes, size=n, p=prior)
        clients.append(ds.sample_client(k, labels, spec.modality_names))
    return clients


def partition_modality_noniid(ds: SyntheticDataset, *, missing_rate: float,
                              seed: int = 0,
                              samples_per_client: Optional[int] = None
                              ) -> List[ClientData]:
    spec = ds.spec
    n = samples_per_client or spec.samples_per_client
    rng = np.random.default_rng(seed + 3)
    m_total = len(spec.modality_names)
    keep_min = 2 if m_total > 2 else 1
    clients = []
    for k in range(spec.num_clients):
        mods = [m for m in spec.modality_names if rng.random() >= missing_rate]
        if len(mods) < keep_min:
            mods = list(rng.choice(spec.modality_names, size=keep_min,
                                   replace=False))
        labels = _uniform_labels(rng, n, spec.num_classes)
        clients.append(ds.sample_client(k, labels, mods))
    return clients


def partition_longtail(ds: SyntheticDataset, *, imbalance_factor: float,
                       seed: int = 0,
                       max_samples: Optional[int] = None) -> List[ClientData]:
    """Client sample counts decay exponentially with IF = n_max / n_min."""
    spec = ds.spec
    n_max = max_samples or spec.samples_per_client
    rng = np.random.default_rng(seed + 4)
    K = spec.num_clients
    ratios = imbalance_factor ** (-np.arange(K) / max(K - 1, 1))
    counts = np.maximum(4, (n_max * ratios)).astype(int)
    rng.shuffle(counts)
    clients = []
    for k in range(K):
        labels = _uniform_labels(rng, counts[k], spec.num_classes)
        clients.append(ds.sample_client(k, labels, spec.modality_names))
    return clients


PARTITIONERS = {
    "iid": partition_iid,
    "natural": partition_natural,
    "class_noniid": partition_class_noniid,
    "modality_noniid": partition_modality_noniid,
    "longtail": partition_longtail,
}


def make_federation(dataset: str, scenario: str = "iid", *, seed: int = 0,
                    reduced: bool = True, noise: float = 1.0,
                    **kw) -> List[ClientData]:
    """One-call constructor: dataset name + scenario -> client datasets."""
    from repro.data.synthetic import make_dataset
    ds = make_dataset(dataset, reduced=reduced, seed=seed, noise=noise)
    return PARTITIONERS[scenario](ds, seed=seed, **kw)

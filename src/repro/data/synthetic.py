"""Class-conditional synthetic multimodal data shaped like the paper's
five datasets.

Generation model, per dataset (seeded, deterministic):

    x[n] = A_k · (prototype[y[n]] + drift_k) + (noise / snr_m) · ε

- ``prototype[c]`` — a fixed random pattern per (class, modality) with the
  modality's feature shape; time-series prototypes are smooth (cumulative sums
  of white noise) so an LSTM can track them; image prototypes are low-frequency
  blobs for the CNN.
- ``A_k, drift_k`` — per-client affine distortion (individual/group/system
  heterogeneity in the paper's taxonomy).
- ``snr_m`` — per-modality informativeness; low-SNR modalities are genuinely
  harder, which is what makes Shapley-based modality selection non-trivial.

All generation is numpy (host-side data pipeline); training consumes jnp
device arrays per minibatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.data.registry import DatasetSpec, get_dataset_spec


@dataclass
class ClientData:
    """One client's local multimodal dataset."""
    client_id: int
    # modality name -> [N, *feature_shape] float32; absent keys = missing
    modalities: Dict[str, np.ndarray]
    labels: np.ndarray                      # [N] int32
    num_classes: int

    @property
    def num_samples(self) -> int:
        return int(self.labels.shape[0])

    @property
    def modality_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.modalities))

    def split(self, frac: float = 0.8, seed: int = 0):
        """Deterministic train/test split."""
        n = self.num_samples
        rng = np.random.default_rng(seed + self.client_id)
        perm = rng.permutation(n)
        cut = max(1, int(n * frac))
        tr, te = perm[:cut], perm[cut:] if cut < n else perm[-1:]
        take = lambda idx: ClientData(
            self.client_id,
            {m: v[idx] for m, v in self.modalities.items()},
            self.labels[idx], self.num_classes)
        return take(tr), take(te)


def _smooth_prototype(rng, shape: Tuple[int, ...]) -> np.ndarray:
    """Smooth random pattern: cumsum over the time axis, unit-normalized."""
    z = rng.standard_normal(shape).astype(np.float32)
    if len(shape) == 2:                     # [T, F] time series
        z = np.cumsum(z, axis=0) / np.sqrt(np.arange(1, shape[0] + 1))[:, None]
    else:                                   # [H, W, C] image: blur via cumsum2d
        z = np.cumsum(np.cumsum(z, axis=0), axis=1)
        z /= np.sqrt(np.outer(np.arange(1, shape[0] + 1),
                              np.arange(1, shape[1] + 1)))[..., None]
    return z / (np.std(z) + 1e-8)


class SyntheticDataset:
    """Holds per-(class, modality) prototypes and samples client datasets."""

    def __init__(self, spec: DatasetSpec, *, reduced: bool = True,
                 seed: int = 0, noise: float = 1.0):
        self.spec = spec
        self.reduced = reduced
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.prototypes: Dict[str, np.ndarray] = {}
        for m in spec.modalities:
            shape = m.feature_shape(reduced)
            self.prototypes[m.name] = np.stack(
                [_smooth_prototype(rng, shape) for _ in range(spec.num_classes)])
        # per-client heterogeneity
        self.client_scale = 1.0 + 0.25 * rng.standard_normal(
            (spec.num_clients,)).astype(np.float32)
        self.client_shift = 0.3 * rng.standard_normal(
            (spec.num_clients,)).astype(np.float32)
        self._seed = seed

    def sample_client(self, client_id: int, labels: np.ndarray,
                      modality_names: Sequence[str],
                      extra_noise: float = 0.0) -> ClientData:
        """Generate measurements for given labels and modality subset."""
        rng = np.random.default_rng(self._seed * 7919 + client_id + 1)
        mods: Dict[str, np.ndarray] = {}
        a, b = self.client_scale[client_id], self.client_shift[client_id]
        for name in modality_names:
            mspec = self.spec.modality(name)
            proto = self.prototypes[name][labels]       # [N, *shape]
            sigma = (self.noise + extra_noise) / mspec.snr
            eps = rng.standard_normal(proto.shape).astype(np.float32)
            mods[name] = a * proto + b + sigma * eps
        return ClientData(client_id, mods, labels.astype(np.int32),
                          self.spec.num_classes)


def make_dataset(name: str, **kw) -> SyntheticDataset:
    return SyntheticDataset(get_dataset_spec(name), **kw)

"""Dataset registry mirroring Table 1 of the paper.

Five multimodal datasets: client counts, task cardinality, modality names and
per-modality feature shapes. Real data is not available offline, so
``repro.data.synthetic`` generates class-conditional synthetic measurements
with the same structure (clients × modalities × [T, F] / [H, W, C]); the
heterogeneity knobs (per-client affine distortion, per-modality SNR, class
priors, long-tail sample counts) reproduce the *relative* phenomena the paper
studies.

Shapes are stored at full paper fidelity; ``reduced=True`` (default for CPU
tests/benchmarks) truncates the time axis so LSTM scans stay cheap.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class ModalitySpec:
    name: str
    # time-series modalities: (T, F); image modalities: (H, W, C)
    shape: Tuple[int, ...]
    kind: str = "timeseries"          # timeseries | image
    snr: float = 1.0                  # synthetic signal-to-noise scale
    # reduced time axis for CPU runs (timeseries only)
    reduced_t: int = 16

    def feature_shape(self, reduced: bool) -> Tuple[int, ...]:
        if self.kind == "image" or not reduced:
            return self.shape
        t, f = self.shape
        return (min(t, self.reduced_t), f)

    def encoder_kind(self) -> str:
        return "cnn" if self.kind == "image" else "lstm"


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_clients: int
    num_classes: int
    modalities: Tuple[ModalitySpec, ...]
    # client ids with structurally missing modalities (natural distribution):
    # {client_id: (missing modality names)}
    natural_missing: Dict[int, Tuple[str, ...]] = field(default_factory=dict)
    # natural per-client sample-count skew exponent (0 = uniform). PTB-XL and
    # MELD concentrate >92% of samples in a handful of clients.
    natural_skew: float = 0.0
    samples_per_client: int = 96      # synthetic default (IID baseline)

    @property
    def modality_names(self) -> Tuple[str, ...]:
        return tuple(m.name for m in self.modalities)

    def modality(self, name: str) -> ModalitySpec:
        for m in self.modalities:
            if m.name == name:
                return m
        raise KeyError(name)


DATASETS: Dict[str, DatasetSpec] = {
    # 9 subjects, 20 kitchen activities; subjects 06-09 miss both tactile
    "actionsense": DatasetSpec(
        name="actionsense",
        num_clients=9,
        num_classes=20,
        modalities=(
            ModalitySpec("eye", (32, 2), snr=1.4),
            ModalitySpec("emg_left", (32, 8), snr=1.0),
            ModalitySpec("emg_right", (32, 8), snr=1.1),
            ModalitySpec("tactile_left", (32, 32), snr=0.6),
            ModalitySpec("tactile_right", (32, 32), snr=0.8),
            ModalitySpec("body", (32, 66), snr=1.6),
        ),
        natural_missing={5: ("tactile_left", "tactile_right"),
                         6: ("tactile_left", "tactile_right"),
                         7: ("tactile_left", "tactile_right"),
                         8: ("tactile_left", "tactile_right")},
    ),
    # 30 subjects, 6 daily activities, identical encoder sizes by design
    "ucihar": DatasetSpec(
        name="ucihar",
        num_clients=30,
        num_classes=6,
        modalities=(
            ModalitySpec("accelerometer", (128, 3), snr=1.0),
            ModalitySpec("gyroscope", (128, 3), snr=1.2),
        ),
        samples_per_client=64,
    ),
    # 39 hospitals, 5 diagnoses; 3 sites hold 93.5% of samples
    "ptbxl": DatasetSpec(
        name="ptbxl",
        num_clients=39,
        num_classes=5,
        modalities=(
            ModalitySpec("limb_ecg", (1000, 6), snr=1.0, reduced_t=32),
            ModalitySpec("precordial_ecg", (1000, 6), snr=1.1, reduced_t=32),
        ),
        natural_skew=2.5,
        samples_per_client=64,
    ),
    # 42 speakers, 4 emotions; 6 speakers hold 92.7% of samples
    "meld": DatasetSpec(
        name="meld",
        num_clients=42,
        num_classes=4,
        modalities=(
            ModalitySpec("audio", (64, 80), snr=0.8, reduced_t=16),
            ModalitySpec("text", (1, 100), snr=1.3, reduced_t=1),
        ),
        natural_skew=2.5,
        samples_per_client=48,
    ),
    # 10 GF2 cities + 17 SV cities, 12 roof types; CNN encoders
    "dfc23": DatasetSpec(
        name="dfc23",
        num_clients=27,
        num_classes=12,
        modalities=(
            ModalitySpec("sar", (32, 32, 1), kind="image", snr=0.7),
            ModalitySpec("optical", (32, 32, 3), kind="image", snr=1.2),
        ),
        samples_per_client=64,
    ),
}


def get_dataset_spec(name: str) -> DatasetSpec:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return DATASETS[name]


def list_datasets():
    return sorted(DATASETS)

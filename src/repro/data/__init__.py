from repro.data.partition import (PARTITIONERS, make_federation,
                                  partition_class_noniid, partition_iid,
                                  partition_longtail,
                                  partition_modality_noniid, partition_natural)
from repro.data.registry import (DATASETS, DatasetSpec, ModalitySpec,
                                 get_dataset_spec, list_datasets)
from repro.data.synthetic import ClientData, SyntheticDataset, make_dataset

__all__ = [
    "DATASETS", "DatasetSpec", "ModalitySpec", "get_dataset_spec",
    "list_datasets", "ClientData", "SyntheticDataset", "make_dataset",
    "PARTITIONERS", "make_federation", "partition_iid", "partition_natural",
    "partition_class_noniid", "partition_modality_noniid",
    "partition_longtail",
]

"""The paper's own model configuration (§4.2 Experiment Setup).

LSTM modality encoders: one LSTM layer with 128 hidden units + a fully
connected head, learning rate 0.1 (datasets i-iv). CNN encoders for DFC23:
one 5x5 conv (32 ch) + ReLU + 2x2 maxpool + FC, lr 0.01. Fusion module over
definitive predicted categories; paper uses a 10-tree random forest - we use
an MLP fusion head (see DESIGN.md §3 for the documented deviation) with
exact interventional Shapley over a |D'|=50 background subsample.

The operational federation config (gamma, delta, alpha weights, E, etc.)
is ``repro.core.rounds.MFedMCConfig`` - re-exported here so
``repro.configs`` is the single config entry point.
"""
from dataclasses import dataclass

from repro.core.rounds import MFedMCConfig  # noqa: F401 (re-export)


@dataclass(frozen=True)
class EncoderConfig:
    kind: str = "lstm"        # lstm | cnn
    hidden: int = 128
    conv_channels: int = 32
    conv_kernel: int = 5
    lr: float = 0.1

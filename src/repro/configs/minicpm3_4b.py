"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B] 62 layers, d_model=2560, 40 heads, d_ff=6400,
vocab=73448. MLA: q_lora_rank=768, kv_lora_rank=256, qk nope/rope head dims
64/32, v_head_dim=64. The KV cache stores the compressed latent
(kv_lora_rank + rope dim per token), which is MLA's memory advantage.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    citation="hf:openbmb/MiniCPM3-4B",
)

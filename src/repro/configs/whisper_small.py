"""Whisper-small — encoder-decoder speech model (transformer backbone only).

[arXiv:2212.04356] 12 encoder + 12 decoder layers, d_model=768, 12 heads
(MHA kv=12), d_ff=3072, vocab=51865. The mel-spectrogram + conv feature
extractor frontend is a STUB per the assignment carve-out; ``input_specs``
supplies precomputed frame embeddings (seq // encoder_frame_ratio frames).

Note: whisper caps source at 1500 frames / target at 448 tokens in its
published form; the 32k shapes here exercise the backbone with interpolated
positions as a dry-run stress config, and ``long_500k`` is SKIPPED
(full-attention enc-dec; see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    encoder_frame_ratio=4,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    act="gelu",
    gated_ffn=False,
    citation="arXiv:2212.04356",
)

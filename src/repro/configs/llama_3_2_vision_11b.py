"""Llama-3.2-11B-Vision — dense decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision] 40 layers, d_model=4096, 32 heads
(GQA kv=8), d_ff=14336, vocab=128256. Cross-attention layers every 5 layers
consume precomputed vision patch embeddings (ViT frontend is a STUB per the
assignment carve-out; ``input_specs`` supplies patch embeddings directly).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    cross_attn_every=5,
    vision_tokens=1601,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)

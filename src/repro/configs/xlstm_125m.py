"""xLSTM-125M — sLSTM + mLSTM recurrent blocks (attention-free).

[arXiv:2405.04517] 12 layers, d_model=768, 4 heads, vocab=50304.
d_ff=0: xLSTM blocks carry their own up/down projections (pre-up-projection
mLSTM blocks, post-up-projection sLSTM blocks per the paper). We use the
paper's 1:1-ish placement with mLSTM at most positions and sLSTM interleaved.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    xlstm_pattern=("mlstm", "mlstm", "slstm"),
    mlstm_chunk=64,
    act="gelu",
    citation="arXiv:2405.04517",
)

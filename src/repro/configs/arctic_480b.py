"""Snowflake Arctic-480B — 128-expert top-2 MoE with a dense residual path.

[hf:Snowflake/snowflake-arctic-base] 35 layers, d_model=7168, 56 heads
(GQA kv=8), expert d_ff=4864, vocab=32000, 128 experts top-2, plus a dense
FFN residual branch in parallel with the MoE branch (Arctic's
"dense-MoE hybrid" design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    citation="hf:Snowflake/snowflake-arctic-base",
)

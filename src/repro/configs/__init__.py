"""Architecture config registry: ``get_config("<arch-id>")`` / ``--arch``."""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import (INPUT_SHAPES, InputShape, MLAConfig,
                                ModelConfig, smoke_shape)
from repro.configs.paper_mfl import EncoderConfig, MFedMCConfig

_ARCH_MODULES = {
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "whisper-small": "repro.configs.whisper_small",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "yi-34b": "repro.configs.yi_34b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "granite-34b": "repro.configs.granite_34b",
    "arctic-480b": "repro.configs.arctic_480b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Whether (arch, shape) is a supported combination (DESIGN.md skips)."""
    if shape.requires_subquadratic and cfg.family == "audio":
        # whisper: enc-dec full attention, no windowed variant (DESIGN.md)
        return False
    return True


__all__ = [
    "ModelConfig", "MLAConfig", "InputShape", "INPUT_SHAPES", "smoke_shape",
    "EncoderConfig", "MFedMCConfig", "get_config", "get_shape", "list_archs",
    "shape_applicable",
]

"""Granite-34B-Code — llama-architecture dense decoder with MQA.

[arXiv:2405.04324] 88 layers, d_model=6144, 48 heads (MQA kv=1),
d_ff=24576, vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24_576,
    vocab_size=49_152,
    citation="arXiv:2405.04324",
)

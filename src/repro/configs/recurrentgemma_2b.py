"""RecurrentGemma-2B — RG-LRU recurrent blocks + local attention, 1:2 pattern.

[arXiv:2402.19427] Griffin/RecurrentGemma: 26 layers, d_model=2560, 10 heads
(MQA kv=1), d_ff=7680, vocab=256000. Block pattern: two recurrent (RG-LRU)
blocks followed by one local-attention block, repeating.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_attn_window=2048,
    rglru_conv_width=4,
    act="gelu",
    gated_ffn=True,
    citation="arXiv:2402.19427",
)

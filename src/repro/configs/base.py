"""Unified model/shape configuration dataclasses for the model zoo.

Every assigned architecture is expressed as a ``ModelConfig``. Families:

- ``dense``  — llama-style decoder (GQA or MLA attention)
- ``moe``    — dense attention + mixture-of-experts FFN (optionally with a
               dense residual FFN path, as in Arctic)
- ``hybrid`` — RG-LRU recurrent blocks interleaved with local attention
               (RecurrentGemma / Griffin 1:2 pattern)
- ``ssm``    — xLSTM (sLSTM + mLSTM blocks)
- ``vlm``    — dense decoder with cross-attention image layers every K layers
               (Llama 3.2 Vision); vision frontend is a stub embedding input
- ``audio``  — encoder-decoder (Whisper); conv/mel frontend is a stub
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention ---
    attn_type: str = "gqa"           # gqa | mla
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention; >0 = window size
    # window applied only for shapes that require sub-quadratic attention
    long_context_window: int = 4096
    mla: Optional[MLAConfig] = None

    # --- FFN ---
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    gated_ffn: bool = True

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False # Arctic: dense FFN in parallel with MoE
    router_aux_loss_coef: float = 0.01

    # --- hybrid (RG-LRU + local attention) ---
    # pattern of block kinds repeated to fill num_layers, e.g.
    # ("rglru", "rglru", "local_attn") is the RecurrentGemma 1:2 pattern.
    block_pattern: Tuple[str, ...] = ()
    rglru_conv_width: int = 4        # temporal conv1d preceding RG-LRU
    local_attn_window: int = 2048

    # --- ssm (xLSTM) ---
    # pattern of ("slstm" | "mlstm") blocks repeated to fill num_layers
    xlstm_pattern: Tuple[str, ...] = ()
    mlstm_chunk: int = 64

    # --- vlm ---
    cross_attn_every: int = 0        # insert a cross-attn layer every K layers
    vision_tokens: int = 1601        # patch embeddings per image (stub input)
    vision_embed_dim: int = 0        # 0 -> d_model

    # --- audio (encoder-decoder) ---
    encoder_layers: int = 0          # >0 -> enc-dec model; decoder=num_layers
    encoder_frame_ratio: int = 4     # source frames = seq // ratio (stub input)

    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"       # full | dots (dots_saveable policy)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/logits table padded to a multiple of 256 so the vocab
        dim divides the 16-way model axis (and MXU lanes). ``vocab_size``
        stays the card-exact value; padded ids are never valid targets."""
        return -(-self.vocab_size // 256) * 256

    @property
    def subquadratic(self) -> bool:
        """True if the arch natively supports very long contexts."""
        return self.family in ("hybrid", "ssm")

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- reduced variant for CPU smoke tests ---------------------------------
    def smoke(self) -> "ModelConfig":
        """A tiny same-family variant (<=2 layers, d_model<=512, <=4 experts)."""
        n_layers = 2
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            dtype="float32",
            remat=False,
        )
        if self.is_moe:
            kw.update(num_experts=4, experts_per_token=min(2, self.experts_per_token))
        if self.block_pattern:
            kw.update(local_attn_window=64,
                      block_pattern=("rglru", "local_attn"))
        if self.xlstm_pattern:
            kw.update(xlstm_pattern=("mlstm", "slstm"), mlstm_chunk=16)
        if self.mla is not None:
            kw.update(mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                    qk_nope_head_dim=16, qk_rope_head_dim=8,
                                    v_head_dim=16))
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        if self.cross_attn_every:
            kw.update(cross_attn_every=2, vision_tokens=16)
        if self.sliding_window:
            kw.update(sliding_window=32)
        kw.update(long_context_window=64)
        return self.with_overrides(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    requires_subquadratic: bool = False


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode",
                            requires_subquadratic=True),
}


def smoke_shape(kind: str = "train") -> InputShape:
    if kind == "train":
        return InputShape("smoke_train", 64, 4, "train")
    if kind == "prefill":
        return InputShape("smoke_prefill", 64, 2, "prefill")
    return InputShape("smoke_decode", 64, 2, "decode")

"""Checkpointing: arbitrary pytrees <-> .npz archives.

Leaves are flattened to '/'-joined key paths. ``restore_sharded`` re-places
each restored leaf with its target ``NamedSharding`` so a checkpoint written
on one mesh restores onto another (the arrays are host-resident between).
Federated runs store the global encoder bank plus the selection state
(recency counters) so a run can resume mid-federation.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree, *, meta: Optional[Dict[str, Any]] = None
                ) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    if meta is not None:
        flat["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
    np.savez(path, **flat)


def load_pytree(path: str, like=None):
    """Load an .npz checkpoint. With ``like`` (a template pytree), values are
    re-nested into the template's structure; otherwise returns the flat dict.
    Returns (tree_or_flat, meta)."""
    with np.load(path if path.endswith(".npz") else path + ".npz",
                 allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    meta = None
    if "__meta__" in flat:
        meta = json.loads(bytes(flat.pop("__meta__")).decode())
    if like is None:
        return flat, meta
    like_flat = _flatten(like)
    missing = set(like_flat) - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} …")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(
        str(p.key) if isinstance(p, jax.tree_util.DictKey)
        else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
        else str(p) for p in path) for path, _ in paths]
    leaves = [jnp.asarray(flat[k]) for k in keys]
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def restore_sharded(path: str, like, shardings):
    """Load and place each leaf with its target sharding (mesh-aware)."""
    tree, meta = load_pytree(path, like)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, shardings)
    return placed, meta

"""Exact-ish FLOP metering by walking the step function's jaxpr.

Why not ``compiled.cost_analysis()``: XLA's HLO cost analysis counts a
``while`` body ONCE, so an L-layer ``lax.scan`` stack under-reports by ~L×
(validated in a controlled experiment — see EXPERIMENTS.md §Roofline,
"cost-analysis undercount"). The jaxpr, in contrast, still knows every
scan's trip count, so walking it and multiplying body costs by ``length``
meters the true executed FLOPs — including the remat recompute and the
autodiff transpose, since ``value_and_grad`` traces them into the jaxpr.

Counted: dot_general (2·B·M·N·K), conv_general_dilated, and a 1-flop/output
charge for elementwise ops (captures the RG-LRU / xLSTM gate math). Gather /
dynamic-slice / layout ops are free (they're memory, not compute).

Primitives that are neither counted, known-free, nor carriers of a
sub-jaxpr are **unknown**: they are still charged 0 FLOPs, but every walk
now collects them (``count_step_flops_detailed`` returns the tally;
``repro.analysis.lint`` surfaces the union per program) instead of
dropping them silently — an op the meter has never seen is a hole in the
roofline until it is classified.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, Tuple

import jax

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "neg", "abs",
    "erf", "sign", "cos", "sin", "log1p", "expm1", "cumsum", "cumlogsumexp",
    "cummax", "select_n", "clamp", "and", "or", "not", "xor", "rem",
    "nextafter", "atan2", "add_any", "round", "ceil", "floor",
}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin", "reduce_precision",
           "logsumexp", "reduce"}

# Deliberately 0-FLOP: data movement, layout, comparisons/bit ops the
# roofline treats as free, control/annotation, and RNG bookkeeping. An op
# here is a *decision* that it costs nothing — new primitives land in the
# unknown tally until someone moves them into a bucket.
_FREE = {
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "expand_dims",
    "concatenate", "pad", "slice", "dynamic_slice", "dynamic_update_slice",
    "gather", "scatter", "scatter-add", "scatter_add", "rev", "iota",
    "convert_element_type", "bitcast_convert_type", "copy", "device_put",
    "stop_gradient", "eq", "ne", "lt", "le", "gt", "ge", "is_finite",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "sort", "argsort", "top_k", "select", "split",
    "random_seed", "random_wrap", "random_unwrap", "random_bits",
    "threefry2x32", "psum", "psum2", "pmax", "pmin", "all_gather",
    "ppermute", "pbroadcast", "axis_index", "one_hot", "squeeze_p",
}


def _dot_flops(eqn) -> float:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = math.prod(lhs[i] for i in lb) if lb else 1
    k = math.prod(lhs[i] for i in lc) if lc else 1
    m = math.prod(lhs[i] for i in range(len(lhs))
                  if i not in set(lb) | set(lc))
    n = math.prod(rhs[i] for i in range(len(rhs))
                  if i not in set(rb) | set(rc))
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval.shape
    rhs = eqn.invars[1].aval.shape          # kernel [*spatial, Cin, Cout]
    return 2.0 * math.prod(out) * math.prod(rhs[:-1])


def _sub_jaxprs(eqn):
    for name in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr"):
        if name in eqn.params:
            j = eqn.params[name]
            yield 1.0, j
    if "branches" in eqn.params:             # cond: charge the max branch
        branches = eqn.params["branches"]
        if branches:
            yield 1.0, max(branches, key=lambda b: _jaxpr_flops(_closed(b)))
    if "body_jaxpr" in eqn.params:            # raw while: trips unknown -> 1
        yield 1.0, eqn.params["body_jaxpr"]


def _closed(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


_CACHE: Dict[int, float] = {}
_UNKNOWN: Counter = Counter()


def _jaxpr_flops(jaxpr) -> float:
    key = id(jaxpr)
    if key in _CACHE:
        return _CACHE[key]
    total = 0.0
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        if p == "dot_general":
            total += _dot_flops(eqn)
        elif p == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif p == "scan":
            body = _closed(eqn.params["jaxpr"])
            total += eqn.params["length"] * _jaxpr_flops(body)
        elif p in _ELEMENTWISE:
            total += math.prod(eqn.outvars[0].aval.shape)
        elif p in _REDUCE:
            total += math.prod(eqn.invars[0].aval.shape)
        elif p == "custom_vjp_call" or p.startswith("custom_"):
            for scale, sub in _sub_jaxprs(eqn):
                total += scale * _jaxpr_flops(_closed(sub))
        else:
            subs = list(_sub_jaxprs(eqn))
            for scale, sub in subs:
                total += scale * _jaxpr_flops(_closed(sub))
            if not subs and p not in _FREE:
                _UNKNOWN[p] += 1        # charged 0, but no longer silently
    _CACHE[key] = total
    return total


def count_step_flops(fn, *example_args, **example_kwargs) -> float:
    """Total FLOPs of one call of ``fn`` at the given abstract shapes.

    ``example_args`` may be ShapeDtypeStructs — nothing is materialized.
    """
    flops, _ = count_step_flops_detailed(fn, *example_args, **example_kwargs)
    return flops


def count_step_flops_detailed(fn, *example_args, **example_kwargs
                              ) -> Tuple[float, Dict[str, int]]:
    """Like :func:`count_step_flops`, plus the walk's unknown-primitive
    tally ``{primitive name: occurrences}`` — ops the meter charged 0 FLOPs
    without a classification. ``repro.analysis.lint`` reports the union."""
    jaxpr = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    return jaxpr_flops_detailed(jaxpr.jaxpr)


def jaxpr_flops_detailed(jaxpr) -> Tuple[float, Dict[str, int]]:
    """Walk an already-traced (open) jaxpr: (FLOPs, unknown tally)."""
    _CACHE.clear()
    _UNKNOWN.clear()
    flops = _jaxpr_flops(jaxpr)
    return flops, dict(_UNKNOWN)

"""Exact-ish FLOP metering by walking the step function's jaxpr.

Why not ``compiled.cost_analysis()``: XLA's HLO cost analysis counts a
``while`` body ONCE, so an L-layer ``lax.scan`` stack under-reports by ~L×
(validated in a controlled experiment — see EXPERIMENTS.md §Roofline,
"cost-analysis undercount"). The jaxpr, in contrast, still knows every
scan's trip count, so walking it and multiplying body costs by ``length``
meters the true executed FLOPs — including the remat recompute and the
autodiff transpose, since ``value_and_grad`` traces them into the jaxpr.

Counted: dot_general (2·B·M·N·K), conv_general_dilated, and a 1-flop/output
charge for elementwise ops (captures the RG-LRU / xLSTM gate math). Gather /
dynamic-slice / layout ops are free (they're memory, not compute).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "neg", "abs",
    "erf", "sign", "cos", "sin", "log1p", "expm1", "cumsum", "cumlogsumexp",
    "cummax", "select_n", "clamp", "and", "or", "not", "xor", "rem",
    "nextafter", "atan2",
}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin", "reduce_precision",
           "logsumexp"}


def _dot_flops(eqn) -> float:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = math.prod(lhs[i] for i in lb) if lb else 1
    k = math.prod(lhs[i] for i in lc) if lc else 1
    m = math.prod(lhs[i] for i in range(len(lhs))
                  if i not in set(lb) | set(lc))
    n = math.prod(rhs[i] for i in range(len(rhs))
                  if i not in set(rb) | set(rc))
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval.shape
    rhs = eqn.invars[1].aval.shape          # kernel [*spatial, Cin, Cout]
    return 2.0 * math.prod(out) * math.prod(rhs[:-1])


def _sub_jaxprs(eqn):
    for name in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr"):
        if name in eqn.params:
            j = eqn.params[name]
            yield 1.0, j
    if "branches" in eqn.params:             # cond: charge the max branch
        branches = eqn.params["branches"]
        if branches:
            yield 1.0, max(branches, key=lambda b: _jaxpr_flops(_closed(b)))
    if "body_jaxpr" in eqn.params:            # raw while: trips unknown -> 1
        yield 1.0, eqn.params["body_jaxpr"]


def _closed(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


_CACHE: Dict[int, float] = {}


def _jaxpr_flops(jaxpr) -> float:
    key = id(jaxpr)
    if key in _CACHE:
        return _CACHE[key]
    total = 0.0
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        if p == "dot_general":
            total += _dot_flops(eqn)
        elif p == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif p == "scan":
            body = _closed(eqn.params["jaxpr"])
            total += eqn.params["length"] * _jaxpr_flops(body)
        elif p in _ELEMENTWISE:
            total += math.prod(eqn.outvars[0].aval.shape)
        elif p in _REDUCE:
            total += math.prod(eqn.invars[0].aval.shape)
        elif p == "custom_vjp_call" or p.startswith("custom_"):
            for scale, sub in _sub_jaxprs(eqn):
                total += scale * _jaxpr_flops(_closed(sub))
        else:
            for scale, sub in _sub_jaxprs(eqn):
                total += scale * _jaxpr_flops(_closed(sub))
    _CACHE[key] = total
    return total


def count_step_flops(fn, *example_args, **example_kwargs) -> float:
    """Total FLOPs of one call of ``fn`` at the given abstract shapes.

    ``example_args`` may be ShapeDtypeStructs — nothing is materialized.
    """
    _CACHE.clear()
    jaxpr = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    return _jaxpr_flops(jaxpr.jaxpr)

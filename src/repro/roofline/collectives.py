"""While-aware collective-bytes metering from compiled HLO text.

Collectives inside a ``lax.scan``'s lowered while body appear ONCE in the
HLO text but execute ``trip_count`` times. This parser splits the module
into computations, finds every ``while`` op, extracts the trip count from
its condition computation (the loop bound is the comparison constant), and
scales collective bytes by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines.

    Header lines look like ``%name (params…) -> type {`` (params may contain
    nested tuple parens, so we key off the trailing '{' + '->')."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            toks = stripped.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = name.lstrip("%")
            comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _find_whiles(comps: Dict[str, List[str]]) -> List[Tuple[str, str, str]]:
    """Returns (enclosing_comp, body_name, cond_name) per while op."""
    out = []
    for cname, lines in comps.items():
        for line in lines:
            if " while(" not in line:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if mb and mc:
                out.append((cname, mb.group(1), mc.group(1)))
    return out


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound = the integer constant compared against in the condition."""
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def computation_multipliers(hlo: str) -> Tuple[Dict[str, List[str]],
                                               Dict[str, float]]:
    comps = split_computations(hlo)
    whiles = _find_whiles(comps)
    mult: Dict[str, float] = {c: 1.0 for c in comps}

    # fixpoint: body multiplier = trips × multiplier(enclosing computation)
    for _ in range(8):                       # nesting depth bound
        changed = False
        for encl, body, cond in whiles:
            trips = _trip_count(comps.get(cond, []))
            new = trips * mult.get(encl, 1.0)
            if body in mult and abs(mult[body] - new) > 0.5:
                mult[body] = new
                changed = True
        if not changed:
            break
    return comps, mult


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Trip-count-weighted collective bytes by kind (executed, not textual)."""
    comps, mult = computation_multipliers(hlo)
    out: Dict[str, float] = {}
    for cname, lines in comps.items():
        scale = mult.get(cname, 1.0)
        for line in lines:
            line = line.strip()
            m = re.match(
                r"(?:ROOT\s+)?\S+ = ((?:\([^)]*\))|(?:\S+\[[\d,]*\]\S*)) "
                r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                r"collective-permute)", line)
            if not m:
                continue
            tys, kind = m.groups()
            if tys.startswith("("):
                # tuple type: extract each dtype[dims] (comma-splitting would
                # break inside multi-dim shapes like f32[128,20])
                total = sum(_shape_bytes(t)
                            for t in re.findall(r"\w+\[[\d,]*\]", tys))
            else:
                total = _shape_bytes(tys)
            out[kind] = out.get(kind, 0.0) + scale * total
    return out

"""Analytic HBM-traffic model per (arch × shape), per chip.

XLA's aggregate ``bytes accessed`` suffers the same while-body undercount as
its FLOP count, so the memory roofline term is modeled analytically from
first principles (MaxText-style); constants are documented per term:

train (per step, per chip):
    params   — read bf16 (2 B) + grad write/read (2+2) + AdamW m,v read/write
               (4×4) + f32 master-ish update write (2) ≈ 24 B/param-shard
    acts     — per layer-scan trip: residual carry [B/dp, S, D] saved fwd +
               read bwd + recompute write+read under remat ≈ 4 passes × 2 B
    logits   — chunked CE: chunk logits f32 written+read in fwd and
               recomputed in bwd ≈ 4 passes × 4 B over [B/dp, S, V] (the
               chunking keeps the *capacity* small; traffic is unchanged)

prefill: params read + 2-pass activations (no bwd, no opt).
decode:  params read + full KV/recurrent-cache read + 1-token write.

These are lower-bound-flavored estimates (VMEM-resident intermediates are
free); they are the memory-roofline inputs, with the raw cost-analysis
figure reported alongside for reference.
"""
from __future__ import annotations

import math
from typing import Dict

import jax

from repro.configs.base import InputShape, ModelConfig


def _param_count(cfg: ModelConfig) -> int:
    from repro.models.model import param_specs
    return sum(int(math.prod(x.shape))
               for x in jax.tree.leaves(param_specs(cfg)))


def _cache_bytes(cfg: ModelConfig, shape: InputShape) -> int:
    from repro.models.model import cache_specs
    mem_len = cfg.vision_tokens if cfg.family == "vlm" else \
        (max(shape.seq_len // cfg.encoder_frame_ratio, 1)
         if cfg.family == "audio" else 0)
    specs = cache_specs(cfg, shape.global_batch, shape.seq_len,
                        memory_len=mem_len)
    return sum(int(math.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(specs))


def analytic_hbm_bytes(cfg: ModelConfig, shape: InputShape,
                       n_chips: int, dp: int) -> Dict[str, float]:
    """Per-chip HBM traffic of one step, by term."""
    n_params = _param_count(cfg)
    b_shard = max(shape.global_batch // dp, 1)
    d, s, v = cfg.d_model, shape.seq_len, cfg.vocab_size
    layers = cfg.num_layers + getattr(cfg, "encoder_layers", 0)

    if shape.kind == "train":
        params = 24.0 * n_params / n_chips
        acts = layers * b_shard * s * d * 2.0 * 4
        logits = b_shard * s * v * 4.0 * 4
        total = params + acts + logits
        return {"params": params, "acts": acts, "logits": logits,
                "cache": 0.0, "total": total}
    if shape.kind == "prefill":
        params = 2.0 * n_params / n_chips
        acts = layers * b_shard * s * d * 2.0 * 2
        total = params + acts
        return {"params": params, "acts": acts, "logits": 0.0,
                "cache": 0.0, "total": total}
    # decode: one token against the cache
    params = 2.0 * n_params / n_chips
    cache = _cache_bytes(cfg, shape) / n_chips      # sharded cache read
    acts = layers * b_shard * d * 2.0 * 4           # tiny
    total = params + cache + acts
    return {"params": params, "acts": acts, "logits": 0.0,
            "cache": cache, "total": total}

"""Roofline metering of the REAL federation round programs.

The historical ``benchmarks/roofline_federated.py`` rooflined a standalone
``make_federated_round`` step that ``run_federation`` never executes. This
module meters the programs the backends actually run:

- :func:`quantized_uplink_roofline` — the §4.10 communication hot path of
  ``aggregate_uploads``: FLOPs of the fused (``repro.kernels.comm``) and
  reference (``quantize_population`` + ``aggregate_quantized``) programs,
  walked from their jaxprs at the padded ``[K, ...]`` population shape
  (nothing materializes — ShapeDtypeStructs in), plus the three byte
  levels a round can move: the exact wire-format lower bound, each impl's
  actual program-boundary payload, and the raw float32 ceiling.
  ``benchmarks/bench_quantized_round.py`` reports achieved
  (``repro.core.hostsync.bytes_moved``) against these bounds.
- :func:`sharded_round_programs` — the sharded backend's per-round
  ``shard_map`` programs (per-epoch local-SGD, the fused all-epochs
  round program with its donated param stack, full-precision psum,
  quantized psum in both impls), returned with representative abstract
  inputs so ``benchmarks/roofline_federated.py`` can lower them on a
  forced-D mesh and parse collective bytes from the compiled HLO.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.jaxpr_flops import count_step_flops

__all__ = ["quantized_uplink_roofline", "sharded_round_programs",
           "stacked_abstract"]


def stacked_abstract(template, k: int):
    """``[K, ...]`` float32 ShapeDtypeStructs for a stacked population of
    ``template`` (the shape ``aggregate_uploads`` sees after padding)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((k,) + tuple(np.shape(l)),
                                       jnp.float32), template)


def quantized_uplink_roofline(template, k: int, bits: int) -> Dict:
    """FLOPs and byte bounds of one modality's K-client upload+reduce.

    Returns::

        {"wire_bytes":      exact §4.10 wire format — the lower bound,
         "payload_bytes":   {"fused": ..., "reference": ...}  (what each
                            impl's program boundary actually carries),
         "raw_bytes":       K × float32 encoder — the uncompressed ceiling,
         "flops":           {"fused": {"uplink", "downlink"},
                             "reference": {"uplink", "downlink"}}}

    All numbers come from the REAL jitted programs ``aggregate_uploads``
    dispatches — metered on abstract shapes via ``count_step_flops``.
    """
    from repro.core.aggregation import aggregate_quantized
    from repro.core.quantize import quantize_population
    from repro.kernels.comm import (payload_nbytes,
                                    quantize_pack_population,
                                    reduce_packed_population,
                                    wire_payload_bytes)
    stacked = stacked_abstract(template, k)
    w = jax.ShapeDtypeStruct((k,), jnp.float32)
    shapes: Tuple[Tuple[int, ...], ...] = tuple(
        tuple(l.shape[1:]) for l in jax.tree_util.tree_leaves(stacked))
    raw_bytes = payload_nbytes(stacked)

    def up_fused(s):
        return quantize_pack_population(s, bits=bits)

    def up_ref(s):
        return quantize_population(s, bits=bits)

    payload_f = jax.eval_shape(up_fused, stacked)
    payload_r = jax.eval_shape(up_ref, stacked)
    flops = {
        "fused": {
            "uplink": count_step_flops(up_fused, stacked),
            "downlink": count_step_flops(
                lambda p, sc, z, ww: reduce_packed_population(
                    p, sc, z, ww, bits=bits, shapes=shapes),
                *payload_f, w),
        },
        "reference": {
            "uplink": count_step_flops(up_ref, stacked),
            "downlink": count_step_flops(aggregate_quantized, *payload_r, w),
        },
    }
    return {
        "wire_bytes": wire_payload_bytes(template, bits, k),
        "payload_bytes": {"fused": payload_nbytes(*payload_f),
                          "reference": payload_nbytes(*payload_r)},
        "raw_bytes": raw_bytes,
        "flops": flops,
    }


def sharded_round_programs(mesh, *, k: int, steps: int, batch: int,
                           feat: Tuple[int, ...], template, lr: float,
                           bits: int, epochs: int = 2) -> Dict:
    """The sharded backend's per-round programs + abstract inputs.

    Returns ``{name: (program, args)}`` where ``program`` is the exact
    lru-cached ``jit(shard_map(...))`` object ``run_federation`` with
    ``backend="sharded"`` dispatches, and ``args`` are ShapeDtypeStructs
    at a representative round shape — ready for ``.lower(*args)`` (HLO
    collective parsing) and ``count_step_flops(program, *args)``.

    ``epoch`` is the reference trainer's single-epoch program;
    ``epoch_fused`` is the ``train_impl="fused"`` all-``epochs`` round
    program (its first argument — the resident param stack — is donated,
    which the lowering's ``args_info`` records)."""
    from repro.core.sharded import (_aggregate_program,
                                    _aggregate_quantized_fused_program,
                                    _aggregate_quantized_program,
                                    _epoch_program, _fused_round_program)
    params = stacked_abstract(template, k)
    f32 = jnp.float32
    xs = jax.ShapeDtypeStruct((k, steps, batch) + tuple(feat), f32)
    ys = jax.ShapeDtypeStruct((k, steps, batch), jnp.int32)
    ws = jax.ShapeDtypeStruct((k, steps, batch), f32)
    exs = jax.ShapeDtypeStruct((k, epochs, steps, batch) + tuple(feat), f32)
    eys = jax.ShapeDtypeStruct((k, epochs, steps, batch), jnp.int32)
    ews = jax.ShapeDtypeStruct((k, epochs, steps, batch), f32)
    w = jax.ShapeDtypeStruct((k,), f32)
    return {
        "epoch": (_epoch_program(mesh, lr), (params, xs, ys, ws)),
        "epoch_fused": (
            _fused_round_program(mesh, lr), (params, exs, eys, ews)),
        "aggregate_full": (_aggregate_program(mesh), (params, w)),
        "aggregate_q_reference": (
            _aggregate_quantized_program(mesh, bits), (params, w)),
        "aggregate_q_fused": (
            _aggregate_quantized_fused_program(mesh, bits), (params, w)),
    }

"""Roofline metering: jaxpr FLOP counter, analytic HBM-traffic model,
while-aware collective-bytes parsing, and the real-round federation
meters (all documented in EXPERIMENTS.md §Roofline, including why raw
``cost_analysis()`` is insufficient)."""
from repro.roofline.collectives import collective_bytes, computation_multipliers
from repro.roofline.federated import (quantized_uplink_roofline,
                                      sharded_round_programs,
                                      stacked_abstract)
from repro.roofline.jaxpr_flops import count_step_flops
from repro.roofline.memory import analytic_hbm_bytes

__all__ = ["collective_bytes", "computation_multipliers",
           "count_step_flops", "analytic_hbm_bytes",
           "quantized_uplink_roofline", "sharded_round_programs",
           "stacked_abstract"]

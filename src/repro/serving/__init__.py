from repro.serving.engine import Request, ServeEngine, WaveStats

__all__ = ["Request", "ServeEngine", "WaveStats"]

"""Batched serving engine for the model zoo.

Scheduling model: requests are grouped into *waves* by prompt-length bucket
(the decode cache keeps one global position per batch, so a wave advances in
lockstep — per-slot positions/continuous batching are recorded as future
work in DESIGN.md). Within a wave:

  1. admitted requests fill the batch slots (padded to the bucket length);
  2. the prompt is consumed token-by-token through ``decode_step`` (cache
     prefill — identical math to a chunked prefill, one token per step);
  3. greedy decoding runs until every request hits EOS or max_new_tokens;
     finished slots are masked out of the returned text but keep stepping
     (their tokens are discarded), so the wave never re-shapes.

The engine reports per-wave throughput; ``examples/serve_requests.py`` runs
it end to end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.launch.steps import make_serve_step
from repro.models.model import init_cache


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class WaveStats:
    wave: int
    batch: int
    prompt_len: int
    decoded: int
    seconds: float

    @property
    def tokens_per_s(self) -> float:
        return self.batch * self.decoded / max(self.seconds, 1e-9)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 4,
                 cache_len: int = 256, bucket: int = 16):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.bucket = bucket
        shape = InputShape("serve", cache_len, max_batch, "decode")
        self._step = jax.jit(make_serve_step(cfg, shape))
        self.queue: List[Request] = []
        self.stats: List[WaveStats] = []

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        req = Request(len(self.queue), list(prompt), max_new_tokens, eos_id)
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    def _bucketed(self) -> Dict[int, List[Request]]:
        buckets: Dict[int, List[Request]] = {}
        for r in self.queue:
            if r.done:
                continue
            b = -(-len(r.prompt) // self.bucket) * self.bucket
            buckets.setdefault(b, []).append(r)
        return buckets

    def _fresh_cache(self):
        cfg = self.cfg
        mem_len = cfg.vision_tokens if cfg.family == "vlm" else \
            (max(self.cache_len // cfg.encoder_frame_ratio, 1)
             if cfg.family == "audio" else 0)
        return init_cache(cfg, self.max_batch, self.cache_len,
                          memory_len=mem_len)

    def run(self) -> List[Request]:
        """Process the whole queue; returns the completed requests."""
        wave_no = 0
        for blen, reqs in sorted(self._bucketed().items()):
            for i in range(0, len(reqs), self.max_batch):
                wave = reqs[i:i + self.max_batch]
                self._run_wave(wave_no, wave, blen)
                wave_no += 1
        return self.queue

    def _run_wave(self, wave_no: int, wave: List[Request], blen: int):
        t0 = time.perf_counter()
        b = self.max_batch
        cache = self._fresh_cache()
        # left-align prompts, pad with token 0 (prefix positions identical
        # across the wave; padded tail tokens are fed but outputs ignored)
        prompts = np.zeros((b, blen), np.int32)
        plens = np.zeros((b,), np.int32)
        for j, r in enumerate(wave):
            prompts[j, :len(r.prompt)] = r.prompt
            plens[j] = len(r.prompt)

        # cache prefill: step the prompt through (one token per step)
        logits = None
        last_logits = [None] * b
        for tpos in range(blen):
            logits, cache = self._step(self.params, cache,
                                       {"tokens": jnp.asarray(
                                           prompts[:, tpos:tpos + 1])})
            for j in range(len(wave)):
                if plens[j] == tpos + 1:
                    last_logits[j] = logits[j]

        # greedy decode
        max_new = max(r.max_new_tokens for r in wave)
        nxt = np.zeros((b, 1), np.int32)
        for j in range(len(wave)):
            nxt[j, 0] = int(jnp.argmax(last_logits[j]))
            wave[j].output.append(int(nxt[j, 0]))
        decoded = 1
        for _ in range(max_new - 1):
            logits, cache = self._step(self.params, cache,
                                       {"tokens": jnp.asarray(nxt)})
            tok = np.asarray(jnp.argmax(logits, axis=-1))
            decoded += 1
            for j, r in enumerate(wave):
                if r.done or len(r.output) >= r.max_new_tokens:
                    r.done = True
                    continue
                t = int(tok[j])
                r.output.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    r.done = True
                nxt[j, 0] = t
            if all(r.done or len(r.output) >= r.max_new_tokens
                   for r in wave):
                break
        for r in wave:
            r.done = True
        self.stats.append(WaveStats(wave_no, len(wave), blen, decoded,
                                    time.perf_counter() - t0))

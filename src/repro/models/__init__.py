from repro.models.model import (cache_specs, decode_step, forward, init_cache,
                                init_params, input_specs, param_specs)
from repro.models.layers import count_params, param_bytes

__all__ = ["cache_specs", "decode_step", "forward", "init_cache",
           "init_params", "input_specs", "param_specs", "count_params",
           "param_bytes"]

"""Shared building blocks: norms, RoPE, FFNs, initializers.

Parameters are plain nested dicts of jnp arrays. Every module exposes
``init_<mod>(rng, cfg, ...) -> params`` and ``<mod>(params, x, ...) -> y``.
Layer stacks are stored stacked on a leading axis and iterated with
``jax.lax.scan`` so the compiled HLO contains one layer body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / (in_dim ** 0.5)
    return (jax.random.normal(rng, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(rng, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                     # [head_dim // 2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                          # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (SwiGLU / gated-GELU / plain MLP)
# ---------------------------------------------------------------------------

def init_ffn(rng, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(rng, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn(params, x, act: str = "silu"):
    h = x @ params["w_in"]
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    if "w_gate" in params:
        h = a(x @ params["w_gate"]) * h
    else:
        h = a(h)
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# temporal conv1d (causal, depthwise) — RG-LRU / xLSTM frontends
# ---------------------------------------------------------------------------

def init_conv1d(rng, dim: int, width: int, dtype):
    scale = 1.0 / (width ** 0.5)
    return {"w": (jax.random.normal(rng, (width, dim)) * scale).astype(dtype),
            "b": jnp.zeros((dim,), dtype)}


def causal_conv1d(params, x, state=None):
    """Depthwise causal conv. x: [B, S, D]. state: [B, width-1, D] or None.

    Returns (y, new_state) where new_state holds the trailing window.
    """
    w = params["w"]
    width = w.shape[0]
    if state is None:
        state = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                     # [B, S+w-1, D]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return (y + params["b"]).astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def stack_layer_params(per_layer):
    """List of identical-structure pytrees -> single pytree stacked on axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))

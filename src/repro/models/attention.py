"""Attention variants: GQA (full/causal/sliding-window), MLA, cross-attn,
and cache-based single-token decode (with an optional context-parallel
flash-decode path used for ``long_500k``; see ``repro.models.decode_attention``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocked_attention import flash_attention
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core softmax attention (einsum formulation, GQA-aware)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: [B,S,H,hd], k: [B,T,KV,hd] -> scores [B,KV,H/KV,S,T]."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    q = q.reshape(b, s, kv, h // kv, hd)
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


def _gqa_out(p, v):
    """p: [B,KV,G,S,T], v: [B,T,KV,hd] -> [B,S,H,hd]."""
    b, kv, g, s, t = p.shape
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b, s, kv * g, v.shape[-1])


def sdpa(q, k, v, *, causal: bool, window: int = 0,
         q_positions=None, kv_positions=None, mask=None):
    """Scaled dot-product attention with GQA head grouping.

    q: [B,S,H,hd]; k,v: [B,T,KV,hd]. ``window`` > 0 enables sliding-window
    (positions within [pos-window+1, pos]). ``mask`` is an optional additive
    [B,1,1,S,T]-broadcastable mask.
    """
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q * scale, k).astype(jnp.float32)
    s, t = scores.shape[-2], scores.shape[-1]
    if q_positions is None:
        q_positions = jnp.arange(s)
    if kv_positions is None:
        kv_positions = jnp.arange(t)
    rel = q_positions[:, None] - kv_positions[None, :]           # [S, T]
    if causal:
        scores = jnp.where(rel >= 0, scores, NEG_INF)
    if window > 0:
        scores = jnp.where(rel < window, scores, NEG_INF)
    if mask is not None:
        scores = scores + mask
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# GQA self-attention module
# ---------------------------------------------------------------------------

def init_gqa(rng, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def gqa_project_qkv(params, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kv, hd)
    v = (x @ params["wv"]).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_self_attention(params, x, cfg: ModelConfig, *, window: int = 0,
                       positions=None, causal: bool = True):
    """Full-sequence (train/prefill) self attention.

    Routes through the Pallas flash kernel on TPU; the pure-jnp blocked
    flash (same tiling/math — the kernel's oracle family) on other
    backends."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = gqa_project_qkv(params, x, cfg, positions)
    kv, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    from repro.kernels.ops import use_pallas
    if use_pallas() and causal:
        from repro.kernels.ops import flash_attention as pallas_flash
        out = pallas_flash(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True,
                           window=window)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
        return out @ params["wo"]
    q5 = q.reshape(b, s, kv, g, cfg.head_dim).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)                     # [B,KV,T,hd]
    vv = v.transpose(0, 2, 1, 3)
    out = flash_attention(q5, kk, vv, causal=causal, window=window)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, -1)
    return out @ params["wo"]


def gqa_decode_attention(params, x, cfg: ModelConfig, cache, *, window: int = 0):
    """One-token decode against a KV cache.

    cache: {"k": [B,T,KV,hd], "v": [B,T,KV,hd], "pos": scalar int32}
    x: [B,1,D]. Returns (out [B,1,D], new_cache).
    """
    b, s, _ = x.shape
    assert s == 1
    pos = cache["pos"]
    positions = pos[None] if pos.ndim == 0 else pos
    q, k_new, v_new = gqa_project_qkv(params, x, cfg, positions)
    # absolute-slot cache: new K/V written at position ``pos``
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, pos, 0, 0))
    t = k.shape[1]
    kv_positions = jnp.arange(t)
    valid = kv_positions <= pos
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    out = sdpa(q, k, v, causal=False, window=window,
               q_positions=positions, kv_positions=kv_positions, mask=mask)
    new_cache = {"k": k, "v": v, "pos": pos + 1}
    return out.reshape(b, 1, -1) @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — MiniCPM3 / DeepSeek-V2 style
# ---------------------------------------------------------------------------

def init_mla(rng, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(rng, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank,
                           h * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "w_kr": dense_init(ks[5], d, m.qk_rope_head_dim, dtype),
        "wo": dense_init(ks[6], h * m.v_head_dim, d, dtype),
    }


def _mla_q(params, x, cfg, positions):
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    q = (x @ params["w_dq"]) @ params["w_uq"]
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_self_attention(params, x, cfg: ModelConfig, *, window: int = 0,
                       positions=None):
    """Naive (materialized-KV) MLA for train/prefill."""
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    c_kv = x @ params["w_dkv"]                                    # [B,S,r]
    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, m.v_head_dim)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)                           # [B,S,1,rd]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, s, h, m.qk_rope_head_dim))], axis=-1)
    # MLA materializes per-head K/V for train/prefill (MHA: KV=H, G=1)
    q5 = q.transpose(0, 2, 1, 3)[:, :, None]          # [B,H,1,S,hd]
    out = flash_attention(q5, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                          causal=True, window=window)
    out = out[:, :, 0].transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"]


def mla_decode_attention(params, x, cfg: ModelConfig, cache, *, window: int = 0):
    """Absorbed-weight MLA decode: the cache stores only the compressed
    latent ``c_kv`` [B,T,r] and the shared rope key [B,T,rd] — MLA's memory
    advantage. W_uk is absorbed into the query and W_uv into the output.
    """
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    assert s == 1
    pos = cache["pos"]
    positions = pos[None]
    q_nope, q_rope = _mla_q(params, x, cfg, positions)            # [B,1,h,*]
    c_new = x @ params["w_dkv"]                                   # [B,1,r]
    kr_new = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]               # [B,1,rd]
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    # absorb W_uk: q_lat[b,h,r] = sum_d q_nope[b,h,d] * W_uk[r, h*d]
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bhr,btr->bht", q_lat, c_kv.astype(q_lat.dtype))
              + jnp.einsum("bhd,btd->bht", q_rope[:, 0],
                           k_rope.astype(q_rope.dtype))) * scale
    t = c_kv.shape[1]
    kv_positions = jnp.arange(t)
    valid = kv_positions <= pos
    if window > 0:
        valid &= (pos - kv_positions) < window
    scores = jnp.where(valid[None, None, :], scores.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bht,btr->bhr", p, c_kv)                     # latent ctx
    # absorb W_uv: out[b,h,vd] = sum_r ctx[b,h,r] * W_uv[r, h*vd]
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", ctx.astype(x.dtype), w_uv)
    out = out.reshape(b, 1, h * m.v_head_dim) @ params["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope, "pos": pos + 1}


# ---------------------------------------------------------------------------
# cross attention (VLM image layers, whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(rng, cfg: ModelConfig, kv_dim: Optional[int], dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_dim = kv_dim or d
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], kv_dim, kv * hd, dtype),
        "wv": dense_init(ks[2], kv_dim, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def cross_attention(params, x, memory, cfg: ModelConfig, *, kv_override=None):
    """x: [B,S,D] attends over memory [B,T,Dm] (non-causal).

    ``kv_override`` lets decode reuse precomputed (k, v) for the memory.
    """
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    if kv_override is None:
        t = memory.shape[1]
        k = (memory @ params["wk"]).reshape(b, t, kv, hd)
        v = (memory @ params["wv"]).reshape(b, t, kv, hd)
    else:
        k, v = kv_override
    out = sdpa(q, k, v, causal=False)
    return out.reshape(b, s, -1) @ params["wo"]


def cross_attention_kv(params, memory, cfg: ModelConfig):
    b, t, _ = memory.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (memory @ params["wk"]).reshape(b, t, kv, hd)
    v = (memory @ params["wv"]).reshape(b, t, kv, hd)
    return k, v

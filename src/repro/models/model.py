"""Model zoo assembly: init / forward / decode for all six families.

Layer stacks are stored stacked on a leading axis and executed with
``jax.lax.scan`` so the compiled HLO contains a single layer body per stack
(critical for dry-run compile times on 88-layer configs). ``jax.checkpoint``
wraps the scanned body when ``cfg.remat``.

Public API:
    init_params(rng, cfg)                     -> params pytree
    param_specs(cfg)                          -> ShapeDtypeStruct pytree
    forward(params, cfg, batch, window=0)     -> logits [B,S,V], aux
    init_cache(cfg, batch, seq_len, dtype)    -> cache pytree
    decode_step(params, cfg, cache, batch)    -> logits [B,V], cache
    input_specs(cfg, shape)                   -> dict of ShapeDtypeStructs
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import attention as attn
from repro.models import hybrid as hyb
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense_init,
    embed_init,
    ffn,
    init_ffn,
    init_rmsnorm,
    rmsnorm,
)
from repro.sharding.partition import constrain


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        # save matmul outputs (no recompute of the MXU work in bwd); only
        # elementwise/softmax intermediates are recomputed — trades HBM for
        # a ~25% cut of backward FLOPs (§Perf)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ===========================================================================
# per-family layer init
# ===========================================================================

def _init_dense_layer(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 2)
    if cfg.attn_type == "mla":
        a = attn.init_mla(ks[0], cfg, dtype)
    else:
        a = attn.init_gqa(ks[0], cfg, dtype)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": a,
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dtype),
    }


def _init_moe_layer(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "moe_norm": init_rmsnorm(cfg.d_model),
        "moe": moe_mod.init_moe(ks[1], cfg, dtype),
    }


def _init_cross_layer(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "norm": init_rmsnorm(cfg.d_model),
        "xattn": attn.init_cross_attention(ks[0], cfg, cfg.vision_embed_dim or None, dtype),
        "gate": jnp.zeros((), jnp.float32),   # zero-init gated cross-attn
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dtype),
    }


def _init_enc_layer(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dtype),
    }


def _init_dec_layer(rng, cfg: ModelConfig, dtype):
    """Whisper decoder layer: self-attn + cross-attn + FFN."""
    ks = jax.random.split(rng, 3)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": attn.init_gqa(ks[0], cfg, dtype),
        "x_norm": init_rmsnorm(cfg.d_model),
        "xattn": attn.init_cross_attention(ks[1], cfg, None, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dtype),
    }


def _stacked(rng, n, init_one):
    keys = jax.random.split(rng, n)
    return jax.vmap(init_one)(keys)


# ===========================================================================
# init_params
# ===========================================================================

def hybrid_period_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.block_pattern or cfg.xlstm_pattern
    n_periods = cfg.num_layers // len(pat)
    remainder = tuple(pat[: cfg.num_layers - n_periods * len(pat)])
    return n_periods, remainder


def init_params(rng, cfg: ModelConfig):
    dtype = _dtype(cfg)
    ks = jax.random.split(rng, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.padded_vocab, dtype)

    fam = cfg.family
    if fam == "dense":
        params["layers"] = _stacked(ks[2], cfg.num_layers,
                                    lambda r: _init_dense_layer(r, cfg, dtype))
    elif fam == "moe":
        params["layers"] = _stacked(ks[2], cfg.num_layers,
                                    lambda r: _init_moe_layer(r, cfg, dtype))
    elif fam == "hybrid":
        n_p, rem = hybrid_period_layout(cfg)
        pat = cfg.block_pattern

        def init_period(r):
            keys = jax.random.split(r, len(pat))
            return {f"b{i}_{kind}": (hyb.init_recurrent_block(keys[i], cfg, dtype)
                                     if kind == "rglru"
                                     else hyb.init_local_attn_block(keys[i], cfg, dtype))
                    for i, kind in enumerate(pat)}

        params["periods"] = _stacked(ks[2], n_p, init_period)
        rem_keys = jax.random.split(ks[3], max(len(rem), 1))
        params["rem"] = [
            (hyb.init_recurrent_block(rem_keys[i], cfg, dtype) if kind == "rglru"
             else hyb.init_local_attn_block(rem_keys[i], cfg, dtype))
            for i, kind in enumerate(rem)]
    elif fam == "ssm":
        n_p, rem = hybrid_period_layout(cfg)
        pat = cfg.xlstm_pattern

        def init_period(r):
            keys = jax.random.split(r, len(pat))
            return {f"b{i}_{kind}": (ssm_mod.init_mlstm_block(keys[i], cfg, dtype)
                                     if kind == "mlstm"
                                     else ssm_mod.init_slstm_block(keys[i], cfg, dtype))
                    for i, kind in enumerate(pat)}

        params["periods"] = _stacked(ks[2], n_p, init_period)
        assert not rem, "xlstm pattern must tile num_layers"
    elif fam == "vlm":
        period = cfg.cross_attn_every
        n_p = cfg.num_layers // period
        n_self = period - 1
        params["periods"] = _stacked(
            ks[2], n_p,
            lambda r: {
                "self": _stacked(r, n_self,
                                 lambda r2: _init_dense_layer(r2, cfg, dtype)),
                "cross": _init_cross_layer(jax.random.fold_in(r, 7), cfg, dtype),
            })
    elif fam == "audio":
        params["enc_layers"] = _stacked(ks[2], cfg.encoder_layers,
                                        lambda r: _init_enc_layer(r, cfg, dtype))
        params["dec_layers"] = _stacked(ks[3], cfg.num_layers,
                                        lambda r: _init_dec_layer(r, cfg, dtype))
        params["enc_norm"] = init_rmsnorm(cfg.d_model)
        params["frame_proj"] = dense_init(ks[4], cfg.d_model, cfg.d_model, dtype)
    else:
        raise ValueError(fam)
    return params


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

def _dense_layer_fwd(lp, h, cfg: ModelConfig, window: int):
    xn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    if cfg.attn_type == "mla":
        h = h + attn.mla_self_attention(lp["attn"], xn, cfg, window=window)
    else:
        h = h + attn.gqa_self_attention(lp["attn"], xn, cfg, window=window)
    xm = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
    return h + ffn(lp["mlp"], xm, cfg.act)


def _moe_layer_fwd(lp, h, cfg: ModelConfig, window: int):
    xn = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    h = h + attn.gqa_self_attention(lp["attn"], xn, cfg, window=window)
    xm = rmsnorm(lp["moe_norm"], h, cfg.norm_eps)
    y, aux = moe_mod.moe_ffn(lp["moe"], xm, cfg)
    return h + y, aux


def _cross_layer_fwd(lp, h, memory, cfg: ModelConfig, kv_override=None):
    xn = rmsnorm(lp["norm"], h, cfg.norm_eps)
    y = attn.cross_attention(lp["xattn"], xn, memory, cfg,
                             kv_override=kv_override)
    h = h + jnp.tanh(lp["gate"]).astype(h.dtype) * y
    xm = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
    return h + ffn(lp["mlp"], xm, cfg.act)


def _sinusoidal(seq: int, dim: int, dtype):
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angles = pos / (10000 ** (2 * i / dim))
    emb = np.concatenate([np.sin(angles), np.cos(angles)], axis=-1)
    return jnp.asarray(emb, dtype)


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                   *, window: int = 0):
    """Full-sequence forward up to the final norm. Returns (h [B,S,D], aux)."""
    tokens = batch["tokens"]
    h = params["embed"][tokens]                    # gather: [B,S,D]
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam == "dense":
        body = _maybe_remat(
            lambda hh, lp: (constrain(_dense_layer_fwd(lp, hh, cfg, window)), None), cfg)
        h, _ = jax.lax.scan(body, h, params["layers"])
    elif fam == "moe":
        def body(hh, lp):
            hh, a = _moe_layer_fwd(lp, hh, cfg, window)
            return constrain(hh), a
        h, auxs = jax.lax.scan(_maybe_remat(body, cfg), h, params["layers"])
        aux = aux + cfg.router_aux_loss_coef * jnp.sum(auxs)
    elif fam == "hybrid":
        pat = cfg.block_pattern

        def period_fwd(hh, pp):
            for i, kind in enumerate(pat):
                lp = pp[f"b{i}_{kind}"]
                if kind == "rglru":
                    hh, _ = hyb.recurrent_block(lp, hh, cfg)
                else:
                    hh, _ = hyb.local_attn_block(lp, hh, cfg)
            return hh, None

        h, _ = jax.lax.scan(_maybe_remat(period_fwd, cfg), h, params["periods"])
        _, rem = hybrid_period_layout(cfg)
        for lp, kind in zip(params["rem"], rem):
            if kind == "rglru":
                h, _ = hyb.recurrent_block(lp, h, cfg)
            else:
                h, _ = hyb.local_attn_block(lp, h, cfg)
    elif fam == "ssm":
        pat = cfg.xlstm_pattern

        def period_fwd(hh, pp):
            for i, kind in enumerate(pat):
                lp = pp[f"b{i}_{kind}"]
                if kind == "mlstm":
                    hh, _ = ssm_mod.mlstm_block(lp, hh, cfg)
                else:
                    hh, _ = ssm_mod.slstm_block(lp, hh, cfg)
            return hh, None

        h, _ = jax.lax.scan(_maybe_remat(period_fwd, cfg), h, params["periods"])
    elif fam == "vlm":
        memory = batch["vision_embeddings"].astype(h.dtype)

        def period_fwd(hh, pp):
            def self_body(hh2, lp):
                return _dense_layer_fwd(lp, hh2, cfg, window), None
            hh, _ = jax.lax.scan(self_body, hh, pp["self"])
            hh = _cross_layer_fwd(pp["cross"], hh, memory, cfg)
            return hh, None

        h, _ = jax.lax.scan(_maybe_remat(period_fwd, cfg), h, params["periods"])
    elif fam == "audio":
        frames = batch["frames"].astype(h.dtype)
        e = frames @ params["frame_proj"]
        e = e + _sinusoidal(e.shape[1], cfg.d_model, e.dtype)[None]

        def enc_body(hh, lp):
            xn = rmsnorm(lp["attn_norm"], hh, cfg.norm_eps)
            hh = hh + attn.gqa_self_attention(lp["attn"], xn, cfg, causal=False)
            xm = rmsnorm(lp["mlp_norm"], hh, cfg.norm_eps)
            return hh + ffn(lp["mlp"], xm, cfg.act), None

        e, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), e, params["enc_layers"])
        memory = rmsnorm(params["enc_norm"], e, cfg.norm_eps)

        def dec_body(hh, lp):
            xn = rmsnorm(lp["attn_norm"], hh, cfg.norm_eps)
            hh = hh + attn.gqa_self_attention(lp["attn"], xn, cfg, window=window)
            xq = rmsnorm(lp["x_norm"], hh, cfg.norm_eps)
            hh = hh + attn.cross_attention(lp["xattn"], xq, memory, cfg)
            xm = rmsnorm(lp["mlp_norm"], hh, cfg.norm_eps)
            return hh + ffn(lp["mlp"], xm, cfg.act), None

        h, _ = jax.lax.scan(_maybe_remat(dec_body, cfg), h, params["dec_layers"])
    else:
        raise ValueError(fam)

    return rmsnorm(params["final_norm"], h, cfg.norm_eps), aux


def logits_from_hidden(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, window: int = 0):
    """Full-sequence forward. Returns (logits [B,S,V], aux scalar)."""
    h, aux = forward_hidden(params, cfg, batch, window=window)
    return logits_from_hidden(params, cfg, h), aux


# ===========================================================================
# KV / recurrent cache
# ===========================================================================

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=None, memory_len: int = 0):
    """Decode cache pytree for ``decode_step``.

    ``seq_len`` is the maximum context (cache capacity) for attention archs;
    SSM/hybrid archs carry O(1) recurrent state (plus a window ring buffer for
    local attention). ``memory_len`` sizes cross-attention memory (vlm/audio).
    """
    dtype = dtype or _dtype(cfg)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    fam = cfg.family
    pos = jnp.zeros((), jnp.int32)

    def kv_stack(n, t):
        return {"k": jnp.zeros((n, batch, t, kv, hd), dtype),
                "v": jnp.zeros((n, batch, t, kv, hd), dtype)}

    if fam == "dense" and cfg.attn_type == "mla":
        m = cfg.mla
        return {"layers": {
            "c_kv": jnp.zeros((cfg.num_layers, batch, seq_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((cfg.num_layers, batch, seq_len, m.qk_rope_head_dim), dtype),
        }, "pos": pos}
    if fam in ("dense", "moe"):
        return {"layers": kv_stack(cfg.num_layers, seq_len), "pos": pos}
    if fam == "hybrid":
        n_p, rem = hybrid_period_layout(cfg)
        pat = cfg.block_pattern
        w = min(cfg.local_attn_window, seq_len)

        def period_state(kind_idx):
            st = {}
            for i, kind in enumerate(pat):
                if kind == "rglru":
                    conv, rg = hyb.recurrent_state_init(cfg, batch, dtype)
                    st[f"b{i}_rglru"] = {
                        "conv": jnp.broadcast_to(conv, (n_p,) + conv.shape),
                        "rg": jnp.broadcast_to(rg, (n_p,) + rg.shape)}
                else:
                    st[f"b{i}_local_attn"] = {
                        "k": jnp.zeros((n_p, batch, w, kv, hd), dtype),
                        "v": jnp.zeros((n_p, batch, w, kv, hd), dtype)}
            return st

        cache = {"periods": period_state(pat), "kv_pos": jnp.full((w,), -1, jnp.int32),
                 "pos": pos, "rem": []}
        for kind in rem:
            if kind == "rglru":
                conv, rg = hyb.recurrent_state_init(cfg, batch, dtype)
                cache["rem"].append({"conv": conv, "rg": rg})
            else:
                cache["rem"].append({"k": jnp.zeros((batch, w, kv, hd), dtype),
                                     "v": jnp.zeros((batch, w, kv, hd), dtype)})
        return cache
    if fam == "ssm":
        n_p, _ = hybrid_period_layout(cfg)
        pat = cfg.xlstm_pattern
        st = {}
        for i, kind in enumerate(pat):
            if kind == "mlstm":
                conv, (C, n, m) = ssm_mod.mlstm_state_init(cfg, batch, dtype)
                st[f"b{i}_mlstm"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_p,) + x.shape),
                    {"conv": conv, "C": C, "n": n, "m": m})
            else:
                c, n, h, m = ssm_mod.slstm_state_init(cfg, batch, dtype)
                st[f"b{i}_slstm"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_p,) + x.shape),
                    {"c": c, "n": n, "h": h, "m": m})
        return {"periods": st, "pos": pos}
    if fam == "vlm":
        period = cfg.cross_attn_every
        n_p = cfg.num_layers // period
        n_self = period - 1
        mem = memory_len or cfg.vision_tokens
        return {"self": {"k": jnp.zeros((n_p, n_self, batch, seq_len, kv, hd), dtype),
                         "v": jnp.zeros((n_p, n_self, batch, seq_len, kv, hd), dtype)},
                "cross": kv_stack(n_p, mem),
                "pos": pos}
    if fam == "audio":
        mem = memory_len or max(seq_len // cfg.encoder_frame_ratio, 1)
        return {"self": kv_stack(cfg.num_layers, seq_len),
                "cross": kv_stack(cfg.num_layers, mem),
                "pos": pos}
    raise ValueError(fam)


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int, memory_len: int = 0):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, seq_len, memory_len=memory_len))


# ===========================================================================
# decode_step — one new token against the cache
# ===========================================================================

def _ring_attn_decode(lp, xn, cfg: ModelConfig, kcache, vcache, kv_pos, pos):
    """Sliding-window decode against a ring buffer cache (hybrid archs)."""
    b = xn.shape[0]
    w = kcache.shape[1]
    slot = jnp.mod(pos, w)
    q, k_new, v_new = attn.gqa_project_qkv(lp, xn, cfg, pos[None] if pos.ndim == 0 else pos)
    k = jax.lax.dynamic_update_slice(kcache, k_new.astype(kcache.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(vcache, v_new.astype(vcache.dtype),
                                     (0, slot, 0, 0))
    rel = pos - kv_pos
    valid = (kv_pos >= 0) & (rel >= 0) & (rel < cfg.local_attn_window)
    valid = valid | (jnp.arange(w) == slot)
    mask = jnp.where(valid, 0.0, attn.NEG_INF)[None, None, None, None, :]
    kvp = jnp.where(jnp.arange(w) == slot, pos, kv_pos)
    out = attn.sdpa(q, k, v, causal=False,
                    q_positions=pos[None], kv_positions=kvp, mask=mask)
    return out.reshape(b, 1, -1) @ lp["wo"], k, v


def decode_step(params, cfg: ModelConfig, cache, batch: Dict[str, jnp.ndarray],
                *, window: int = 0):
    """tokens: [B,1] -> (logits [B,V], new_cache)."""
    tokens = batch["tokens"]
    h = params["embed"][tokens]
    pos = cache["pos"]
    fam = cfg.family

    if fam in ("dense", "moe") and cfg.attn_type != "mla":
        def body(hh, xs):
            lp, lc = xs
            xn = rmsnorm(lp["attn_norm"], hh, cfg.norm_eps)
            y, new_c = attn.gqa_decode_attention(
                lp["attn"], xn, cfg, {"k": lc["k"], "v": lc["v"], "pos": pos},
                window=window)
            hh = hh + y
            if fam == "moe":
                xm = rmsnorm(lp["moe_norm"], hh, cfg.norm_eps)
                y2, _ = moe_mod.moe_ffn(lp["moe"], xm, cfg)
            else:
                xm = rmsnorm(lp["mlp_norm"], hh, cfg.norm_eps)
                y2 = ffn(lp["mlp"], xm, cfg.act)
            return hh + y2, {"k": new_c["k"], "v": new_c["v"]}

        h, new_layers = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers, "pos": pos + 1}
    elif fam == "dense":  # MLA
        def body(hh, xs):
            lp, lc = xs
            xn = rmsnorm(lp["attn_norm"], hh, cfg.norm_eps)
            y, new_c = attn.mla_decode_attention(
                lp["attn"], xn, cfg,
                {"c_kv": lc["c_kv"], "k_rope": lc["k_rope"], "pos": pos},
                window=window)
            hh = hh + y
            xm = rmsnorm(lp["mlp_norm"], hh, cfg.norm_eps)
            return hh + ffn(lp["mlp"], xm, cfg.act), \
                {"c_kv": new_c["c_kv"], "k_rope": new_c["k_rope"]}

        h, new_layers = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers, "pos": pos + 1}
    elif fam == "hybrid":
        pat = cfg.block_pattern
        kv_pos = cache["kv_pos"]

        def period_body(hh, xs):
            pp, pc = xs
            new_pc = {}
            for i, kind in enumerate(pat):
                lp = pp[f"b{i}_{kind}"]
                if kind == "rglru":
                    st = pc[f"b{i}_rglru"]
                    hh, (conv, rg) = hyb.recurrent_block(
                        lp, hh, cfg, state=(st["conv"], st["rg"]))
                    new_pc[f"b{i}_rglru"] = {"conv": conv, "rg": rg}
                else:
                    st = pc[f"b{i}_local_attn"]
                    xn = rmsnorm(lp["norm"], hh, cfg.norm_eps)
                    y, k, v = _ring_attn_decode(lp["attn"], xn, cfg,
                                                st["k"], st["v"], kv_pos, pos)
                    hh = hh + y
                    xm = rmsnorm(lp["mlp_norm"], hh, cfg.norm_eps)
                    hh = hh + ffn(lp["mlp"], xm, cfg.act)
                    new_pc[f"b{i}_local_attn"] = {"k": k, "v": v}
            return hh, new_pc

        h, new_periods = jax.lax.scan(period_body, h,
                                      (params["periods"], cache["periods"]))
        new_rem = []
        _, rem = hybrid_period_layout(cfg)
        for lp, st, kind in zip(params["rem"], cache["rem"], rem):
            if kind == "rglru":
                h, (conv, rg) = hyb.recurrent_block(
                    lp, h, cfg, state=(st["conv"], st["rg"]))
                new_rem.append({"conv": conv, "rg": rg})
            else:
                xn = rmsnorm(lp["norm"], h, cfg.norm_eps)
                y, k, v = _ring_attn_decode(lp["attn"], xn, cfg,
                                            st["k"], st["v"], kv_pos, pos)
                h = h + y
                xm = rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
                h = h + ffn(lp["mlp"], xm, cfg.act)
                new_rem.append({"k": k, "v": v})
        w = kv_pos.shape[0]
        slot = jnp.mod(pos, w)
        new_kv_pos = jnp.where(jnp.arange(w) == slot, pos, kv_pos)
        new_cache = {"periods": new_periods, "rem": new_rem,
                     "kv_pos": new_kv_pos, "pos": pos + 1}
    elif fam == "ssm":
        pat = cfg.xlstm_pattern

        def period_body(hh, xs):
            pp, pc = xs
            new_pc = {}
            for i, kind in enumerate(pat):
                lp = pp[f"b{i}_{kind}"]
                if kind == "mlstm":
                    st = pc[f"b{i}_mlstm"]
                    hh, (conv, (C, n, m)) = ssm_mod.mlstm_block(
                        lp, hh, cfg, state=(st["conv"], (st["C"], st["n"], st["m"])))
                    new_pc[f"b{i}_mlstm"] = {"conv": conv, "C": C, "n": n, "m": m}
                else:
                    st = pc[f"b{i}_slstm"]
                    hh, (c, n, hs, m) = ssm_mod.slstm_block(
                        lp, hh, cfg, state=(st["c"], st["n"], st["h"], st["m"]))
                    new_pc[f"b{i}_slstm"] = {"c": c, "n": n, "h": hs, "m": m}
            return hh, new_pc

        h, new_periods = jax.lax.scan(period_body, h,
                                      (params["periods"], cache["periods"]))
        new_cache = {"periods": new_periods, "pos": pos + 1}
    elif fam == "vlm":
        def period_body(hh, xs):
            pp, sc, cc = xs

            def self_body(hh2, xs2):
                lp, lc = xs2
                xn = rmsnorm(lp["attn_norm"], hh2, cfg.norm_eps)
                y, new_c = attn.gqa_decode_attention(
                    lp["attn"], xn, cfg,
                    {"k": lc["k"], "v": lc["v"], "pos": pos}, window=window)
                hh2 = hh2 + y
                xm = rmsnorm(lp["mlp_norm"], hh2, cfg.norm_eps)
                return hh2 + ffn(lp["mlp"], xm, cfg.act), \
                    {"k": new_c["k"], "v": new_c["v"]}

            hh, new_sc = jax.lax.scan(self_body, hh, (pp["self"], sc))
            xn = rmsnorm(pp["cross"]["norm"], hh, cfg.norm_eps)
            y = attn.cross_attention(pp["cross"]["xattn"], xn, None, cfg,
                                     kv_override=(cc["k"], cc["v"]))
            hh = hh + jnp.tanh(pp["cross"]["gate"]).astype(hh.dtype) * y
            xm = rmsnorm(pp["cross"]["mlp_norm"], hh, cfg.norm_eps)
            hh = hh + ffn(pp["cross"]["mlp"], xm, cfg.act)
            return hh, new_sc

        h, new_self = jax.lax.scan(period_body, h,
                                   (params["periods"], cache["self"],
                                    cache["cross"]))
        new_cache = {"self": new_self, "cross": cache["cross"], "pos": pos + 1}
    elif fam == "audio":
        def body(hh, xs):
            lp, sc, cc = xs
            xn = rmsnorm(lp["attn_norm"], hh, cfg.norm_eps)
            y, new_c = attn.gqa_decode_attention(
                lp["attn"], xn, cfg, {"k": sc["k"], "v": sc["v"], "pos": pos},
                window=window)
            hh = hh + y
            xq = rmsnorm(lp["x_norm"], hh, cfg.norm_eps)
            hh = hh + attn.cross_attention(lp["xattn"], xq, None, cfg,
                                           kv_override=(cc["k"], cc["v"]))
            xm = rmsnorm(lp["mlp_norm"], hh, cfg.norm_eps)
            return hh + ffn(lp["mlp"], xm, cfg.act), \
                {"k": new_c["k"], "v": new_c["v"]}

        h, new_self = jax.lax.scan(body, h, (params["dec_layers"],
                                             cache["self"], cache["cross"]))
        new_cache = {"self": new_self, "cross": cache["cross"], "pos": pos + 1}
    else:
        raise ValueError(fam)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h @ params["embed"].T) if cfg.tie_embeddings else (h @ params["lm_head"])
    return logits[:, 0], new_cache


# ===========================================================================
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ===========================================================================

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = _dtype(cfg)
    S = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": S((b, s), i32), "targets": S((b, s), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": S((b, s), i32)}
    else:  # decode
        specs = {"tokens": S((b, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeddings"] = S((b, cfg.vision_tokens,
                                        cfg.vision_embed_dim or cfg.d_model), dt)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = S((b, max(s // cfg.encoder_frame_ratio, 1),
                             cfg.d_model), dt)
    return specs

"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan).

TPU adaptation (see DESIGN.md): the xLSTM paper ships fused CUDA step kernels;
on TPU the mLSTM is computed in *chunkwise-parallel* form — within a chunk the
recurrence unrolls into an attention-like masked matmul (MXU-friendly), across
chunks a small ``lax.scan`` carries the (C, n, m) state. The sLSTM recurrence
is inherently sequential (recurrent R matrices break associativity), so it is
a ``lax.scan`` over time — its per-step work is a small block-diagonal matmul.

All recurrences are numerically stabilized in log space with a running max
``m`` (exponential gating as in the paper, Appendix A).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    causal_conv1d,
    dense_init,
    init_conv1d,
    init_rmsnorm,
    rmsnorm,
)

NEG_INF = -1e30


# ===========================================================================
# mLSTM — matrix memory with exponential gating, chunkwise parallel
# ===========================================================================

def mlstm_chunk_step(carry, inputs, *, eps: float = 1e-6):
    """One chunk of the stabilized mLSTM recurrence.

    carry: C [B,H,dk,dv], n [B,H,dk], m [B,H]
    inputs: q,k,v [B,H,L,d*], i_pre,f_pre [B,H,L]
    """
    C, n, m = carry
    q, k, v, i_pre, f_pre = inputs
    L = q.shape[2]
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))          # [B,H,L]
    b = jnp.cumsum(logf, axis=-1)                                  # decay to t
    a = i_pre.astype(jnp.float32) - b                              # source logit
    bL = b[..., -1]

    # stabilizers
    a_run_max = jax.lax.cummax(a, axis=a.ndim - 1)                 # [B,H,L]
    m_loc = jnp.maximum(b + a_run_max, b + m[..., None])           # [B,H,L]

    # intra-chunk: D[t,s] = exp(b_t + a_s - m_loc_t) for s <= t
    expo = b[..., :, None] + a[..., None, :] - m_loc[..., :, None]
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask, jnp.exp(expo), 0.0)                        # [B,H,L,L]
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k).astype(jnp.float32) * scale
    w = scores * D                                                 # [B,H,L,L]
    h_intra = jnp.einsum("bhts,bhsd->bhtd", w, v.astype(jnp.float32))

    # inter-chunk: contribution of carried state
    inter_w = jnp.exp(b + m[..., None] - m_loc)                    # [B,H,L]
    qf = q.astype(jnp.float32) * scale
    qC = jnp.einsum("bhtd,bhde->bhte", qf, C)                      # [B,H,L,dv]
    qn = jnp.einsum("bhtd,bhd->bht", qf, n)
    h_num = h_intra + inter_w[..., None] * qC
    # normalizer n_t . q_t = sum_s w[t,s] + inter_w * (q . n_prev),
    # floored at the stabilized unit exp(-m_loc) (paper's max(|n.q|, 1))
    denom = jnp.maximum(
        jnp.abs(jnp.sum(w, axis=-1) + inter_w * qn), jnp.exp(-m_loc)) + eps
    h = h_num / denom[..., None]

    # state update to end of chunk
    m_new = bL + jnp.maximum(m, jnp.max(a, axis=-1))
    state_w = jnp.exp(bL[..., None] + a - m_new[..., None])        # [B,H,L]
    C_new = (jnp.exp(bL + m - m_new)[..., None, None] * C
             + jnp.einsum("bhs,bhsd,bhse->bhde", state_w,
                          k.astype(jnp.float32), v.astype(jnp.float32)))
    n_new = (jnp.exp(bL + m - m_new)[..., None] * n
             + jnp.einsum("bhs,bhsd->bhd", state_w, k.astype(jnp.float32)))
    return (C_new, n_new, m_new), h


def mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int, state=None):
    """q,k,v: [B,H,S,d]; gates [B,H,S]. Returns (h [B,H,S,dv], state).

    On TPU with no carried state the Pallas chunk-scan kernel
    (repro.kernels.mlstm_scan) takes this path instead."""
    if state is None:
        from repro.kernels.ops import use_pallas
        if use_pallas():
            from repro.kernels.ops import mlstm_scan as pallas_mlstm
            return pallas_mlstm(q, k, v, i_pre, f_pre, chunk=chunk)
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0 or s < chunk, (s, chunk)
    L = min(chunk, s)
    nc = max(1, s // L)
    if state is None:
        state = (jnp.zeros((b, h, dk, dv), jnp.float32),
                 jnp.zeros((b, h, dk), jnp.float32),
                 jnp.full((b, h), 0.0, jnp.float32))

    def reshape_c(x, d=None):
        if d is None:
            return x.reshape(b, h, nc, L).transpose(2, 0, 1, 3)
        return x.reshape(b, h, nc, L, d).transpose(2, 0, 1, 3, 4)

    qs, ks_, vs = reshape_c(q, dk), reshape_c(k, dk), reshape_c(v, dv)
    is_, fs = reshape_c(i_pre), reshape_c(f_pre)

    def step(carry, xs):
        return mlstm_chunk_step(carry, xs)

    state, hs = jax.lax.scan(step, state, (qs, ks_, vs, is_, fs))
    h_out = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dv)
    return h_out.astype(v.dtype), state


def mlstm_recurrent_step(state, q, k, v, i_pre, f_pre, eps: float = 1e-6):
    """Single-token decode step. q,k,v: [B,H,d]; gates [B,H]."""
    C, n, m = state
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i_log = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, i_log)
    f_eff = jnp.exp(logf + m - m_new)
    i_eff = jnp.exp(i_log - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = f_eff[..., None, None] * C + i_eff[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n_new = f_eff[..., None] * n + i_eff[..., None] * kf
    scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                      jnp.exp(-m_new)) + eps
    return (C_new, n_new, m_new), (num / den[..., None]).astype(v.dtype)


# ---------------------------------------------------------------------------
# mLSTM block (pre-up-projection)
# ---------------------------------------------------------------------------

def init_mlstm_block(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_up = 2 * d
    h = cfg.num_heads
    dk = d_up // h
    ks = jax.random.split(rng, 9)
    return {
        "norm": init_rmsnorm(d),
        "w_up": dense_init(ks[0], d, 2 * d_up, dtype),      # [u | z]
        "conv": init_conv1d(ks[1], d_up, 4, dtype),
        "wq": dense_init(ks[2], d_up, d_up, dtype),
        "wk": dense_init(ks[3], d_up, d_up, dtype),
        "wv": dense_init(ks[4], d_up, d_up, dtype),
        "w_i": dense_init(ks[5], d_up, h, dtype),
        "w_f": dense_init(ks[6], d_up, h, dtype),
        "out_norm": init_rmsnorm(d_up),
        "w_down": dense_init(ks[7], d_up, d, dtype),
        "b_f": jnp.full((h,), 3.0, jnp.float32),             # forget bias init
    }


def _mlstm_qkvif(params, u_conv, cfg):
    b, s, d_up = u_conv.shape
    h = cfg.num_heads
    dk = d_up // h
    def heads(y):
        return y.reshape(b, s, h, dk).transpose(0, 2, 1, 3)
    q = heads(u_conv @ params["wq"])
    k = heads(u_conv @ params["wk"])
    v = heads(u_conv @ params["wv"])
    i_pre = (u_conv @ params["w_i"]).transpose(0, 2, 1)      # [B,H,S]
    f_pre = (u_conv @ params["w_f"]).transpose(0, 2, 1) + params["b_f"][None, :, None]
    return q, k, v, i_pre, f_pre


def mlstm_block(params, x, cfg: ModelConfig, state=None):
    """x: [B,S,D] -> (y, new_state). state: (conv_state, (C, n, m)) or None."""
    b, s, d = x.shape
    h = cfg.num_heads
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    uz = xn @ params["w_up"]
    u, z = jnp.split(uz, 2, axis=-1)                          # [B,S,d_up]
    conv_state = None if state is None else state[0]
    u_conv, conv_state = causal_conv1d(params["conv"], u, conv_state)
    u_conv = jax.nn.silu(u_conv)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, u_conv, cfg)
    rec_state = None if state is None else state[1]
    if s == 1 and rec_state is not None:
        rec_state, hh = mlstm_recurrent_step(
            rec_state, q[:, :, 0], k[:, :, 0], v[:, :, 0],
            i_pre[:, :, 0], f_pre[:, :, 0])
        hh = hh[:, :, None, :]
    else:
        hh, rec_state = mlstm_chunked(q, k, v, i_pre, f_pre,
                                      cfg.mlstm_chunk, rec_state)
    hh = hh.transpose(0, 2, 1, 3).reshape(b, s, -1)           # [B,S,d_up]
    hh = rmsnorm(params["out_norm"], hh, cfg.norm_eps)
    y = (hh * jax.nn.silu(z)) @ params["w_down"]
    return x + y, (conv_state, rec_state)


def mlstm_state_init(cfg: ModelConfig, batch: int, dtype):
    d_up = 2 * cfg.d_model
    h = cfg.num_heads
    dk = d_up // h
    conv = jnp.zeros((batch, 3, d_up), dtype)
    rec = (jnp.zeros((batch, h, dk, dk), jnp.float32),
           jnp.zeros((batch, h, dk), jnp.float32),
           jnp.zeros((batch, h), jnp.float32))
    return (conv, rec)


# ===========================================================================
# sLSTM — scalar memory, sequential scan with recurrent block-diagonal R
# ===========================================================================

def init_slstm_block(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(rng, 8)
    def rmat(key):
        return (jax.random.normal(key, (h, hd, hd)) / (hd ** 0.5)).astype(dtype)
    pf = int(d * 4 / 3)
    return {
        "norm": init_rmsnorm(d),
        "w_zifo": dense_init(ks[0], d, 4 * d, dtype),
        "r_z": rmat(ks[1]), "r_i": rmat(ks[2]),
        "r_f": rmat(ks[3]), "r_o": rmat(ks[4]),
        "b_zifo": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": init_rmsnorm(d),
        "w_up": dense_init(ks[5], d, 2 * pf, dtype),          # gated FFN
        "w_down": dense_init(ks[6], pf, d, dtype),
        "b_f": jnp.full((d,), 3.0, jnp.float32),
    }


def _slstm_cell(params, carry, x_t, cfg: ModelConfig):
    """carry: (c, n, h, m) each [B, D]. x_t: [B, 4D] preactivations (input part)."""
    c, n, h_prev, m = carry
    b = x_t.shape[0]
    H, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    hp = h_prev.reshape(b, H, hd)
    def rec(r):
        return jnp.einsum("bhd,hde->bhe", hp, r).reshape(b, -1)
    z_in, i_in, f_in, o_in = jnp.split(x_t + params["b_zifo"], 4, axis=-1)
    z = jnp.tanh(z_in + rec(params["r_z"]))
    i_log = (i_in + rec(params["r_i"])).astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        (f_in + rec(params["r_f"])).astype(jnp.float32) + params["b_f"])
    o = jax.nn.sigmoid(o_in + rec(params["r_o"]))
    m_new = jnp.maximum(f_log + m, i_log)
    f_eff = jnp.exp(f_log + m - m_new)
    i_eff = jnp.exp(i_log - m_new)
    c_new = f_eff * c + i_eff * z.astype(jnp.float32)
    n_new = f_eff * n + i_eff
    h_new = (o.astype(jnp.float32) * c_new /
             jnp.maximum(n_new, 1e-6)).astype(x_t.dtype)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(params, x, cfg: ModelConfig, state=None):
    """x: [B,S,D] -> (y, new_state)."""
    b, s, d = x.shape
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    pre = xn @ params["w_zifo"]                               # [B,S,4D]
    if state is None:
        state = slstm_state_init(cfg, b, x.dtype)

    def step(carry, x_t):
        return _slstm_cell(params, carry, x_t, cfg)

    state, hs = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)                                # [B,S,D]
    hs = rmsnorm(params["out_norm"], hs, cfg.norm_eps)
    u, g = jnp.split(hs @ params["w_up"], 2, axis=-1)
    y = (u * jax.nn.gelu(g)) @ params["w_down"]
    return x + y, state


def slstm_state_init(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, d), jnp.float32))

"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

TPU-idiomatic formulation: tokens are scattered into a dense per-expert
buffer ``[E, C, D]`` (C = capacity) so the expert computation is a single
``[E, C, D] x [E, D, F]`` batched matmul that shards cleanly over the
``model`` mesh axis (expert parallelism). The scatter/gather between the
token-sharded and expert-sharded layouts is where XLA inserts the
all-to-all-like collectives that dominate MoE roofline terms.

Positions within each expert are computed with a cumulative-sum over the
one-hot assignment matrix (Switch-Transformer style), avoiding the huge
``[T, E, C]`` dispatch one-hot. Tokens beyond capacity are dropped (their
combine weight is zero), matching standard capacity-factor semantics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_moe(rng, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e, d, f)) / (d ** 0.5)).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, d, f)) / (d ** 0.5)).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e, f, d)) / (f ** 0.5)).astype(dtype),
    }
    if cfg.moe_dense_residual:
        from repro.models.layers import init_ffn
        p["dense_residual"] = init_ffn(ks[4], d, f, True, dtype)
    return p


def router_probs(params, x):
    """x: [T, D] -> probs [T, E] (f32 router as is standard practice)."""
    logits = x.astype(jnp.float32) @ params["router"]
    return jax.nn.softmax(logits, axis=-1), logits


def load_balance_loss(probs, expert_idx, num_experts: int):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    one_hot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.float32)
    f = one_hot.mean(axis=(0, 1))          # fraction of assignments per expert
    p = probs.mean(axis=0)                 # mean router prob per expert
    return num_experts * jnp.sum(f * p)


def moe_ffn(params, x, cfg: ModelConfig, *, capacity_factor: float = 1.25
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(b * s, d)
    t = b * s

    probs, _ = router_probs(params, xt)                       # [T, E]
    weights, expert_idx = jax.lax.top_k(probs, k)             # [T, K]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    aux = load_balance_loss(probs, expert_idx, e)

    capacity = max(1, int(capacity_factor * t * k / e))

    # flatten assignments; row-major order keeps earlier tokens prioritized
    flat_expert = expert_idx.reshape(-1)                      # [T*K]
    one_hot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32) # [T*K, E]
    pos_in_expert = jnp.cumsum(one_hot, axis=0) - one_hot     # [T*K, E]
    flat_pos = jnp.sum(pos_in_expert * one_hot, axis=-1)      # [T*K]
    keep = flat_pos < capacity
    flat_pos = jnp.where(keep, flat_pos, capacity - 1)

    token_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    contrib = jnp.where(keep[:, None], xt[token_idx], 0).astype(x.dtype)
    buf = buf.at[flat_expert, flat_pos].add(contrib, mode="drop")
    from repro.sharding.partition import constrain_moe_buffer
    buf = constrain_moe_buffer(buf)

    # expert computation: batched SwiGLU over [E, C, D]
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h * g, params["w_out"])

    # gather back and combine with routing weights
    gathered = out_buf[flat_expert, flat_pos]                 # [T*K, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = weights.reshape(-1)[:, None].astype(gathered.dtype)   # [T*K, 1]
    y = jnp.zeros((t, d), gathered.dtype).at[token_idx].add(gathered * w)

    if cfg.moe_dense_residual:
        from repro.models.layers import ffn
        y = y + ffn(params["dense_residual"], xt, cfg.act)
    return y.reshape(b, s, d), aux


def moe_ffn_dense_fallback(params, x, cfg: ModelConfig):
    """Oracle: evaluate every expert on every token (tests only)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, _ = router_probs(params, xt)
    weights, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    h = jnp.einsum("td,edf->etf", xt, params["w_in"])
    g = jax.nn.silu(jnp.einsum("td,edf->etf", xt, params["w_gate"]))
    per_expert = jnp.einsum("etf,efd->etd", h * g, params["w_out"])  # [E,T,D]
    mask = jnp.zeros((b * s, cfg.num_experts), per_expert.dtype)
    mask = mask.at[jnp.arange(b * s)[:, None], expert_idx].set(
        weights.astype(per_expert.dtype))
    y = jnp.einsum("etd,te->td", per_expert, mask)
    if cfg.moe_dense_residual:
        from repro.models.layers import ffn
        y = y + ffn(params["dense_residual"], xt, cfg.act)
    return y.reshape(b, s, d)

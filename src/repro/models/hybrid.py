"""RecurrentGemma / Griffin blocks: RG-LRU recurrent block + local attention.

RG-LRU (Real-Gated Linear Recurrent Unit, arXiv:2402.19427):

    r_t = sigmoid(W_a x_t)                 (recurrence gate)
    i_t = sigmoid(W_x x_t)                 (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal recurrence is associative, so training/prefill uses
``jax.lax.associative_scan`` over time (log-depth on TPU); decode carries the
state. The Pallas kernel in ``repro.kernels.rglru_scan`` implements the fused
time-blocked version; this module is the XLA path used by the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import gqa_decode_attention, gqa_self_attention, init_gqa
from repro.models.layers import (causal_conv1d, dense_init, ffn, init_conv1d,
                                 init_ffn, init_rmsnorm, rmsnorm)

RGLRU_C = 8.0


def init_rglru(rng, width: int, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "w_a": dense_init(ks[0], width, width, dtype),
        "w_x": dense_init(ks[1], width, width, dtype),
        # Lambda parameterized so that a ~ U(0.9, 0.999) at init (paper)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jax.random.uniform(ks[2], (width,), jnp.float32,
                                        0.9, 0.999)) / RGLRU_C)),
    }


def rglru_gates(params, x):
    r = jax.nn.sigmoid((x @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_x"]).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r        # [B,S,W]
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x.astype(jnp.float32)
    return a, gated_x


def rglru_scan(params, x, state=None):
    """x: [B,S,W] -> (h [B,S,W], last_state [B,W]) via associative scan.

    On TPU with no carried state the fused Pallas kernel
    (repro.kernels.rglru_scan) takes this path instead."""
    if state is None:
        from repro.kernels.ops import use_pallas
        if use_pallas():
            from repro.kernels.ops import rglru_scan as pallas_rglru
            return pallas_rglru(x, params["w_a"], params["w_x"],
                                params["lam"])
    a, gx = rglru_gates(params, x)
    if state is not None:
        # fold carried state into the first step: h_0' uses a_0 * state
        gx = gx.at[:, 0].add(a[:, 0] * state.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(params, x_t, state):
    """Single-token decode. x_t: [B,W], state: [B,W]."""
    a, gx = rglru_gates(params, x_t[:, None, :])
    h = a[:, 0] * state + gx[:, 0]
    return h.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# recurrent block (Griffin): gated RG-LRU branch + GeLU gate branch
# ---------------------------------------------------------------------------

def init_recurrent_block(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    return {
        "norm": init_rmsnorm(d),
        "w_rec": dense_init(ks[0], d, d, dtype),
        "w_gate": dense_init(ks[1], d, d, dtype),
        "conv": init_conv1d(ks[2], d, cfg.rglru_conv_width, dtype),
        "rglru": init_rglru(ks[3], d, dtype),
        "w_out": dense_init(ks[4], d, d, dtype),
        "mlp_norm": init_rmsnorm(d),
        "mlp": init_ffn(ks[5], d, cfg.d_ff, cfg.gated_ffn, dtype),
    }


def recurrent_block(params, x, cfg: ModelConfig, state=None):
    """state: (conv_state, rglru_state) or None. x: [B,S,D]."""
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    u = xn @ params["w_rec"]
    g = jax.nn.gelu(xn @ params["w_gate"])
    conv_state = None if state is None else state[0]
    u, conv_state = causal_conv1d(params["conv"], u, conv_state)
    if x.shape[1] == 1 and state is not None:
        h, rg_state = rglru_step(params["rglru"], u[:, 0], state[1])
        h = h[:, None, :]
    else:
        h, rg_state = rglru_scan(params["rglru"], u,
                                 None if state is None else state[1])
    y = (h * g) @ params["w_out"]
    x = x + y
    xm = rmsnorm(params["mlp_norm"], x, cfg.norm_eps)
    return x + ffn(params["mlp"], xm, cfg.act), (conv_state, rg_state)


def recurrent_state_init(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return (jnp.zeros((batch, cfg.rglru_conv_width - 1, d), dtype),
            jnp.zeros((batch, d), jnp.float32))


# ---------------------------------------------------------------------------
# local attention block (sliding window)
# ---------------------------------------------------------------------------

def init_local_attn_block(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "norm": init_rmsnorm(cfg.d_model),
        "attn": init_gqa(ks[0], cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dtype),
    }


def local_attn_block(params, x, cfg: ModelConfig, cache=None, positions=None):
    xn = rmsnorm(params["norm"], x, cfg.norm_eps)
    if cache is not None and x.shape[1] == 1:
        y, cache = gqa_decode_attention(params["attn"], xn, cfg, cache,
                                        window=cfg.local_attn_window)
    else:
        y = gqa_self_attention(params["attn"], xn, cfg,
                               window=cfg.local_attn_window,
                               positions=positions)
    x = x + y
    xm = rmsnorm(params["mlp_norm"], x, cfg.norm_eps)
    return x + ffn(params["mlp"], xm, cfg.act), cache

"""Context-parallel flash-decode: explicit shard_map decode attention for
long contexts (the TPU analogue of GPU "flash-decoding").

For ``long_500k`` (batch 1, 512k context) the KV cache is sharded along the
*sequence* dim over the mesh (data [+ model]) axes. Rather than letting XLA
infer a combine for the sharded contraction, this module computes per-shard
partial attention with online-softmax statistics and merges them with one
explicit ``psum``-based reduction:

    per shard:  m_i = max score, l_i = Σ exp(score − m_i), o_i = P_i · V_i
    combine:    M = max_i m_i;  L = Σ_i l_i·e^{m_i−M};
                o = Σ_i o_i·l_i·e^{m_i−M} / L

The combine moves only (o, m, l) — [B, H, d]+2·[B, H] per shard — instead of
any KV bytes: collective traffic is independent of context length.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _partial_attention(q, k, v, kv_pos, pos):
    """One shard's partial attention.

    q: [B, H, d]; k, v: [B, T_shard, KV, d]; kv_pos: [T_shard] global
    positions; pos: scalar current position. Returns (o [B,H,d] unnormalized,
    m [B,H], l [B,H]).
    """
    b, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = d ** -0.5
    qg = (q * scale).reshape(b, kv, g, d)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    valid = (kv_pos <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                             # [B,KV,G]
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v).astype(jnp.float32)
    return o.reshape(b, h, d), m.reshape(b, h), l.reshape(b, h)


def _merge(o, m, l, axes: Tuple[str, ...]):
    """Combine per-shard (o, m, l) into the exact softmax output."""
    M = m
    for a in axes:
        M = jax.lax.pmax(M, a)
    corr = jnp.exp(m - M)                                    # [B,H]
    o_c = o * corr[..., None]
    l_c = l * corr
    for a in axes:
        o_c = jax.lax.psum(o_c, a)
        l_c = jax.lax.psum(l_c, a)
    return o_c / jnp.maximum(l_c, 1e-30)[..., None]


def make_flash_decode(mesh, seq_axes: Tuple[str, ...] = ("data", "model")):
    """Builds the context-parallel decode-attention fn for ``mesh``.

    Inputs (global shapes):
        q       [B, H, d]           replicated
        k, v    [B, T, KV, d]       T sharded over ``seq_axes``
        kv_pos  [T]                 global positions of cache slots
        pos     []                  current decode position
    Returns the attention output [B, H, d] (replicated).
    """
    seq_axes = tuple(a for a in seq_axes if a in mesh.shape)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(None, seq_axes), P(seq_axes), P()),
        out_specs=P(),
        check_rep=False)
    def flash_decode(q, kvs, kv_pos, pos):
        k, v = kvs
        o, m, l = _partial_attention(q, k, v, kv_pos, pos)
        return _merge(o, m, l, seq_axes).astype(q.dtype)

    def apply(q, k, v, kv_pos, pos):
        return flash_decode(q, (k, v), kv_pos, pos)

    return apply


def flash_decode_reference(q, k, v, kv_pos, pos):
    """Unsharded oracle (same math, single device)."""
    o, m, l = _partial_attention(q, k, v, kv_pos, pos)
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

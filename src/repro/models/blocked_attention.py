"""Blocked (flash-style) attention in pure JAX — the XLA-path memory fix.

The naive softmax attention materializes [S, T] f32 scores; at
train_4k / phi3 scale that is ~43 GiB per layer per chip, which can never fit
HBM. This module computes attention with an online-softmax scan over KV
blocks nested in a scan over Q blocks, so the live score tile is
[block_q, block_k]. The inner body is ``jax.checkpoint``-ed so autodiff
recomputes tiles instead of saving them.

This is also the pure-jnp oracle family for the Pallas flash kernel
(``repro/kernels/flash_attention``) — same tiling, same math.

Layout: q [B, KV, G, S, hd]; k, v [B, KV, T, hd] (GQA grouped heads).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, causal: bool, window: int):
    rel = q_pos[:, None] - k_pos[None, :]
    m = jnp.zeros(rel.shape, jnp.float32)
    if causal:
        m = jnp.where(rel >= 0, m, NEG_INF)
    if window > 0:
        m = jnp.where(rel < window, m, NEG_INF)
    return m


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset=0, block_q: int = 256, block_k: int = 1024,
                    extra_mask=None):
    """Online-softmax blocked attention.

    q: [B, KV, G, S, hd]; k, v: [B, KV, T, hd]. ``q_offset`` shifts query
    positions (prefill continuation). ``extra_mask``: additive [T] mask.
    Returns [B, KV, G, S, hd].
    """
    b, kv, g, s, hd = q.shape
    hd_v = v.shape[-1]              # MLA: qk head dim != v head dim
    t = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    if s % block_q or t % block_k:
        return _plain_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, extra_mask=extra_mask)
    nq, nk = s // block_q, t // block_k
    scale = hd ** -0.5
    qf = (q * scale).reshape(b, kv, g, nq, block_q, hd)
    qf = jnp.moveaxis(qf, 3, 0)                       # [nq, B,KV,G,bq,hd]
    kb = jnp.moveaxis(k.reshape(b, kv, nk, block_k, hd), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, kv, nk, block_k, hd_v), 2, 0)

    def q_block(iq, q_blk):
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)

        @jax.checkpoint
        def kv_block(carry, xs):
            ik, k_blk, v_blk = xs
            o, m, l = carry
            k_pos = ik * block_k + jnp.arange(block_k)
            sblk = jnp.einsum("bkgqd,bktd->bkgqt", q_blk, k_blk,
                              preferred_element_type=jnp.float32)
            sblk = sblk + _block_mask(q_pos, k_pos, causal, window)
            if extra_mask is not None:
                em = jax.lax.dynamic_slice(extra_mask, (ik * block_k,),
                                           (block_k,))
                sblk = sblk + em
            m_new = jnp.maximum(m, jnp.max(sblk, axis=-1))
            p = jnp.exp(sblk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            o = o * corr[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (o, m_new, l), None

        o0 = jnp.zeros((b, kv, g, block_q, hd_v), jnp.float32)
        m0 = jnp.full((b, kv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, block_q), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_block, (o0, m0, l0), (jnp.arange(nk), kb, vb))
        # cast per block: the stacked full-sequence output stays in v.dtype
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(v.dtype)

    out = jax.lax.map(lambda xs: q_block(*xs), (jnp.arange(nq), qf))
    out = jnp.moveaxis(out, 0, 3).reshape(b, kv, g, s, hd_v)
    return out.astype(v.dtype)


def _plain_attention(q, k, v, *, causal, window, q_offset=0, extra_mask=None):
    """Unblocked fallback for tiny/ragged shapes."""
    scale = q.shape[-1] ** -0.5
    s, t = q.shape[3], k.shape[2]
    scores = jnp.einsum("bkgsd,bktd->bkgst", q * scale, k,
                        preferred_element_type=jnp.float32)
    q_pos = q_offset + jnp.arange(s)
    k_pos = jnp.arange(t)
    scores = scores + _block_mask(q_pos, k_pos, causal, window)
    if extra_mask is not None:
        scores = scores + extra_mask
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,bktd->bkgsd", p.astype(v.dtype), v)

"""Local fusion module ω^k (Eq. 5) — strictly client-local, never uploaded.

The fusion module consumes the per-modality predictions Ŷ^k = {ŷ_m} (one-hot
categories by default, §4.2) concatenated with a presence mask, and emits the
final class logits. The paper uses a 10-tree Random Forest to make TreeSHAP
cheap; decision forests are neither differentiable nor TPU-idiomatic, so we
use a small 2-layer MLP and compute *exact interventional Shapley values* by
enumerating modality subsets (see ``repro.core.shapley`` and DESIGN.md §3).

Masking convention (interventional feature perturbation): when modality m is
excluded from a coalition, its slot is replaced by a background value (a
sample from the client's background dataset), NOT zeroed — this is the
"interventional" expectation that TreeSHAP-with-background computes.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

FUSION_HIDDEN = 64


def _glorot(rng, shape):
    scale = jnp.sqrt(2.0 / (shape[-2] + shape[-1]))
    return scale * jax.random.normal(rng, shape, jnp.float32)


def init_fusion(rng, num_modalities: int, num_classes: int,
                hidden: int = FUSION_HIDDEN) -> Dict:
    """Fusion MLP over flattened [M, C] prediction block + [M] presence mask."""
    in_dim = num_modalities * num_classes + num_modalities
    ks = jax.random.split(rng, 2)
    return {
        "w1": _glorot(ks[0], (in_dim, hidden)),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": _glorot(ks[1], (hidden, num_classes)),
        "b2": jnp.zeros((num_classes,), jnp.float32),
    }


def fusion_forward(params, preds, mask):
    """preds: [B, M, C] per-modality predictions; mask: [M] or [B, M] float
    presence (1 = modality available). Returns logits [B, C]."""
    b, m, c = preds.shape
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None], (b, m))
    x = jnp.concatenate([(preds * mask[..., None]).reshape(b, m * c),
                         mask.astype(jnp.float32)], axis=-1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def fusion_loss(params, preds, mask, y):
    logits = fusion_forward(params, preds, mask)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def masked_fusion_loss(params, preds, mask, y, w):
    """Mask-weighted fusion CE: Σ w·ce / max(Σ w, 1) over a padded batch.

    Equals :func:`fusion_loss` on the real rows; padded rows (w = 0) carry
    neither loss nor gradient, so fully-padded steps are no-op updates."""
    logits = fusion_forward(params, preds, mask)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ce = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.sum(w * ce) / jnp.maximum(jnp.sum(w), 1.0)


@functools.partial(jax.jit, static_argnames=("lr",))
def fusion_sgd_step(params, preds, mask, y, lr: float = 0.1):
    loss, grads = jax.value_and_grad(fusion_loss)(params, preds, mask, y)
    return jax.tree.map(lambda p, g: p - lr * g, params, grads), loss


@jax.jit
def fusion_eval(params, preds, mask, y):
    logits = fusion_forward(params, preds, mask)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


def masked_fusion_eval(params, preds, mask, y, w):
    """Mask-weighted (loss, accuracy) over a padded sample axis — the
    batched-population counterpart of :func:`fusion_eval`."""
    logits = fusion_forward(params, preds, mask)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ce = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    denom = jnp.maximum(jnp.sum(w), 1.0)
    loss = jnp.sum(w * ce) / denom
    hit = (jnp.argmax(logits, -1) == y).astype(jnp.float32)
    return loss, jnp.sum(w * hit) / denom


def fusion_value(params, preds, mask, y):
    """Coalition value v(S) used by Shapley: mean predicted probability of
    the true class under presence-mask S (interventional masking happens in
    the caller by substituting background predictions)."""
    p = jax.nn.softmax(fusion_forward(params, preds, mask).astype(jnp.float32))
    return jnp.mean(jnp.take_along_axis(p, y[:, None], axis=1))

"""SOTA MFL baselines (§4.2): FL-FD, MMFed, FedMultimodal, FLASH, Harmony.

Per the paper's protocol, all baselines share the base networks (LSTM
trunks / CNN trunks, same hyperparameters) and differ only in the fusion
level and upload policy — specialized add-ons (co-attention etc.) are
deliberately omitted to isolate the algorithmic comparison:

- **FL-FD**        data-level fusion: modalities resampled to a common time
                   axis and concatenated on features; one holistic model;
                   full-model upload every round.
- **MMFed**        feature-level fusion: per-modality trunk → concat hidden
                   states → shared head; full-model upload.
- **FedMultimodal** feature-level fusion with mean-pooled trunk features;
                   full-model upload.
- **FLASH**        MMFed architecture, but each client uploads ONE uniformly
                   random component (a modality trunk or the head) per round.
- **Harmony**      disentangled two-stage: per-modality trunks are federated
                   (all uploaded), the fusion head stays local.

Missing modalities are zero-padded — exactly the degradation mode the
decoupled MFedMC architecture avoids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import CommLedger
from repro.core.encoders import LSTM_HIDDEN, _glorot
from repro.core.rounds import MFedMCConfig, RoundRecord, RunHistory
from repro.core.timing import resolve_trace
from repro.data.registry import DatasetSpec, get_dataset_spec
from repro.data.synthetic import ClientData


# ---------------------------------------------------------------------------
# shared trunks
# ---------------------------------------------------------------------------

def _init_lstm_trunk(rng, feat: int, hidden: int = LSTM_HIDDEN):
    ks = jax.random.split(rng, 2)
    return {"w_x": _glorot(ks[0], (feat, 4 * hidden)),
            "w_h": _glorot(ks[1], (hidden, 4 * hidden)),
            "b": jnp.zeros((4 * hidden,), jnp.float32)
                 .at[hidden:2 * hidden].set(1.0)}


def _lstm_trunk(params, x):
    b, t, f = x.shape
    hidden = params["w_h"].shape[0]

    def cell(carry, x_t):
        h, c = carry
        z = x_t @ params["w_x"] + h @ params["w_h"] + params["b"]
        i, fg, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(fg) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((b, hidden), x.dtype)
    (h, _), _ = jax.lax.scan(cell, (h0, h0), jnp.moveaxis(x, 1, 0))
    return h


def _init_cnn_trunk(rng, in_shape, channels: int = 32):
    h, w, c = in_shape
    ph, pw = (h - 4) // 2, (w - 4) // 2
    return {"conv_w": 0.1 * jax.random.normal(rng, (5, 5, c, channels)),
            "conv_b": jnp.zeros((channels,), jnp.float32),
            "_out": jnp.zeros((ph * pw * channels,), jnp.float32)}  # dim tag


def _cnn_trunk(params, x):
    y = jax.lax.conv_general_dilated(
        x, params["conv_w"], (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["conv_b"]
    y = jax.nn.relu(y)
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return y.reshape(y.shape[0], -1)


def _resample_time(x: np.ndarray, t_common: int) -> np.ndarray:
    """Nearest-index resample of [N, T, F] to [N, t_common, F]."""
    t = x.shape[1]
    idx = np.linspace(0, t - 1, t_common).round().astype(int)
    return x[:, idx, :]


# ---------------------------------------------------------------------------
# holistic model (data-level / feature-level)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BaselineArch:
    name: str            # flfd | mmfed | fedmultimodal | flash | harmony
    fusion_level: str    # data | feature
    upload: str          # full | random_component | trunks_only


BASELINES: Dict[str, BaselineArch] = {
    "flfd": BaselineArch("flfd", "data", "full"),
    "mmfed": BaselineArch("mmfed", "feature", "full"),
    "fedmultimodal": BaselineArch("fedmultimodal", "feature_mean", "full"),
    "flash": BaselineArch("flash", "feature", "random_component"),
    "harmony": BaselineArch("harmony", "feature", "trunks_only"),
}


def init_holistic(rng, spec: DatasetSpec, arch: BaselineArch,
                  reduced: bool = True) -> Dict:
    c = spec.num_classes
    image = spec.modalities[0].kind == "image"
    ks = jax.random.split(rng, len(spec.modalities) + 1)
    if arch.fusion_level == "data":
        if image:
            ch = sum(m.shape[-1] for m in spec.modalities)
            h, w, _ = spec.modalities[0].shape
            trunk = _init_cnn_trunk(ks[0], (h, w, ch))
            feat_dim = trunk["_out"].shape[0]
        else:
            f_total = sum(m.feature_shape(reduced)[-1]
                          for m in spec.modalities)
            trunk = _init_lstm_trunk(ks[0], f_total)
            feat_dim = LSTM_HIDDEN
        return {"trunk": trunk,
                "head": {"w": _glorot(ks[-1], (feat_dim, c)),
                         "b": jnp.zeros((c,), jnp.float32)}}
    # feature-level
    trunks, dims = {}, 0
    for i, m in enumerate(spec.modalities):
        if m.kind == "image":
            trunks[m.name] = _init_cnn_trunk(ks[i], m.shape)
            dims += trunks[m.name]["_out"].shape[0]
        else:
            trunks[m.name] = _init_lstm_trunk(
                ks[i], m.feature_shape(reduced)[-1])
            dims += LSTM_HIDDEN
    if arch.name == "fedmultimodal":
        dims = (trunks[spec.modalities[0].name]["_out"].shape[0]
                if image else LSTM_HIDDEN)      # mean-pool over modalities
    return {"trunks": trunks,
            "head": {"w": _glorot(ks[-1], (dims, c)),
                     "b": jnp.zeros((c,), jnp.float32)}}


def holistic_forward(params, batch: Dict[str, jnp.ndarray],
                     modality_names: Tuple[str, ...], fusion_level: str):
    if fusion_level == "data":
        x = batch["__concat__"]
        feats = (_cnn_trunk(params["trunk"], x)
                 if "conv_w" in params["trunk"]
                 else _lstm_trunk(params["trunk"], x))
    else:
        cols = []
        for m in modality_names:
            x = batch[m]
            tr = params["trunks"][m]
            cols.append(_cnn_trunk(tr, x) if "conv_w" in tr
                        else _lstm_trunk(tr, x))
        feats = (sum(cols) / len(cols)) if fusion_level == "feature_mean" \
            else jnp.concatenate(cols, axis=-1)
    return feats @ params["head"]["w"] + params["head"]["b"]


def _prep_batch(data: ClientData, spec: DatasetSpec, idx: np.ndarray,
                fusion_level: str, reduced: bool = True):
    """Zero-pads missing modalities; data-level concatenation on features."""
    out: Dict[str, jnp.ndarray] = {}
    n = len(idx)
    if fusion_level == "data":
        image = spec.modalities[0].kind == "image"
        if image:
            parts = []
            for m in spec.modalities:
                x = data.modalities.get(m.name)
                parts.append(x[idx] if x is not None
                             else np.zeros((n,) + m.shape, np.float32))
            out["__concat__"] = jnp.asarray(np.concatenate(parts, axis=-1))
        else:
            t_common = max(m.feature_shape(reduced)[0]
                           for m in spec.modalities)
            parts = []
            for m in spec.modalities:
                shape = m.feature_shape(reduced)
                x = data.modalities.get(m.name)
                arr = x[idx] if x is not None \
                    else np.zeros((n,) + shape, np.float32)
                parts.append(_resample_time(arr, t_common))
            out["__concat__"] = jnp.asarray(np.concatenate(parts, axis=-1))
        return out
    for m in spec.modalities:
        shape = m.shape if m.kind == "image" else m.feature_shape(reduced)
        x = data.modalities.get(m.name)
        out[m.name] = jnp.asarray(
            x[idx] if x is not None else np.zeros((n,) + shape, np.float32))
    return out


def _holistic_loss(params, batch, y, modality_names, fusion_level):
    logits = holistic_forward(params, batch, modality_names, fusion_level)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * 4 for x in jax.tree.leaves(tree))


def run_baseline(name: str, dataset: str, scenario: str = "natural",
                 cfg: Optional[MFedMCConfig] = None, *,
                 verbose: bool = False, reduced: bool = True,
                 client_datasets: Optional[List[ClientData]] = None,
                 allowed_full_upload: Optional[Sequence[int]] = None,
                 **partition_kw) -> RunHistory:
    """Run a SOTA baseline under the same protocol/ledger as MFedMC.

    ``allowed_full_upload`` (Fig. 8): for end-to-end baselines (flfd/mmfed/
    fedmultimodal) only these client ids can upload; FLASH/Harmony clients
    upload components subject to the same cap implicitly (they always can).
    """
    arch = BASELINES[name]
    cfg = cfg or MFedMCConfig()
    spec = get_dataset_spec(dataset)
    if client_datasets is None:
        from repro.data.partition import make_federation
        client_datasets = make_federation(dataset, scenario, seed=cfg.seed,
                                          reduced=reduced, **partition_kw)
    client_datasets = [d for d in client_datasets if d.num_samples > 1]
    splits = [d.split(0.8, seed=cfg.seed) for d in client_datasets]
    rng = np.random.default_rng(cfg.seed)
    rngs = jax.random.split(jax.random.key(cfg.seed), 1)[0]

    global_params = init_holistic(rngs, spec, arch, reduced)
    local_params = [jax.tree.map(jnp.asarray, global_params)
                    for _ in client_datasets]
    loss_grad = jax.jit(jax.value_and_grad(_holistic_loss),
                        static_argnames=("modality_names", "fusion_level"))

    ledger = CommLedger()
    history = RunHistory()
    image = spec.modalities[0].kind == "image"
    lr = 0.01 if image else cfg.lr_encoder

    component_names = (["head"] + [f"trunks/{m}" for m in spec.modality_names]
                       if arch.fusion_level == "feature" else ["head", "trunk"])

    trace = resolve_trace(cfg)
    for t in range(1, cfg.rounds + 1):
        # §4.9 availability through the same trace abstraction as MFedMC
        # (Bernoulli rate, Markov churn, ...). When nobody reports, the
        # round is an explicit empty-upload round — no silently forced
        # client 0 — matching run_federation's semantics: no training, no
        # uploads, evaluate the current models.
        avail_mask = trace.step(rng, len(client_datasets))
        active = [i for i in range(len(client_datasets)) if avail_mask[i]]
        # ---- local training ----
        for i in active:
            train, _ = splits[i]
            p = local_params[i]
            n = train.num_samples
            for _ in range(cfg.local_epochs):
                order = rng.permutation(n)
                for s in range(0, n, cfg.batch_size):
                    idx = order[s:s + cfg.batch_size]
                    if len(idx) == 0:
                        continue
                    batch = _prep_batch(train, spec, idx, arch.fusion_level,
                                        reduced)
                    y = jnp.asarray(train.labels[idx])
                    _, grads = loss_grad(
                        p, batch, y, modality_names=spec.modality_names,
                        fusion_level=arch.fusion_level)
                    p = jax.tree.map(lambda a, g: a - lr * g, p, grads)
            local_params[i] = p

        # ---- uploads ----
        weights, contribs = [], []
        if arch.upload == "random_component":            # FLASH
            # per-component accumulation
            comp_acc: Dict[str, List[Tuple]] = {}
            for i in active:
                comp = component_names[rng.integers(len(component_names))]
                sub = _get_component(local_params[i], comp)
                comp_acc.setdefault(comp, []).append(
                    (sub, splits[i][0].num_samples))
                ledger.record(_tree_bytes(sub))
            for comp, items in comp_acc.items():
                w = np.array([n for _, n in items], np.float64)
                w /= w.sum()
                avg = jax.tree.map(
                    lambda *xs: sum(wi * x for wi, x in zip(w, xs)),
                    *[s for s, _ in items])
                _set_component(global_params, comp, avg)
        else:
            upl = active
            if allowed_full_upload is not None and arch.upload == "full":
                upl = [i for i in active
                       if client_datasets[i].client_id in allowed_full_upload]
            for i in upl:
                if arch.upload == "trunks_only":          # Harmony
                    sub = {"trunks": local_params[i]["trunks"]}
                else:
                    sub = {k: v for k, v in local_params[i].items()
                           if k in ("trunk", "trunks", "head")}
                contribs.append(sub)
                weights.append(splits[i][0].num_samples)
                ledger.record(_tree_bytes(sub))
            if contribs:
                w = np.array(weights, np.float64)
                w /= w.sum()
                avg = jax.tree.map(
                    lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *contribs)
                for k, v in avg.items():
                    global_params[k] = v

        # ---- broadcast ----
        for i in active:
            for k in ("trunk", "trunks", "head"):
                if k in global_params and \
                        not (arch.upload == "trunks_only" and k == "head"):
                    local_params[i][k] = global_params[k]

        # ---- evaluate ----
        tot, acc_sum, loss_sum = 0, 0.0, 0.0
        for i, (train, test) in enumerate(splits):
            batch = _prep_batch(test, spec, np.arange(test.num_samples),
                                arch.fusion_level, reduced)
            y = jnp.asarray(test.labels)
            logits = holistic_forward(local_params[i], batch,
                                      spec.modality_names,
                                      arch.fusion_level)
            acc = float(jnp.mean((jnp.argmax(logits, -1) == y)))
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            loss = float(-jnp.mean(jnp.take_along_axis(logp, y[:, None], 1)))
            n = test.num_samples
            tot += n
            acc_sum += acc * n
            loss_sum += loss * n
        acc, loss = acc_sum / tot, loss_sum / tot
        history.records.append(RoundRecord(t, acc, loss, ledger.megabytes,
                                           [], {}))
        if verbose:
            print(f"[{name} round {t:3d}] acc={acc:.4f} "
                  f"comm={ledger.megabytes:.2f}MB")
        if cfg.comm_budget_mb is not None and \
                ledger.megabytes >= cfg.comm_budget_mb:
            break
    return history


def _get_component(params, comp: str):
    if "/" in comp:
        a, b = comp.split("/")
        return params[a][b]
    return params[comp]


def _set_component(params, comp: str, value):
    if "/" in comp:
        a, b = comp.split("/")
        params[a][b] = value
    else:
        params[comp] = value

"""Uplink quantization (§4.10): uniform affine per-tensor quantization of
encoder parameters to 4 or 8 bits, applied on upload and dequantized at the
server before aggregation. Composes with modality/client selection — the
ledger then counts ``bits/8`` bytes per parameter.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.encoders import encoder_param_arrays


def quantize_tensor(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, float, float]:
    """Symmetric-range affine quantization. Returns (codes, scale, zero)."""
    levels = 2 ** bits - 1
    lo = jnp.min(x)
    hi = jnp.max(x)
    scale = jnp.maximum((hi - lo) / levels, 1e-12)
    codes = jnp.clip(jnp.round((x - lo) / scale), 0, levels)
    return codes.astype(jnp.uint8 if bits <= 8 else jnp.int32), \
        float(scale), float(lo)


def dequantize_tensor(codes: jnp.ndarray, scale: float, zero: float):
    return codes.astype(jnp.float32) * scale + zero


def quantize_encoder(params: Dict, bits: int) -> Dict:
    """Quantize every numeric leaf."""
    out: Dict = {"bits": bits}
    for k, v in encoder_param_arrays(params).items():
        codes, scale, zero = quantize_tensor(v, bits)
        out[k] = {"codes": codes, "scale": scale, "zero": zero}
    return out


def dequantize_encoder(q: Dict) -> Dict:
    return {k: dequantize_tensor(v["codes"], v["scale"], v["zero"])
            for k, v in q.items() if k != "bits"}


def quantized_roundtrip(params: Dict, bits: int) -> Dict:
    """What the server receives after a ``bits``-bit uplink."""
    if bits >= 32:
        return params
    return dequantize_encoder(quantize_encoder(params, bits))

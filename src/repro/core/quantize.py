"""Uplink quantization (§4.10) as a device-resident communication subsystem.

Every §4.10 upload is uniform *asymmetric min-max affine* per-tensor
quantization: codes in [0, 2^bits − 1] plus one (scale, zero) float32 pair
per tensor. The same code path serves all three execution tiers:

- :func:`quantize_pytree` / :func:`dequantize_pytree` are pure traceable
  pytree transforms — jit them, ``vmap`` them over the stacked ``[K, ...]``
  population layout of ``repro.core.batched``, or call them per client.
  Scale/zero stay on device (0-d arrays): quantizing a whole population is
  one XLA program with no per-leaf host syncs.
- :func:`quantize_population` / :func:`quantized_roundtrip_population` are
  the jit'd vmapped forms used by ``run_federation``'s upload path and the
  benchmarks.
- :func:`quantize_with_error_feedback` adds client-held residual
  accumulators (EF14/EF21-style): the client quantizes ``params + residual``
  and keeps the quantization error for the next round, so low-bit uplinks
  average out their rounding error across rounds instead of accumulating it.
- Wire accounting is *exact*: codes ship in the smallest sufficient
  unsigned dtype (uint8 for ≤8 bits, uint16 for ≤16), sub-byte codes
  count as bit-packed (:func:`pack_codes` / :func:`unpack_codes` realize
  that format — 8//bits codes per byte — and pin its size in tests; the
  in-process simulator skips the physical pack since both endpoints share
  memory), and every tensor's (scale, zero) metadata is counted.
  :func:`tensor_wire_bytes` / :func:`pytree_wire_bytes` are the single
  source of truth the comm ledger
  (``repro.core.encoders.encoder_bytes``) delegates to.

``bits >= 32`` means "no quantization" and only the passthrough entry
points (:func:`quantized_roundtrip`, the accounting helpers) accept it;
the quantizers themselves require ``1 <= bits <= 16`` — float32 rounding is
exact there, whereas 17–31-bit codes would overflow the float32 mantissa.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SCALE_BYTES = 4     # per-tensor float32 scale shipped with the codes
ZERO_BYTES = 4      # per-tensor float32 zero-point (range minimum)
TENSOR_METADATA_BYTES = SCALE_BYTES + ZERO_BYTES


def _check_bits(bits: int) -> None:
    if not 1 <= int(bits) <= 16:
        raise ValueError(
            f"quantization requires 1 <= bits <= 16 (got {bits}); "
            "bits >= 32 means full precision — use quantized_roundtrip "
            "or the accounting helpers, which pass it through")


def code_dtype(bits: int):
    """Smallest unsigned dtype that holds 2^bits − 1 codes on the wire."""
    _check_bits(bits)
    return jnp.uint8 if bits <= 8 else jnp.uint16


# ---------------------------------------------------------------------------
# per-tensor transform (traceable; scale/zero are 0-d device arrays)
# ---------------------------------------------------------------------------

def quantize_tensor(x: jnp.ndarray, bits: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Asymmetric min-max affine quantization. Returns (codes, scale, zero):
    ``x ≈ codes · scale + zero`` with codes in [0, 2^bits − 1] and
    ``zero = min(x)``. Scale/zero are 0-d float32 device arrays — no host
    sync — so the transform jits and vmaps over stacked populations."""
    levels = 2 ** int(bits) - 1
    xf = jnp.asarray(x).astype(jnp.float32)
    lo = jnp.min(xf)
    hi = jnp.max(xf)
    scale = jnp.maximum((hi - lo) / levels, 1e-12)
    codes = jnp.clip(jnp.round((xf - lo) / scale), 0, levels)
    return codes.astype(code_dtype(bits)), scale, lo


def dequantize_tensor(codes: jnp.ndarray, scale, zero,
                      dtype=None) -> jnp.ndarray:
    """Inverse transform; restores ``dtype`` (default float32) so quantized
    aggregation composes with non-f32 encoders."""
    out = codes.astype(jnp.float32) * scale + zero
    return out if dtype is None else out.astype(dtype)


# ---------------------------------------------------------------------------
# sub-byte packing (what actually ships for bits < 8)
# ---------------------------------------------------------------------------

def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack ``bits``-bit codes into a flat uint8/uint16 wire buffer.

    For bits ∈ {1, 2, 4} (divisors of 8), 8//bits codes share each byte —
    the buffer's ``nbytes`` is exactly ``ceil(n·bits/8)``. 8- and ≤16-bit
    codes already occupy their smallest dtype and pass through flattened."""
    _check_bits(bits)
    dt = code_dtype(bits)
    flat = codes.reshape(-1).astype(dt)
    per = 8 // bits if 8 % bits == 0 else 1
    if per <= 1:
        return flat
    pad = (-flat.shape[0]) % per
    flat = jnp.concatenate([flat, jnp.zeros((pad,), dt)])
    lanes = flat.reshape(-1, per).astype(jnp.uint32)
    shifts = jnp.arange(per, dtype=jnp.uint32) * bits
    return jnp.sum(lanes << shifts[None, :], axis=1).astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray, bits: int, n: int,
                 shape: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`: recover ``n`` codes shaped ``shape``."""
    _check_bits(bits)
    per = 8 // bits if 8 % bits == 0 else 1
    if per <= 1:
        return packed.reshape(shape)
    shifts = jnp.arange(per, dtype=jnp.uint32) * bits
    mask = jnp.uint32(2 ** bits - 1)
    lanes = (packed.astype(jnp.uint32)[:, None] >> shifts[None, :]) & mask
    return lanes.reshape(-1)[:n].astype(code_dtype(bits)).reshape(shape)


# ---------------------------------------------------------------------------
# exact wire accounting (the ledger's single source of truth)
# ---------------------------------------------------------------------------

def tensor_wire_bytes(shape, bits: int, dtype=np.float32) -> int:
    """Exact uplink bytes for one tensor at the given precision.

    - ``bits >= 32``: raw parameters, ``n × itemsize`` — no metadata.
    - otherwise: the bit-packed code buffer (``ceil(n·bits/8)`` when bits
      divides 8, else ``n × itemsize(code_dtype)``) **plus** the per-tensor
      float32 (scale, zero) pair. 16-bit codes therefore cost 2 bytes per
      parameter — not the 4 an int32 container would ship."""
    n = int(np.prod(shape, dtype=np.int64)) if len(tuple(shape)) else 1
    if bits >= 32:
        return n * np.dtype(dtype).itemsize
    _check_bits(bits)
    if 8 % bits == 0:
        code = -((n * bits) // -8)                      # packed, ceil
    else:
        code = n * np.dtype(code_dtype(bits)).itemsize  # unpacked container
    return code + TENSOR_METADATA_BYTES


def pytree_wire_bytes(params, bits: int) -> int:
    """Exact uplink bytes for a whole parameter pytree (Eq. 10's cost)."""
    return sum(tensor_wire_bytes(np.shape(leaf), bits,
                                 getattr(leaf, "dtype", np.float32))
               for leaf in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# pytree transforms (vmap-able over the stacked [K, ...] population)
# ---------------------------------------------------------------------------

def quantize_pytree(params, bits: int):
    """Quantize every leaf. Returns ``(codes, scales, zeros)`` — three
    pytrees with the input's structure; scales/zeros hold 0-d device
    scalars. Pure and traceable: ``jax.vmap`` over a stacked ``[K, ...]``
    tree yields per-client per-tensor ranges with ``[K]``-shaped scales."""
    _check_bits(bits)
    flat, treedef = jax.tree_util.tree_flatten(params)
    cs, ss, zs = [], [], []
    for leaf in flat:
        c, s, z = quantize_tensor(leaf, bits)
        cs.append(c)
        ss.append(s)
        zs.append(z)
    return (jax.tree_util.tree_unflatten(treedef, cs),
            jax.tree_util.tree_unflatten(treedef, ss),
            jax.tree_util.tree_unflatten(treedef, zs))


def dequantize_pytree(codes, scales, zeros, like=None):
    """Inverse of :func:`quantize_pytree`; ``like`` (a pytree of arrays or
    dtypes) restores each leaf's original dtype."""
    if like is None:
        return jax.tree.map(dequantize_tensor, codes, scales, zeros)
    return jax.tree.map(
        lambda c, s, z, ref: dequantize_tensor(
            c, s, z, getattr(ref, "dtype", ref)),
        codes, scales, zeros, like)


def fake_quantize_pytree(params, bits: int):
    """Quantize → dequantize in one traceable transform: what the server
    sees after a ``bits``-bit uplink, with the original dtypes restored.
    This is the §4.10 composition the mesh round applies to each client's
    payload before Eq. 21's masked all-reduce."""
    return dequantize_pytree(*quantize_pytree(params, bits), like=params)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_population(stacked, *, bits: int):
    """Vmapped :func:`quantize_pytree` over a stacked ``[K, ...]`` pytree:
    one jit'd program quantizes every client's upload with per-client
    per-tensor ranges (scales/zeros shaped ``[K]``)."""
    return jax.vmap(lambda t: quantize_pytree(t, bits))(stacked)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantized_roundtrip_population(stacked, *, bits: int):
    """Vmapped fake-quant of a stacked population — the device-resident
    replacement for K host-side ``quantized_roundtrip`` calls."""
    return jax.vmap(lambda t: fake_quantize_pytree(t, bits))(stacked)


# ---------------------------------------------------------------------------
# error feedback (client-held residual accumulators)
# ---------------------------------------------------------------------------

def _ef_step(params, residual, bits: int):
    compensated = jax.tree.map(
        lambda a, b: a.astype(jnp.float32) + b, params, residual)
    codes, scales, zeros = quantize_pytree(compensated, bits)
    sent = dequantize_pytree(codes, scales, zeros)
    new_r = jax.tree.map(lambda a, b: a - b, compensated, sent)
    return codes, scales, zeros, new_r


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_with_error_feedback(params, residual, *, bits: int):
    """Quantize ``params + residual`` for ONE client and return
    ``(codes, scales, zeros, new_residual)``.

    The residual is the quantization error the uplink could not carry this
    round; adding it back before the next quantization makes the *average*
    transmitted encoder unbiased, so low-bit (e.g. 4-bit) federations
    converge where plain quantization stalls."""
    return _ef_step(params, residual, bits)


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_population_with_error_feedback(stacked, residuals, *,
                                            bits: int):
    """Vmapped :func:`quantize_with_error_feedback` over stacked ``[K, ...]``
    params and residuals: per-client per-tensor ranges, one jit'd program."""
    return jax.vmap(lambda p, r: _ef_step(p, r, bits))(stacked, residuals)


def zero_residual(params):
    """A zeroed float32 residual accumulator shaped like ``params``."""
    return jax.tree.map(lambda x: jnp.zeros(jnp.shape(x), jnp.float32),
                        params)


# ---------------------------------------------------------------------------
# dict-payload API (kept for Tier-1 / external callers)
# ---------------------------------------------------------------------------

def quantize_encoder(params: Dict, bits: int) -> Dict:
    """Quantize every leaf of one encoder into a wire-payload dict:
    ``{name: {codes, scale, zero, dtype}, "bits": bits}``. Guarded: full
    precision (bits >= 32) is not a quantization — callers wanting the
    passthrough use :func:`quantized_roundtrip`."""
    _check_bits(bits)
    out: Dict = {"bits": int(bits)}
    for k, v in params.items():
        codes, scale, zero = quantize_tensor(v, bits)
        out[k] = {"codes": codes, "scale": scale, "zero": zero,
                  "dtype": jnp.asarray(v).dtype}
    return out


def dequantize_encoder(q: Dict) -> Dict:
    """Decode a :func:`quantize_encoder` payload, restoring each leaf's
    original dtype when the payload carries one."""
    return {k: dequantize_tensor(v["codes"], v["scale"], v["zero"],
                                 v.get("dtype"))
            for k, v in q.items() if k != "bits"}


def quantized_roundtrip(params: Dict, bits: int) -> Dict:
    """What the server receives after a ``bits``-bit uplink (identity at
    full precision)."""
    if bits >= 32:
        return params
    return dequantize_encoder(quantize_encoder(params, bits))

"""Shapley-value modality impact (Eq. 8), exact and sampled estimators.

The paper evaluates each modality's impact on the fusion module with Shapley
values computed by interventional feature perturbation over a subsampled
background dataset (|D'| = 50). The paper's Random-Forest fusion enables
TreeSHAP; our MLP fusion instead gets an **exact interventional Shapley**
by enumerating all 2^M modality coalitions (M ≤ 6 for every dataset here),
fully vectorized:

    v(S)  = E_{x ~ eval} E_{b ~ background} p_fusion(y_x | x_S, b_{\\bar S})
    φ_m   = Σ_{S ⊆ M\\{m}} |S|!(M−|S|−1)!/M! · (v(S ∪ {m}) − v(S))

Unavailable modalities are *dummy players* (their eval and background
predictions are identical zeros), so their marginal contribution — and hence
their Shapley value — is exactly 0, and the remaining values equal those of
the restricted game (dummy-consistency of the Shapley value).

A permutation-sampling estimator handles hypothetical M > 12 deployments.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import fusion_forward


@functools.lru_cache(maxsize=None)
def subset_masks(m: int) -> np.ndarray:
    """[2^m, m] boolean matrix; row i = binary expansion of i.

    Cached (and marked read-only): the enumeration re-traces per fusion
    bucket per round, and rebuilding the 2^m table each trace is waste."""
    idx = np.arange(2 ** m)
    out = ((idx[:, None] >> np.arange(m)) & 1).astype(bool)
    out.flags.writeable = False
    return out


@functools.lru_cache(maxsize=None)
def _shapley_weights(m: int) -> np.ndarray:
    """w[s] = s!(m−s−1)!/m! for coalition sizes s = 0..m−1 (cached)."""
    out = np.array([math.factorial(s) * math.factorial(m - s - 1)
                    / math.factorial(m) for s in range(m)])
    out.flags.writeable = False
    return out


@functools.partial(jax.jit, static_argnames=("num_modalities",))
def exact_shapley(fusion_params, preds, background, avail_mask, y,
                  *, num_modalities: int):
    """Exact interventional Shapley values per modality.

    preds:      [B, M, C]   eval predictions (zeros where unavailable)
    background: [G, M, C]   background-dataset predictions (zeros likewise)
    avail_mask: [M]         1.0 where the modality exists on this client
    y:          [B]         true labels
    Returns φ [M] (float32); Σφ = v(full) − v(∅) and φ_m = 0 for absent m.

    The unpadded case of :func:`_masked_exact_shapley` (unit sample masks),
    so the loop and batched backends share one Shapley implementation.
    """
    return _masked_exact_shapley(
        fusion_params, preds, background, avail_mask, y,
        jnp.ones((preds.shape[0],), jnp.float32),
        jnp.ones((background.shape[0],), jnp.float32),
        num_modalities=num_modalities)


def _masked_exact_shapley(fusion_params, preds, background, avail_mask, y,
                          eval_w, bg_w, *, num_modalities: int):
    """Single-client exact Shapley with weighted (padding-aware) means.

    ``eval_w`` [B] / ``bg_w`` [G] are 0/1 sample masks; v(S) becomes the
    mask-weighted mean of p(y|·), so clients whose eval/background subsets
    are padded up to a population-wide (B, G) compute the same values as the
    unpadded per-client enumeration."""
    m = num_modalities
    masks = jnp.asarray(subset_masks(m), jnp.float32)          # [2^m, M]
    b, _, c = preds.shape
    g = background.shape[0]
    wmat = eval_w[:, None] * bg_w[None, :]                     # [B, G]
    denom = jnp.maximum(jnp.sum(wmat), 1.0)

    def value(smask):
        mixed = (smask[None, None, :, None] * preds[:, None] +
                 (1 - smask)[None, None, :, None] * background[None])
        mixed = mixed.reshape(b * g, m, c)
        logits = fusion_forward(fusion_params, mixed,
                                jnp.broadcast_to(avail_mask[None], (b * g, m)))
        p = jax.nn.softmax(logits.astype(jnp.float32))
        p_true = jnp.take_along_axis(
            p.reshape(b, g, c), jnp.broadcast_to(y[:, None, None], (b, g, 1)),
            axis=2)[..., 0]
        return jnp.sum(wmat * p_true) / denom

    vals = jax.lax.map(value, masks)                           # [2^m]

    sizes = jnp.sum(masks, axis=1)                             # |S| incl. m
    w_table = jnp.asarray(_shapley_weights(m), jnp.float32)

    def phi(mi):
        has_m = masks[:, mi] > 0
        pair = jnp.arange(2 ** m) - (1 << mi)
        contrib = jnp.where(has_m,
                            w_table[jnp.clip(sizes - 1, 0, m - 1).astype(int)]
                            * (vals - vals[jnp.clip(pair, 0, None)]),
                            0.0)
        return jnp.sum(contrib)

    return jax.vmap(phi)(jnp.arange(m))


@functools.partial(jax.jit, static_argnames=("num_modalities",))
def exact_shapley_population(fusion_params, preds, background, avail_mask, y,
                             eval_w, bg_w, *, num_modalities: int):
    """Exact interventional Shapley for a stacked client population.

    One vmapped 2^M enumeration replaces the per-client Python loop:

    fusion_params: pytree with leading K axis (each client's local fusion)
    preds:      [K, B, M, C]  eval predictions, padded over B
    background: [K, G, M, C]  background predictions, padded over G
    avail_mask: [K, M]        per-(client, modality) presence
    y:          [K, B]        true labels (padded rows arbitrary)
    eval_w/bg_w:[K, B]/[K, G] 0/1 sample masks for the padded rows
    Returns φ [K, M]; rows reproduce :func:`exact_shapley` per client."""
    fn = functools.partial(_masked_exact_shapley,
                           num_modalities=num_modalities)
    return jax.vmap(fn)(fusion_params, preds, background, avail_mask, y,
                        eval_w, bg_w)


def sampled_shapley(fusion_params, preds, background, avail_mask, y,
                    *, num_modalities: int, num_permutations: int = 64,
                    rng: Optional[np.random.Generator] = None):
    """Permutation-sampling estimator for large M (unbiased, O(P·M) values).

    The coalition value is jit-compiled once per call (the eager op-by-op
    forward used to pay dispatch on every marginal), and v(∅) — identical
    for every permutation — is hoisted out of the permutation loop."""
    m = num_modalities
    rng = rng or np.random.default_rng(0)
    b, _, c = preds.shape
    g = background.shape[0]
    yj = jnp.asarray(y)

    @jax.jit
    def value(smask):
        mixed = (smask[None, None, :, None] * preds[:, None] +
                 (1 - smask)[None, None, :, None] * background[None])
        mixed = mixed.reshape(b * g, m, c)
        logits = fusion_forward(fusion_params, mixed,
                                jnp.broadcast_to(avail_mask[None], (b * g, m)))
        p = jax.nn.softmax(logits.astype(jnp.float32))
        p_true = jnp.take_along_axis(
            p.reshape(b, g, c), jnp.broadcast_to(yj[:, None, None], (b, g, 1)),
            axis=2)
        return jnp.mean(p_true)

    v_empty = float(value(jnp.zeros((m,), jnp.float32)))
    phi = np.zeros(m)
    for _ in range(num_permutations):
        perm = rng.permutation(m)
        smask = np.zeros(m, np.float32)
        v_prev = v_empty
        for mi in perm:
            smask[mi] = 1.0
            v_new = float(value(jnp.asarray(smask)))
            phi[mi] += v_new - v_prev
            v_prev = v_new
    return jnp.asarray(phi / num_permutations, jnp.float32)

"""MFedMC federation loop — Algorithm 1, with every ablation knob from §4.

``run_federation`` executes T communication rounds:

  1. Local learning: each (available) client trains its modality encoders for
     E epochs, then Stage-#1 trains its fusion module (frozen encoders).
  2. Modality selection (§3.2): Shapley impact + encoder size + recency →
     composite priority → top-γ per client. Strategies: 'priority' (paper),
     'random', 'all' (upload every encoder — the no-modality-selection
     ablation), 'fixed:<name>' (heterogeneous-network tiers).
  3. Client selection (§3.3): server keeps ⌈δK⌉ clients by
     'low_loss' (paper) | 'high_loss' | 'random' | 'all' | 'loss_recency'.
  4. Server aggregation (Eq. 21) per modality as a stacked, device-resident
     reduction; the §4.10 uplink (1–16 bit, optionally with error-feedback
     residuals) quantizes the whole upload population in one vmapped
     program, and the ledger records exact wire bytes (packed codes +
     per-tensor scale/zero metadata).
  5. Local deploying: global encoders installed, Stage-#2 fusion fine-tune.

Joint selection (steps 2–3) runs through ONE decision layer shared by every
tier: the deterministic criteria execute on device over the ``[K, M]``
population matrices (``repro.core.selection_engine`` — bit-identical
outcomes to the numpy reference by construction), while the RNG-owning
strategies ('random' modality/client draws) stay host-side in the round's
generator order. ``cfg.selection_impl="host"`` keeps the pre-engine
per-client numpy block as the reference/benchmark path.

Round-persistent population arrays (recency matrix, wire sizes, losses,
presence) live in a :class:`~repro.core.federation_state.FederationState`;
``backend="engine"`` additionally keeps the *parameters* resident — stacked
per shape family, gathered/scattered per phase — so a round never restacks
or unstacks ``Client`` pytrees (see ``docs/ARCHITECTURE.md``).

§4.9 availability is trace-driven for every backend
(``repro.core.timing``: Bernoulli rates, Markov on/off churn), and
``backend="async"`` runs the whole loop on an event-driven virtual clock
(``repro.core.scheduler``): per-client compute/uplink time models,
staleness-aware buffered aggregation, and deadline-based straggler
dropping, with a degenerate config that reduces exactly to the
synchronous engine backend.

Returns a :class:`RunHistory` with per-round accuracy, cumulative MB, and
mean Shapley per modality (Fig. 5's data).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import hostsync
from repro.core.aggregation import (CommLedger, aggregate_quantized,
                                    aggregate_stacked, pad_axis0,
                                    pad_uploads_pow2, stack_uploads)
from repro.core.client import Client, make_client
from repro.core.federation_state import ClientStore, FederationState
from repro.core.quantize import (quantize_population,
                                 quantize_population_with_error_feedback,
                                 zero_residual)
from repro.core.selection import (modality_priority, select_clients,
                                  select_top_gamma)
from repro.core.selection_engine import (select_clients_arrays,
                                         select_modalities_arrays)
from repro.core.timing import resolve_trace
from repro.data.registry import DatasetSpec, get_dataset_spec
from repro.data.synthetic import ClientData


@dataclass
class MFedMCConfig:
    rounds: int = 20
    local_epochs: int = 5                  # E
    lr_encoder: float = 0.1                # LSTM lr (CNN uses 0.01, §4.2)
    lr_fusion: float = 0.1
    batch_size: int = 32
    gamma: int = 1                         # modality uploads per client
    delta: float = 0.2                     # client participation ratio
    alpha_s: float = 1 / 3
    alpha_c: float = 1 / 3
    alpha_r: float = 1 / 3
    modality_strategy: str = "priority"    # priority | random | all
    client_strategy: str = "low_loss"      # low_loss | high_loss | random |
                                           # all | loss_recency
    loss_weight: float = 1.0               # loss_recency blend (§4.8)
    selection_impl: str = "engine"         # engine (device [K, M] programs)
                                           # | host (per-client numpy ref)
    mesh_clients: Optional[int] = None     # backend="sharded": client-mesh
                                           # size D (None = every device)
    background_size: int = 50              # |D'| for Shapley
    eval_size: int = 32
    quantize_bits: int = 32                # 32 = no quantization (§4.10)
    comm_impl: str = "fused"               # fused (one-pass quantize+pack /
                                           # reduce-from-packed, kernels/
                                           # comm.py) | reference (separate
                                           # quantize + aggregate programs)
    train_impl: str = "fused"              # fused (one donated multi-epoch
                                           # program per bucket, kernels/
                                           # train.py) | reference (one
                                           # program per epoch per bucket)
    error_feedback: bool = False           # client-held EF residuals
    availability: float = 1.0              # client availability rate (§4.9)
    # -- virtual-time runtime (backend="async"; repro.core.scheduler) ---
    availability_trace: Optional[object] = None  # trace spec/object (§4.9
                                           # churn: "markov:p_drop,p_join",
                                           # "bernoulli:rate"); None falls
                                           # back to Bernoulli(availability)
    deadline_s: Optional[float] = None     # per-cycle reporting deadline on
                                           # the virtual clock (None = ∞:
                                           # never drop a straggler)
    buffer_size: Optional[int] = None      # aggregate every N client
                                           # arrivals (None = all arrivals,
                                           # one flush per cycle)
    staleness_discount: float = 1.0        # buffered-flush weight ×=
                                           # d**staleness (1.0 = off)
    recency_unit: str = "round"            # round | time — Eq. 11/§4.8 on
                                           # cycle indices or virtual clock
    compute_sec_per_step: float = 1e-3     # ComputeModel base step cost
    link_preset: str = "iot"               # iot | ici uplink preset
    link_sigma: float = 0.0                # log-normal per-client bandwidth
                                           # spread (0 = one shared link)
    straggler_fraction: float = 0.0        # clients at straggler_factor×
    straggler_factor: float = 10.0         # compute-time multiplier
    # per-client uplink restriction: client id -> allowed modality names
    allowed_modalities: Optional[Dict[int, Set[str]]] = None
    comm_budget_mb: Optional[float] = None # stop once exceeded
    fusion_input: str = "onehot"
    seed: int = 0


@dataclass
class RoundRecord:
    round: int
    accuracy: float
    mean_loss: float
    comm_mb: float
    uploads: List[Tuple[int, str]]
    shapley: Dict[str, float]              # mean |φ| per modality this round
    # -- virtual-time runtime fields (zero/empty on sync backends) ------
    sim_time: float = 0.0                  # virtual clock at cycle end (s)
    flushes: int = 0                       # buffered-aggregation flushes
    dropped: List[int] = field(default_factory=list)  # deadline-dropped ids


@dataclass
class RunHistory:
    records: List[RoundRecord] = field(default_factory=list)

    @property
    def makespan_s(self) -> float:
        """Simulated wall-clock of the whole run (async backend; 0.0 on
        sync backends, which do not advance a virtual clock)."""
        return self.records[-1].sim_time if self.records else 0.0

    @property
    def accuracies(self) -> np.ndarray:
        return np.array([r.accuracy for r in self.records])

    @property
    def comm_mb(self) -> np.ndarray:
        return np.array([r.comm_mb for r in self.records])

    def accuracy_under_budget(self, budget_mb: float) -> float:
        """Best accuracy reached with cumulative uplink ≤ budget (Table 2i)."""
        ok = [r.accuracy for r in self.records if r.comm_mb <= budget_mb]
        return max(ok) if ok else float("nan")

    def overhead_to_target(self, target_acc: float) -> float:
        """MB spent when accuracy first reaches target (Table 2ii); NaN=never."""
        for r in self.records:
            if r.accuracy >= target_acc:
                return r.comm_mb
        return float("nan")

    def final_accuracy(self) -> float:
        return self.records[-1].accuracy if self.records else float("nan")


def aggregate_uploads(clients: Sequence[Client], modality: str,
                      sample_counts: Sequence[int], bits: int, *,
                      error_feedback: bool = False, store=None,
                      comm_impl: str = "fused") -> Dict:
    """One modality's §4.10 uplink + Eq. 21 aggregation, device-resident.

    The selected clients' encoders stack on a leading K axis. At reduced
    precision, ``comm_impl`` picks the communication hot path:

    - ``"fused"`` (default): ``repro.kernels.comm`` — one program
      quantizes AND bit-packs the population, so only the wire-format
      payload (packed words + per-tensor scale/zero) crosses the program
      boundary; a second program computes the Eq. 21 mean straight from
      the packed words without materializing any dequantized stack.
    - ``"reference"``: the historical pipeline — ``quantize_population``
      hands unpacked code containers to ``aggregate_quantized``.

    Both paths produce bit-identical codes (pinned in
    ``tests/test_comm_kernels.py``) and report the device bytes of the
    payload that crossed the upload boundary to
    ``repro.core.hostsync.record_bytes``. With ``error_feedback`` each
    client's residual accumulator is folded into its payload and the new
    residual written back (strictly client-held state).

    ``store`` selects where the upload population lives: the default
    :class:`ClientStore` stacks from ``Client.encoders`` (loop/batched
    backends); a :class:`~repro.core.federation_state.StateStore` gathers
    rows of the resident stacked buckets instead (engine backend)."""
    from repro.kernels.comm import (payload_nbytes, quantize_pack_population,
                                    quantize_pack_population_ef,
                                    reduce_packed_population)
    store = store or ClientStore()
    with telemetry.span("comm.aggregate", modality=modality,
                        clients=len(clients), bits=bits, impl=comm_impl):
        stacked = store.gather_encoders([(c, modality) for c in clients])
        w = jnp.asarray(np.asarray(sample_counts, np.float32))
        stacked, w, pad = pad_uploads_pow2(stacked, w, len(clients))
        ref = clients[0].encoders[modality]
        if bits >= 32:
            hostsync.record_bytes(payload_nbytes(stacked))
            with telemetry.span("comm.reduce"):
                return aggregate_stacked(stacked, w)
        with telemetry.span("comm.quantize_pack"):
            if error_feedback:
                res = stack_uploads([
                    c.residuals[modality] if modality in c.residuals
                    else zero_residual(c.encoders[modality])
                    for c in clients])
                if pad:
                    res = pad_axis0(res, pad)
                if comm_impl == "fused":
                    packed, scales, zeros, new_res = \
                        quantize_pack_population_ef(stacked, res, bits=bits)
                else:
                    codes, scales, zeros, new_res = \
                        quantize_population_with_error_feedback(stacked, res,
                                                                bits=bits)
                for j, c in enumerate(clients):  # padded slots discarded
                    c.residuals[modality] = jax.tree.map(lambda v: v[j],
                                                         new_res)
            elif comm_impl == "fused":
                packed, scales, zeros = quantize_pack_population(stacked,
                                                                 bits=bits)
            else:
                codes, scales, zeros = quantize_population(stacked,
                                                           bits=bits)
        with telemetry.span("comm.reduce"):
            if comm_impl == "fused":
                hostsync.record_bytes(payload_nbytes(packed, scales, zeros))
                shapes = tuple(tuple(l.shape[1:])
                               for l in jax.tree_util.tree_leaves(stacked))
                agg = reduce_packed_population(packed, scales, zeros, w,
                                               bits=bits, shapes=shapes)
            else:
                hostsync.record_bytes(payload_nbytes(codes, scales, zeros))
                agg = aggregate_quantized(codes, scales, zeros, w)
        return jax.tree.map(lambda a, r: a.astype(r.dtype), agg, ref)


def _weighted_accuracy(clients: Sequence[Client]) -> Tuple[float, float]:
    tot, acc_sum, loss_sum = 0, 0.0, 0.0
    for c in clients:
        loss, acc, n = c.evaluate()
        tot += n
        acc_sum += acc * n
        loss_sum += loss * n
    return acc_sum / max(tot, 1), loss_sum / max(tot, 1)


def build_federation(dataset: str, scenario: str = "natural", *,
                     cfg: Optional[MFedMCConfig] = None, seed: int = 0,
                     reduced: bool = True,
                     client_datasets: Optional[List[ClientData]] = None,
                     **partition_kw) -> Tuple[List[Client], DatasetSpec]:
    from repro.data.partition import make_federation
    spec = get_dataset_spec(dataset)
    if client_datasets is None:
        client_datasets = make_federation(dataset, scenario, seed=seed,
                                          reduced=reduced, **partition_kw)
    fusion_input = cfg.fusion_input if cfg else "onehot"
    clients = [make_client(d.client_id, spec, d, seed=seed,
                           fusion_input=fusion_input)
               for d in client_datasets if d.num_samples > 1]
    return clients, spec


def _engine_modality_choices(state: FederationState, cand_ids: List[int],
                             names_by_cid: Dict[int, List[str]],
                             phi_by_name: Dict[int, Dict[str, float]],
                             t: int, cfg: MFedMCConfig,
                             recency_matrix: Optional[np.ndarray] = None
                             ) -> Dict[int, List[str]]:
    """Eqs. 12–16 for the whole candidate population in one device program
    (``selection_engine``) — outcome-identical to the per-client numpy
    block (``selection_impl="host"``). ``recency_matrix`` overrides the
    Eq. 11 round-index recency with the async runtime's virtual-clock
    [K, M] view (``recency_unit="time"``)."""
    n, M = len(cand_ids), len(state.modalities)
    phi = np.zeros((n, M))
    sizes = np.zeros((n, M))
    recency = np.zeros((n, M))
    presence = np.zeros((n, M), bool)
    for i, cid in enumerate(cand_ids):
        k = state.row_of[cid]
        sizes[i] = state.sizes[k]
        recency[i] = (t - state.last_upload[k] - 1
                      if recency_matrix is None else recency_matrix[k])
        for m in names_by_cid[cid]:
            mi = state.mod_index[m]
            presence[i, mi] = True
            phi[i, mi] = phi_by_name[cid][m]
    if getattr(state, "mesh", None) is not None:
        # sharded backend: same Eqs. 12–16 program, shard_map'ped over the
        # candidate block (outcome-identical; see repro.core.sharded)
        from repro.core.sharded import select_modalities_sharded
        shard_ids = np.array([state.shard_of[state.row_of[cid]]
                              for cid in cand_ids], np.int64)
        dec = select_modalities_sharded(
            phi, sizes, recency, presence, state.name_rank, shard_ids,
            state.mesh, t=t, gamma=cfg.gamma, alpha_s=cfg.alpha_s,
            alpha_c=cfg.alpha_c, alpha_r=cfg.alpha_r)
    else:
        dec = select_modalities_arrays(
            phi, sizes, recency, presence, state.name_rank, t=t,
            gamma=cfg.gamma, alpha_s=cfg.alpha_s, alpha_c=cfg.alpha_c,
            alpha_r=cfg.alpha_r)
    return {cid: dec.choices(i, state.modalities)
            for i, cid in enumerate(cand_ids)}


def _engine_client_selection(state: FederationState, cands: List[Client],
                             choices: Dict[int, List[str]], t: int,
                             cfg: MFedMCConfig,
                             client_staleness: Optional[np.ndarray] = None
                             ) -> List[int]:
    """Eqs. 17–19 as one device rank program — outcome-identical to
    ``selection.select_clients`` on the representative losses.
    ``client_staleness`` overrides the round-index §4.8 staleness with the
    async runtime's virtual-clock [K] view (``recency_unit="time"``)."""
    cand_ids = sorted(c.client_id for c in cands)
    n, M = len(cand_ids), len(state.modalities)
    rows = [state.row_of[cid] for cid in cand_ids]
    losses = state.losses[rows]          # ℓ_m^k, mirrored after training
    mask = np.zeros((n, M), bool)
    for i, cid in enumerate(cand_ids):
        for m in choices[cid]:
            mask[i, state.mod_index[m]] = True
    crec = None
    if cfg.client_strategy == "loss_recency":
        stale = (state.client_staleness(t) if client_staleness is None
                 else client_staleness)
        crec = np.array([stale[state.row_of[cid]] for cid in cand_ids])
    sel = select_clients_arrays(
        losses, mask, delta=cfg.delta, criterion=cfg.client_strategy,
        client_recency=crec, loss_weight=cfg.loss_weight)
    return [cid for i, cid in enumerate(cand_ids) if sel[i]]


def _joint_selection(avail: List[Client], state: FederationState,
                     cfg: MFedMCConfig, rng: np.random.Generator, t: int,
                     qbits: int, batched: bool, store, *,
                     recency_matrix: Optional[np.ndarray] = None,
                     client_staleness: Optional[np.ndarray] = None,
                     cache=None
                     ) -> Tuple[Dict[int, List[str]], List[int],
                                Dict[str, List[float]]]:
    """Algorithm 1 steps 2–3 (modality selection §3.2, client selection
    §3.3) over one round's available cohort.

    Shared verbatim by the synchronous backends and the virtual-time async
    runtime (``repro.core.scheduler``) so RNG consumption and selection
    outcomes cannot drift between them — the degenerate-async parity oracle
    depends on it. The optional ``recency_matrix``/``client_staleness``
    overrides feed Eq. 11 and §4.8 from the virtual clock instead of round
    indices (``recency_unit="time"``; engine selection only).

    Returns ``(choices, selected, round_shapley)``: per-client top-γ
    modality lists, the server-selected client ids, and the raw |φ| samples
    per modality for the round record."""
    with telemetry.span("select.joint", clients=len(avail)):
        # -- modality selection (§3.2) ----------------------------------
        round_shapley: Dict[str, List[float]] = {}
        choices: Dict[int, List[str]] = {}
        names_by_cid: Dict[int, List[str]] = {}
        engine_sel = cfg.selection_impl == "engine"
        with telemetry.span("select.modality"):
            for c in avail:
                names = list(c.modality_names)
                if cfg.allowed_modalities is not None:
                    allowed = cfg.allowed_modalities.get(c.client_id)
                    names = [m for m in names
                             if allowed is None or m in allowed]
                if names:
                    names_by_cid[c.client_id] = names
            phi_by_cid = None
            if cfg.modality_strategy not in ("all", "random") and batched:
                # one vmapped 2^M Shapley enumeration for the population;
                # draws the per-client eval/background subsets in the exact
                # client order the loop backend would (RNG parity)
                from repro.core.batched import batched_shapley_values
                shap_clients = [c for c in avail
                                if c.client_id in names_by_cid]
                if shap_clients:
                    with telemetry.span("select.shapley",
                                        clients=len(shap_clients)):
                        phi_by_cid = batched_shapley_values(
                            shap_clients, cfg.background_size,
                            cfg.eval_size, rng, store=store, cache=cache)
            phi_by_name: Dict[int, Dict[str, float]] = {}
            for c in avail:
                if c.client_id not in names_by_cid:
                    continue
                names = names_by_cid[c.client_id]
                if cfg.modality_strategy == "all":
                    choices[c.client_id] = names
                elif cfg.modality_strategy == "random":
                    g = min(cfg.gamma, len(names))
                    choices[c.client_id] = sorted(
                        rng.choice(names, size=g, replace=False).tolist())
                else:  # priority (paper)
                    phi = (phi_by_cid[c.client_id]
                           if phi_by_cid is not None
                           else c.shapley_values(cfg.background_size,
                                                 cfg.eval_size, rng))
                    phi_named = dict(zip(c.modality_names, phi))
                    phi_by_name[c.client_id] = phi_named
                    for m, p in phi_named.items():
                        round_shapley.setdefault(m, []).append(
                            abs(float(p)))
                    if engine_sel:
                        continue        # ranked below, whole population
                    # Eq. 10's cost criterion ranks what the uplink
                    # actually ships: exact compressed wire bytes at the
                    # round's precision
                    sizes = c.encoder_sizes(qbits)
                    idx = [list(c.modality_names).index(m) for m in names]
                    rec = c.recency.recency_vector(names, t)
                    prio = modality_priority(
                        np.array([phi[i] for i in idx]), sizes[idx], rec,
                        t, cfg.alpha_s, cfg.alpha_c, cfg.alpha_r)
                    choices[c.client_id] = select_top_gamma(
                        prio, names, cfg.gamma)
            if engine_sel and phi_by_name:
                choices.update(_engine_modality_choices(
                    state, sorted(phi_by_name), names_by_cid, phi_by_name,
                    t, cfg, recency_matrix=recency_matrix))

        # -- client selection (§3.3) ------------------------------------
        with telemetry.span("select.client"):
            cands = [c for c in avail if c.client_id in choices]
            if not cands:
                # No client has a selectable modality this round (e.g. an
                # allowed_modalities config that bars every candidate):
                # record an explicit empty-upload round instead of
                # selecting from an empty candidate set.
                selected: List[int] = []
            elif cfg.client_strategy == "all":
                selected = [c.client_id for c in cands]
            elif engine_sel and cfg.client_strategy != "random":
                selected = _engine_client_selection(
                    state, cands, choices, t, cfg,
                    client_staleness=client_staleness)
            else:
                # representative loss = min over the selected modalities
                losses = {c.client_id: min(c.losses[m]
                                           for m in choices[c.client_id])
                          for c in cands}
                crit = cfg.client_strategy
                client_rec: Dict[int, int] = {}
                if crit == "loss_recency":
                    for c in cands:
                        client_rec[c.client_id] = t - 1 - max(
                            c.recency.last_upload.values(), default=-1)
                selected = select_clients(
                    losses, cfg.delta, criterion=crit, recency=client_rec,
                    loss_weight=cfg.loss_weight, rng=rng)
        return choices, selected, round_shapley


def run_federation(clients: List[Client], spec: DatasetSpec,
                   cfg: MFedMCConfig, *, verbose: bool = False,
                   server_encoders: Optional[Dict[str, Dict]] = None,
                   backend: str = "loop",
                   quantize_bits: Optional[int] = None) -> RunHistory:
    """Run T rounds of Algorithm 1.

    ``backend`` selects how the per-client hot phases execute:
      - ``"loop"``    — per-client Python loop (paper-faithful reference);
      - ``"batched"`` — the whole population (including ragged federations:
        diverse modality sets, skewed sample counts) is stacked on a leading
        K axis and trained with padded, mask-weighted vmapped SGD
        (``repro.core.batched``); exact Shapley and evaluation run vmapped
        over the same stacked layout. Both backends consume the round RNG
        identically, so selection, aggregation and the comm ledger match the
        loop to float tolerance.
      - ``"engine"``  — the batched backend with the population *resident*:
        encoders and fusion modules stay stacked per shape family inside a
        :class:`FederationState` for the whole run (training, predictions,
        Eq. 21 and deployment gather/scatter rows on device), and the
        ``Client`` objects are written back once at the end. Selection and
        RNG behavior are identical to the other backends.
      - ``"async"``   — the engine backend on an event-driven virtual
        clock (``repro.core.scheduler``): DISPATCH → LOCAL_DONE →
        UPLOAD_DONE events from per-client compute/uplink models,
        availability traces, buffered staleness-discounted aggregation,
        and a reporting deadline that drops stragglers. The degenerate
        config (``deadline_s=None``, ``buffer_size=None``,
        ``staleness_discount=1.0``) matches ``"engine"`` exactly on
        uploads/ledger/selection and ≤1e-5 on encoders.
      - ``"sharded"`` — the engine backend with the resident population
        split row-wise over a 1-D client mesh (``cfg.mesh_clients``
        devices; ``repro.core.sharded``): local training and modality
        selection run as per-shard ``shard_map`` programs, Eq. 21 is a
        masked ``psum`` of upload-weighted rows (fused with the §4.10
        quantizer at reduced precision), and per-round host syncs stay
        O(1) in mesh size. On a 1×1 mesh it reduces to ``"engine"``
        exactly on uploads/ledger/selection and ≤1e-5 on encoders.

    All backends route joint selection through the shared decision layer:
    deterministic criteria run as device ``[K, M]`` programs
    (``repro.core.selection_engine``; ``cfg.selection_impl="host"`` keeps
    the per-client numpy reference), RNG-owning strategies stay host-side
    in generator order.

    The §4.10 uplink (``quantize_bits`` — overrides ``cfg.quantize_bits``
    when given) runs device-resident for every backend: per modality, the
    selected uploads stack on a K axis, quantize vmapped, and aggregate
    through one fused dequantize-and-reduce program
    (:func:`aggregate_uploads`); the ledger records exact wire bytes
    (bit-packed codes + per-tensor scale/zero metadata).
    """
    if backend not in ("loop", "batched", "engine", "async", "sharded"):
        raise ValueError(f"unknown backend {backend!r}")
    if cfg.selection_impl not in ("engine", "host"):
        raise ValueError(f"unknown selection_impl {cfg.selection_impl!r}")
    if cfg.comm_impl not in ("fused", "reference"):
        raise ValueError(f"unknown comm_impl {cfg.comm_impl!r}: use "
                         '"fused" or "reference"')
    if cfg.train_impl not in ("fused", "reference"):
        raise ValueError(f"unknown train_impl {cfg.train_impl!r}: use "
                         '"fused" or "reference"')
    qbits = cfg.quantize_bits if quantize_bits is None else quantize_bits
    if qbits < 32 and not 1 <= qbits <= 16:
        raise ValueError(f"quantize_bits={qbits} unsupported: use 1..16 "
                         "(quantized) or >= 32 (full precision)")
    if cfg.recency_unit not in ("round", "time"):
        raise ValueError(f"unknown recency_unit {cfg.recency_unit!r}")
    if not 0.0 < cfg.staleness_discount <= 1.0:
        raise ValueError("staleness_discount must be in (0, 1]")
    if backend == "async":
        from repro.core.scheduler import run_async_federation
        return run_async_federation(clients, spec, cfg, verbose=verbose,
                                    server_encoders=server_encoders,
                                    quantize_bits=qbits)
    # the async-only aggregation-semantics knobs must not be silently
    # dropped: a sync run with a deadline configured is not "the same run
    # without stragglers", it is a different experiment
    if cfg.recency_unit == "time":
        raise ValueError('recency_unit="time" needs the virtual clock: '
                         'use backend="async"')
    if cfg.deadline_s is not None or cfg.buffer_size is not None or \
            cfg.staleness_discount != 1.0:
        raise ValueError(
            "deadline_s/buffer_size/staleness_discount only take effect on "
            f'the virtual clock — use backend="async" (got backend='
            f'{backend!r})')
    if cfg.mesh_clients is not None and backend != "sharded":
        raise ValueError('mesh_clients sizes the client mesh — use '
                         f'backend="sharded" (got backend={backend!r})')
    if backend == "sharded" and cfg.error_feedback:
        raise ValueError(
            "error_feedback residuals are client-held state the sharded "
            "backend does not fold into its resident shards yet")
    rng = np.random.default_rng(cfg.seed)
    ledger = CommLedger()
    history = RunHistory()
    # global encoder store (initialized lazily from the first upload)
    server_encoders = server_encoders if server_encoders is not None else {}

    resident = backend in ("engine", "sharded")
    batched = backend in ("batched", "engine", "sharded")
    # population decision arrays (recency matrix, exact wire sizes at this
    # run's precision, presence, losses); resident runs also stack params
    if backend == "sharded":
        from repro.core.sharded import ShardedFederationState, client_mesh
        state = ShardedFederationState.build_sharded(
            clients, spec, qbits, mesh=client_mesh(cfg.mesh_clients))
    else:
        state = FederationState.build(clients, spec, qbits, stack=resident)
    store = state.store if resident else ClientStore()

    trace = resolve_trace(cfg)
    tr = telemetry.get()
    try:
        for t in range(1, cfg.rounds + 1):
          with telemetry.span("round", round=t, backend=backend):
            # -- client availability (§4.9, trace-driven) ----------------
            avail_mask = trace.step(rng, len(clients))
            avail = [c for k, c in enumerate(clients) if avail_mask[k]]
            if not avail:
                # nobody reported this round: an explicit empty-upload
                # round (shared semantics with the baselines) — no
                # training, no uploads, accuracy of the current models
                with telemetry.span("eval"):
                    if batched:
                        from repro.core.batched import batched_evaluate
                        acc, loss = batched_evaluate(clients, store=store)
                    else:
                        acc, loss = _weighted_accuracy(clients)
                ledger.rounds = t
                history.records.append(RoundRecord(
                    t, acc, loss, ledger.megabytes, [], {}))
                if tr is not None:
                    tr.metrics.record_round(
                        round=t, accuracy=float(acc),
                        mean_loss=float(loss),
                        comm_mb=ledger.megabytes, uplink=[],
                        selected=[], choices={}, shapley={}, dropped=[])
                continue

            # -- local learning ------------------------------------------
            # one train-split prediction cache per round: filled by
            # Stage-#1 fusion, reused by Shapley, dropped before deploy
            # overwrites the encoders it was computed from
            cache = None
            if batched:
                from repro.core.batched import PredictionCache
                cache = PredictionCache()
            with telemetry.span("train.local", clients=len(avail)):
                if backend == "sharded":
                    from repro.core.sharded import sharded_local_learning
                    sharded_local_learning(avail, cfg, rng, state,
                                           cache=cache)
                elif batched:
                    from repro.core.batched import batched_local_learning
                    batched_local_learning(avail, cfg, rng, store=store,
                                           cache=cache)
                else:
                    for c in avail:
                        c.train_encoders(cfg.local_epochs, cfg.lr_encoder,
                                         cfg.batch_size, rng)
                        c.train_fusion(cfg.local_epochs, cfg.lr_fusion,
                                       cfg.batch_size, rng)  # Stage #1
                for c in avail:             # mirror ℓ_m^k into the state
                    k = state.row_of[c.client_id]
                    for m, v in c.losses.items():
                        state.losses[k, state.mod_index[m]] = v

            # -- joint selection (§3.2 + §3.3, shared with async) ---------
            choices, selected, round_shapley = _joint_selection(
                avail, state, cfg, rng, t, qbits, batched, store,
                cache=cache)

            # -- upload + server aggregation (Eq. 21, §4.10 uplink) -------
            by_id = {c.client_id: c for c in clients}
            uploads: List[Tuple[int, str]] = []
            per_modality: Dict[str, List[Client]] = {}
            upload_mask = np.zeros_like(state.presence)
            uplink_log: List[Dict] = []
            with telemetry.span("comm.uplink", clients=len(selected)):
                for cid in selected:
                    c = by_id[cid]
                    k = state.row_of[cid]
                    for m in choices[cid]:
                        per_modality.setdefault(m, []).append(c)
                        # exact wire bytes, precomputed once per run
                        nb = float(state.sizes[k, state.mod_index[m]])
                        ledger.record(nb, modality=m)
                        uplink_log.append({"client": cid, "modality": m,
                                           "bytes": nb})
                        uploads.append((cid, m))
                        upload_mask[k, state.mod_index[m]] = True
                    c.recency.mark_uploaded(choices[cid], t)  # tracker
                state.mark_uploaded(upload_mask, t)        # Eq. 11, [K, M]
                for m, ups in per_modality.items():
                    if backend == "sharded":
                        from repro.core.sharded import \
                            aggregate_modality_sharded
                        server_encoders[m] = aggregate_modality_sharded(
                            state, ups, m,
                            [c.train.num_samples for c in ups],
                            qbits, comm_impl=cfg.comm_impl)
                    else:
                        server_encoders[m] = aggregate_uploads(
                            ups, m, [c.train.num_samples for c in ups],
                            qbits, error_feedback=cfg.error_feedback,
                            store=store, comm_impl=cfg.comm_impl)

            # -- local deploying + Stage #2 -------------------------------
            with telemetry.span("deploy"):
                if resident:
                    for m, params in server_encoders.items():
                        rows = [state.row_of[c.client_id] for c in avail
                                if m in c.encoders]
                        state.deploy_global(m, rows, params)
                else:
                    for c in avail:
                        for m in c.modality_names:
                            if m in server_encoders:
                                c.install_global(m, server_encoders[m])
            with telemetry.span("train.fusion2", clients=len(avail)):
                if batched:
                    from repro.core.batched import batched_fusion_stage
                    batched_fusion_stage(avail, cfg, rng, store=store)
                else:
                    for c in avail:
                        c.train_fusion(cfg.local_epochs, cfg.lr_fusion,
                                       cfg.batch_size, rng)  # Stage #2

            # -- evaluate -------------------------------------------------
            with telemetry.span("eval"):
                if batched:
                    from repro.core.batched import batched_evaluate
                    acc, loss = batched_evaluate(clients, store=store)
                else:
                    acc, loss = _weighted_accuracy(clients)
            ledger.rounds = t
            shap = {m: float(np.mean(v))
                    for m, v in round_shapley.items()}
            history.records.append(RoundRecord(
                t, acc, loss, ledger.megabytes, uploads, shap))
            if tr is not None:
                tr.metrics.record_round(
                    round=t, accuracy=float(acc), mean_loss=float(loss),
                    comm_mb=ledger.megabytes, uplink=uplink_log,
                    selected=sorted(int(cid) for cid in selected),
                    choices={int(cid): list(choices[cid])
                             for cid in selected},
                    shapley=shap, dropped=[])
            if verbose:
                print(f"[round {t:3d}] acc={acc:.4f} loss={loss:.4f} "
                      f"comm={ledger.megabytes:.3f}MB "
                      f"uploads={len(uploads)}")
            if cfg.comm_budget_mb is not None and \
                    ledger.megabytes >= cfg.comm_budget_mb:
                break
    finally:
        if resident:
            with telemetry.span("write_back"):
                state.write_back()
        if tr is not None:
            tr.metrics.set_run(
                backend=backend, rounds=len(history.records),
                ledger_bytes=float(ledger.uploaded_bytes),
                ledger_uploads=int(ledger.uploads),
                ledger_by_modality={m: float(v) for m, v in
                                    ledger.by_modality.items()})
    return history


def run_mfedmc(dataset: str, scenario: str = "natural",
               cfg: Optional[MFedMCConfig] = None, *, verbose: bool = False,
               backend: str = "loop", **partition_kw) -> RunHistory:
    """One-call paper pipeline: build federation + run Algorithm 1."""
    cfg = cfg or MFedMCConfig()
    clients, spec = build_federation(dataset, scenario, cfg=cfg,
                                     seed=cfg.seed, **partition_kw)
    return run_federation(clients, spec, cfg, verbose=verbose,
                          backend=backend)

"""Host-sync accounting for the federation round's device→host boundary.

Every place the round loop moves data off the accelerator — per-batch loss
scalars in the loop backend, per-bucket loss arrays in the batched backend,
the selection engine's decision fetch — funnels through :func:`fetch` /
:func:`fetch_scalar`, so ``benchmarks/bench_selection_round.py`` can report
*measured* host-syncs-per-round instead of an estimate. The counter is
process-global and costs one integer increment when nobody is measuring.

The module also carries the uplink **bytes-moved** counter: every
aggregation path (reference or fused, batched/engine/sharded/async)
reports the device bytes of the payload that crossed its upload program
boundary via :func:`record_bytes`, so ``benchmarks/bench_quantized_round``
can compare *measured* bytes against the §4.10 wire-format roofline.

Third counter: **dispatches** — the number of jitted programs the
local-training path launches (encoder epochs, fusion epochs, prediction
forwards, the Shapley enumeration, evaluation). Every training-path call
site in ``repro.core.batched`` / ``repro.core.sharded`` reports through
:func:`record_dispatch`, so ``benchmarks/bench_train_step.py`` and the
budget manifest can pin *measured* dispatched-programs-per-round for the
fused (one multi-epoch program per bucket) vs reference (one program per
epoch per bucket) trainers.

Measurements should scope through :func:`measuring`, which snapshots and
restores the process-global counters atomically — nested measurements and
surrounding accumulation both stay correct, and a test that forgets to
reset can no longer leak counts into the next one (the ``lint`` tier and
``tests/conftest.py``'s autouse fixture both rely on this).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass

import numpy as np

_count = 0
_bytes = 0
_dispatches = 0


def fetch(x) -> np.ndarray:
    """Device→host transfer of an array (counted)."""
    global _count
    _count += 1
    return np.asarray(x)


def fetch_scalar(x) -> float:
    """Device→host transfer of a scalar (counted)."""
    global _count
    _count += 1
    return float(x)


def record_bytes(n: int) -> None:
    """Account ``n`` payload bytes moved across an upload boundary."""
    global _bytes
    _bytes += int(n)


def record_dispatch(n: int = 1) -> None:
    """Account ``n`` jitted local-training program launches."""
    global _dispatches
    _dispatches += int(n)


def reset() -> None:
    global _count, _bytes, _dispatches
    _count = 0
    _bytes = 0
    _dispatches = 0


def count() -> int:
    return _count


def bytes_moved() -> int:
    return _bytes


def dispatches() -> int:
    return _dispatches


@dataclass
class Measurement:
    """One scoped measurement window's counters.

    Inside the ``with`` block the properties read live; after exit they are
    frozen at the block's totals."""
    _frozen_syncs: int = 0
    _frozen_bytes: int = 0
    _frozen_dispatches: int = 0
    _live: bool = True

    @property
    def syncs(self) -> int:
        return _count if self._live else self._frozen_syncs

    @property
    def bytes_moved(self) -> int:
        return _bytes if self._live else self._frozen_bytes

    @property
    def dispatches(self) -> int:
        return _dispatches if self._live else self._frozen_dispatches

    def as_dict(self) -> dict:
        """The window's counters under the canonical budget keys — the
        schema ``budgets.json``, the BENCH jsons, and the telemetry run
        totals all share (``host_syncs`` / ``bytes_moved`` /
        ``dispatches``)."""
        return {"host_syncs": int(self.syncs),
                "bytes_moved": int(self.bytes_moved),
                "dispatches": int(self.dispatches)}


@contextlib.contextmanager
def measuring():
    """Scope a measurement: reset the counters on entry, yield a live
    :class:`Measurement`, and on exit freeze its totals and fold them back
    into the enclosing scope's counters — so an outer ``measuring()`` (or a
    caller accumulating across rounds) still sees every sync and byte, and
    two sequential windows can never bleed into each other."""
    global _count, _bytes, _dispatches
    outer = (_count, _bytes, _dispatches)
    _count, _bytes, _dispatches = 0, 0, 0
    m = Measurement()
    try:
        yield m
    finally:
        m._frozen_syncs, m._frozen_bytes = _count, _bytes
        m._frozen_dispatches = _dispatches
        m._live = False
        _count = outer[0] + m._frozen_syncs
        _bytes = outer[1] + m._frozen_bytes
        _dispatches = outer[2] + m._frozen_dispatches

"""Host-sync accounting for the federation round's device→host boundary.

Every place the round loop moves data off the accelerator — per-batch loss
scalars in the loop backend, per-bucket loss arrays in the batched backend,
the selection engine's decision fetch — funnels through :func:`fetch` /
:func:`fetch_scalar`, so ``benchmarks/bench_selection_round.py`` can report
*measured* host-syncs-per-round instead of an estimate. The counter is
process-global and costs one integer increment when nobody is measuring.

The module also carries the uplink **bytes-moved** counter: every
aggregation path (reference or fused, batched/engine/sharded/async)
reports the device bytes of the payload that crossed its upload program
boundary via :func:`record_bytes`, so ``benchmarks/bench_quantized_round``
can compare *measured* bytes against the §4.10 wire-format roofline.
"""
from __future__ import annotations

import numpy as np

_count = 0
_bytes = 0


def fetch(x) -> np.ndarray:
    """Device→host transfer of an array (counted)."""
    global _count
    _count += 1
    return np.asarray(x)


def fetch_scalar(x) -> float:
    """Device→host transfer of a scalar (counted)."""
    global _count
    _count += 1
    return float(x)


def record_bytes(n: int) -> None:
    """Account ``n`` payload bytes moved across an upload boundary."""
    global _bytes
    _bytes += int(n)


def reset() -> None:
    global _count, _bytes
    _count = 0
    _bytes = 0


def count() -> int:
    return _count


def bytes_moved() -> int:
    return _bytes

"""MFedMC on the production mesh — the datacenter adaptation (DESIGN.md §3).

The paper's federation is IoT-scale (10 Mbps uplinks). On a TPU pod the same
algorithm becomes a *sparse, masked cross-device reduction*:

- the K-client population is stacked on a leading axis and sharded over the
  mesh's data-parallel axes (``('pod', 'data')`` multi-pod);
- each client's E local epochs run as a ``lax.scan`` of vmapped SGD steps —
  no cross-client communication;
- Eq. 21's weighted FedAvg is ``psum(select·weight·θ) / psum(select·weight)``
  over the client axes — the 0/1 ``select`` mask is the joint
  modality+client selection, so *unselected clients contribute zero bytes of
  gradient-carrying payload*: the collective's useful traffic shrinks by
  exactly the paper's γ/M̄·δ factor (the roofline benchmark measures this);
- deployment (encoder download) is the broadcast half of the same collective:
  clients that own the modality overwrite their slot with the aggregate.

``make_federated_round`` returns a jit-able function suitable for
``.lower().compile()`` on the production mesh (see launch/dryrun.py
--mode=federated and benchmarks/roofline_federated.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.encoders import encoder_loss, masked_encoder_loss
from repro.core.quantize import code_dtype, fake_quantize_pytree


def _client_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the client population is sharded over."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def make_federated_round(mesh, *, local_steps: int, lr: float = 0.1,
                         loss_fn: Callable = encoder_loss,
                         masked_loss_fn: Optional[Callable] = None,
                         hierarchical: bool = False,
                         uplink_dtype=None,
                         quantize_bits: Optional[int] = None):
    """Build the jit-able one-round function for one modality's encoders.

    Signature of the returned fn:
        (stacked_params,            # pytree with leading K axis
         batches,                   # {x: [K, S, B, ...], y: [K, S, B]}
                                    #  + optional {w: [K, S, B]} sample mask
         select,                    # [K] float 0/1 — joint selection mask
         weight)                    # [K] float — |D_m^k| sample counts
        -> (new_stacked_params, aggregated_params, per_client_loss [K])

    ``quantize_bits`` (1–16) is §4.10's quantized uplink composed into the
    mesh round: each client's payload is affine-quantized *per client, per
    tensor* on device (vmapped fake-quant over the local shard) before
    Eq. 21's masked weighted all-reduce, so the server aggregate is built
    from exactly what a ``bits``-bit wire would deliver. Local training
    itself runs at full precision — quantization touches only the payload
    entering the reduction (deployment then broadcasts the aggregate into
    every slot, exactly as at full precision). ``uplink_dtype`` (e.g.
    bfloat16) remains the cheaper reduced-precision-collective variant
    applied to the summed numerator.

    Ragged federations use the padded population layout shared with the
    Tier-2 simulator (``repro.core.batched.padded_population_batches``):
    when ``batches`` carries a 0/1 sample mask ``w``, each step's loss is
    mask-weighted (``masked_loss_fn``, defaulting to the masked counterpart
    of ``encoder_loss``), fully-padded steps are exact no-op updates, and
    ``per_client_loss`` averages over real steps only — so clients with
    diverse sample counts (and absent-modality dummies with all-zero masks
    and zero Eq. 21 weight) ride the same mesh program.

    ``hierarchical=True`` (beyond-paper): a within-pod FedAvg runs after
    every local step over the cheap intra-pod ICI, and the selective
    (masked) aggregation runs once over the expensive cross-pod axis.
    """
    caxes = _client_axes(mesh)
    has_pod = "pod" in mesh.shape
    if masked_loss_fn is None and loss_fn is encoder_loss:
        masked_loss_fn = masked_encoder_loss
    if quantize_bits is not None and quantize_bits < 32:
        code_dtype(quantize_bits)       # validate early: 1..16 only
    else:
        quantize_bits = None            # >= 32 -> full-precision uplink

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(caxes), P(caxes), P(caxes), P(caxes)),
        out_specs=(P(caxes), P(), P(caxes)),
        check_rep=False)
    def round_fn(params, batches, select, weight):
        has_w = "w" in batches
        if has_w and masked_loss_fn is None:
            raise ValueError("batches carry a sample mask 'w' but no "
                             "masked_loss_fn was provided")

        # ---- local learning: scan(E·steps) of vmapped per-client SGD ----
        def local_step(pp, xyw):
            if has_w:
                x, y, w = xyw
                loss, g = jax.value_and_grad(masked_loss_fn)(pp, x, y, w)
            else:
                x, y = xyw
                loss, g = jax.value_and_grad(loss_fn)(pp, x, y)
            pp = jax.tree.map(lambda a, b: a - lr * b, pp, g)
            if hierarchical and has_pod:
                # within-pod sync every step (cheap ICI axis)
                pp = jax.tree.map(lambda a: jax.lax.pmean(a, "data"), pp)
            return pp, loss

        def one_client(p, *xs):
            return jax.lax.scan(local_step, p, xs)

        args = (batches["x"], batches["y"])
        if has_w:
            args = args + (batches["w"],)
        new_params, losses = jax.vmap(one_client)(params, *args)
        if has_w:
            sv = (jnp.sum(batches["w"], axis=-1) > 0).astype(losses.dtype)
            per_client_loss = (jnp.sum(losses * sv, axis=-1)
                               / jnp.maximum(jnp.sum(sv, axis=-1), 1.0))
        else:
            per_client_loss = jnp.mean(losses, axis=-1)

        # ---- §4.10 uplink: per-client on-device quantized payload ----
        if quantize_bits is not None:
            # vmapped fake-quant over the local K/shard axis: per-client
            # per-tensor affine codes — the server reduction below consumes
            # exactly what a quantize_bits-bit wire would deliver; local
            # training above ran at full precision, only this payload copy
            # is quantized
            upload = jax.vmap(
                lambda t: fake_quantize_pytree(t, quantize_bits))(new_params)
        else:
            upload = new_params

        # ---- Eq. 21 as a masked sparse all-reduce over client axes ----
        w = (select * weight)[:, None]                      # [K/shard, 1]
        axes = caxes if not (hierarchical and has_pod) else ("pod",)

        def allreduce(x):
            num = jnp.sum(w.reshape(w.shape[:1] + (1,) * (x.ndim - 1)) * x,
                          axis=0, keepdims=False)
            if uplink_dtype is not None:
                # reduced-precision collective: the numerator itself ships
                # in uplink_dtype (cheaper than per-client codes, coarser)
                num = num.astype(uplink_dtype)
            for a in axes:
                num = jax.lax.psum(num, a)
            return num.astype(jnp.float32)

        denom = jnp.sum(w[:, 0])
        for a in axes:
            denom = jax.lax.psum(denom, a)
        agg = jax.tree.map(lambda x: allreduce(x) / jnp.maximum(denom, 1e-8),
                           upload)

        # ---- deployment: selected aggregate broadcast into every slot ----
        deployed = jax.tree.map(
            lambda cur, g: jnp.where(
                jnp.reshape(denom > 0, (1,) * cur.ndim),
                jnp.broadcast_to(g[None], cur.shape), cur),
            new_params, agg)
        return deployed, agg, per_client_loss

    return round_fn


def make_multimodal_federated_round(mesh, *, local_steps: int,
                                    lr: float = 0.1,
                                    loss_fn: Callable = encoder_loss,
                                    masked_loss_fn: Optional[Callable] = None,
                                    hierarchical: bool = False,
                                    uplink_dtype=None,
                                    quantize_bits: Optional[int] = None):
    """The batched multi-modality round: every modality's encoder population
    trains and aggregates inside ONE jit'd mesh program.

    Each modality carries its own stacked pytree (clients on the leading K
    axis, sharded over the mesh client axes) and its own [K] 0/1 mask, so the
    joint modality-and-client selection (Eq. 20) — not just client selection —
    gates Eq. 21's weighted all-reduce per (client, modality) pair.

    Signature of the returned fn (all dicts keyed by modality name):
        (params,    # {m: pytree with leading K axis}
         batches,   # {m: {"x": [K, S, B, ...], "y": [K, S, B]}}
         select,    # {m: [K] float 0/1} — per-(client, modality) mask
         weight)    # {m: [K] float}     — |D_m^k| sample counts
        -> (deployed, aggregated, per_client_loss) dicts keyed by modality

    The python loop over modalities unrolls at trace time: XLA sees one
    program with M independent masked reductions and can overlap their
    collectives. A modality whose mask is all-zero skips the broadcast and
    keeps each client's locally-trained params (denominator guard in the
    single-modality round). ``quantize_bits`` applies §4.10's per-client
    uplink quantization to every modality's payload (see
    :func:`make_federated_round`).
    """
    single = make_federated_round(mesh, local_steps=local_steps, lr=lr,
                                  loss_fn=loss_fn,
                                  masked_loss_fn=masked_loss_fn,
                                  hierarchical=hierarchical,
                                  uplink_dtype=uplink_dtype,
                                  quantize_bits=quantize_bits)

    def round_fn(params: Dict, batches: Dict, select: Dict, weight: Dict):
        deployed: Dict = {}
        agg: Dict = {}
        losses: Dict = {}
        for m in sorted(params):
            deployed[m], agg[m], losses[m] = single(
                params[m], batches[m], select[m], weight[m])
        return deployed, agg, losses

    return round_fn


def selection_masks(choices: Mapping[int, Sequence[str]],
                    selected_clients: Sequence[int],
                    num_clients: int,
                    modality_names: Sequence[str]) -> Dict[str, jnp.ndarray]:
    """Joint selection (Eq. 20) -> per-modality [K] 0/1 device masks.

    ``choices`` maps client id -> modality names that client would upload
    (top-γ, Eq. 16); ``selected_clients`` are the server-kept ids (Eq. 19).
    Client ids index the stacked K axis directly.
    """
    chosen = set(int(k) for k in selected_clients)
    masks = {}
    for m in modality_names:
        row = [1.0 if (k in chosen and m in choices.get(k, ())) else 0.0
               for k in range(num_clients)]
        masks[m] = jnp.asarray(row, jnp.float32)
    return masks


def selection_masks_from_matrix(upload_mask,
                                modality_names: Sequence[str]
                                ) -> Dict[str, jnp.ndarray]:
    """[K, M] joint-selection matrix (Eq. 20 — e.g.
    ``selection_engine.EngineDecision.upload_mask``) -> the per-modality
    ``{m: [K]}`` dict the multimodal mesh round consumes. Column order must
    match ``modality_names``."""
    m_arr = jnp.asarray(np.asarray(upload_mask, np.float32))
    return {m: m_arr[:, i] for i, m in enumerate(modality_names)}


def multimodal_input_specs(num_clients: int, steps: int, batch: int,
                           feature_shapes: Mapping[str, Tuple[int, ...]],
                           param_specs: Mapping[str, Dict],
                           with_mask: bool = False) -> Dict:
    """Per-modality ShapeDtypeStruct stand-ins for the dry-run."""
    specs = {m: federated_input_specs(num_clients, steps, batch,
                                      feature_shapes[m], param_specs[m],
                                      with_mask=with_mask)
             for m in feature_shapes}
    return {
        "params": {m: s["params"] for m, s in specs.items()},
        "batches": {m: s["batches"] for m, s in specs.items()},
        "select": {m: s["select"] for m, s in specs.items()},
        "weight": {m: s["weight"] for m, s in specs.items()},
    }


def federated_input_specs(num_clients: int, steps: int, batch: int,
                          feature_shape: Tuple[int, ...],
                          param_spec, with_mask: bool = False) -> Dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).

    ``with_mask=True`` adds the ``w`` sample mask of the padded ragged
    layout, so the lowered program is the masked variant."""
    S = jax.ShapeDtypeStruct
    stacked = jax.tree.map(
        lambda s: S((num_clients,) + s.shape, s.dtype), param_spec)
    batches = {
        "x": S((num_clients, steps, batch) + tuple(feature_shape),
               jnp.float32),
        "y": S((num_clients, steps, batch), jnp.int32),
    }
    if with_mask:
        batches["w"] = S((num_clients, steps, batch), jnp.float32)
    return {
        "params": stacked,
        "batches": batches,
        "select": S((num_clients,), jnp.float32),
        "weight": S((num_clients,), jnp.float32),
    }


def federated_shardings(mesh, specs):
    caxes = _client_axes(mesh)

    def shard(leaf):
        return NamedSharding(mesh, P(caxes))

    return jax.tree.map(shard, specs)

"""Client-axis sharded resident population — ``backend="sharded"``.

``backend="engine"`` made the population *resident*: one device holds the
stacked per-shape-family encoder/fusion buckets and the ``[K, M]`` decision
matrices for the whole run. This module splits that residency row-wise
across the devices of a 1-D ``clients`` mesh
(``repro.sharding.partition.client_mesh``), so population capacity scales
with mesh size while the round structure — and therefore every parity
oracle — stays the engine's:

- **Layout.** Clients map to shards round-robin (``k % D``). Each bucket's
  slots are *shard-major* (``repro.sharding.partition.shard_slots``):
  shard d owns one equal-size block of rows, padded to the largest
  per-shard group, and every leaf is placed with
  ``NamedSharding(mesh, P("clients"))``. On a 1×1 mesh the layout (and so
  the whole backend) degenerates to the engine's bucket order exactly.
- **Training.** Local learning runs the *full* resident bucket through one
  ``shard_map``-ped program per epoch — each device scans its own
  ``[G/D, S, B]`` block with no cross-device communication. Unavailable
  clients and padding slots carry all-zero sample masks: the masked loss is
  identically 0 with zero gradient, so their SGD steps are exact no-ops and
  a fixed program shape serves every round (no per-round gathers, O(1)
  compilations).
- **Modality selection.** ``selection_engine._modality_program`` (Eqs.
  12–16) is row-independent, so it runs as one ``shard_map``-ped
  ``[Kc/D, M]`` program over the shard-major-permuted candidate block —
  same f64 math, AOT-compiled at ``xla_backend_optimization_level=0``, so
  outcomes stay bit-identical to the numpy reference. Client selection
  (Eqs. 17–19) is a global rank over ⌈δK⌉ — inherently cross-shard, and
  tiny — and stays on the engine path.
- **Aggregation.** Eq. 21 is a masked ``psum``: each shard contracts its
  own block's upload-weighted rows, weights sum-normalized by a global
  ``psum`` with the engine's ``max(Σw, 1e-12)`` guard — a shard whose
  clients all sat out contributes an exact zero term, never NaN. At
  reduced precision the PR 3 quantizer fuses in: each shard quantizes,
  dequantizes, and contracts its rows in one program (per-row ranges make
  the codes independent of which rows share a shard).
- **Edge→cloud reading.** The two-tier wireless-MFL topology (Han et al.,
  2509.12930) maps onto this mesh: a shard's local contraction is the edge
  server's aggregate over its associated clients, the ``psum`` is the
  cloud's aggregate over edges, and the PR 5 staleness machinery (buffered
  flushes on the virtual clock) gives the per-edge flush cadence.

Host-sync discipline: per round, the sharded backend fetches exactly what
the engine fetches — final-epoch losses (one per bucket), the three
modality-selection outputs, the client-selection mask, and the evaluation
reductions. Nothing scales with mesh size (``bench_sharded_population``
measures this via ``repro.core.hostsync``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import telemetry
from repro.core import hostsync
from repro.core.encoders import masked_encoder_loss
from repro.core.federation_state import (FederationState, StateStore,
                                         _EncoderBucket, _FusionBucket)
from repro.core.quantize import dequantize_tensor, quantize_population
from repro.kernels.comm import _quantize_rows as _quantize_rows_fused
from repro.core.selection_engine import (_COMPILER_OPTIONS, ModalityDecision,
                                         _f64, _modality_program, _pow2)
from repro.sharding.partition import (CLIENT_AXIS, client_mesh, client_spec,
                                      shard_rows, shard_slots)

__all__ = ["ShardedFederationState", "ShardedStore", "client_mesh",
           "sharded_local_learning", "aggregate_modality_sharded",
           "select_modalities_sharded"]


# ---------------------------------------------------------------------------
# sharded resident state
# ---------------------------------------------------------------------------

@dataclass
class _ShardedEncBucket(_EncoderBucket):
    """Engine bucket + the shard-major slot map. ``pairs[i]`` lives in
    padded row ``slots[i]``; ``size`` counts padded rows (G·D ≥ len(pairs))."""
    slots: List[int] = field(default_factory=list)
    size: int = 0


@dataclass
class _ShardedFusionBucket(_FusionBucket):
    slots: List[int] = field(default_factory=list)
    size: int = 0


def _row_gather(tree, idx):
    return jax.tree.map(lambda v: v[idx], tree)


def _row_scatter(tree, idx, sub):
    return jax.tree.map(lambda v, s: v.at[idx].set(s), tree, sub)


class ShardedStore(StateStore):
    """StateStore over shard-major padded buckets.

    Differences from the engine store: the zero-copy identity fast path
    keys on the *padded* bucket size (slot i ≠ index i once padding rows
    exist); gathers run as ONE jit'd program whose output lands on the
    mesh's first device (the cross-tier phases that consume subsets —
    predictions, fusion, Shapley, evaluation — are small and run fastest
    concentrated, instead of strewn across shards with per-op collectives);
    and scatters jit with ``out_shardings`` pinned back to
    ``P("clients")`` — an unpinned ``.at[idx].set`` output would silently
    de-shard the population."""

    def __init__(self, state: "ShardedFederationState"):
        super().__init__(state)
        mesh = state.mesh
        self._sharding = jax.sharding.NamedSharding(mesh, client_spec())
        self._replicated = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        self._dev0 = jax.sharding.SingleDeviceSharding(
            np.asarray(mesh.devices).flat[0])
        self._gather = jax.jit(_row_gather)
        self._scatter = jax.jit(_row_scatter, out_shardings=self._sharding)

    def _gather_to_dev0(self, params, idx):
        # one jit'd gather (not leaf-by-leaf eager dispatch), landed on the
        # first device so downstream consumers compile single-device
        return jax.device_put(self._gather(params, idx), self._dev0)

    def _scatter_rows(self, params, idx, sub):
        # jit rejects mixed input device sets: replicate the (dev0-committed)
        # subset onto the mesh before the pinned-output scatter
        sub = jax.device_put(sub, self._replicated)
        return self._scatter(params, idx, sub)

    def gather_encoders(self, pairs):
        bucket, idx = self._encoder_slots(pairs)
        if self._is_identity(idx, bucket.size):
            return bucket.params
        return self._gather_to_dev0(bucket.params, idx)

    def scatter_encoders(self, pairs, stacked):
        bucket, idx = self._encoder_slots(pairs)
        if self._is_identity(idx, bucket.size):
            bucket.params = shard_rows(stacked, self.state.mesh)
        else:
            bucket.params = self._scatter_rows(bucket.params, idx, stacked)

    def gather_fusion(self, clients):
        bucket, idx = self._fusion_slots(clients)
        if self._is_identity(idx, bucket.size):
            return bucket.params
        return self._gather_to_dev0(bucket.params, idx)

    def scatter_fusion(self, clients, stacked):
        bucket, idx = self._fusion_slots(clients)
        if self._is_identity(idx, bucket.size):
            bucket.params = shard_rows(stacked, self.state.mesh)
        else:
            bucket.params = self._scatter_rows(bucket.params, idx, stacked)


def _stack_padded(trees, slots: Sequence[int], size: int):
    """Stack pytrees into a [size, ...] stack at the given slots; unassigned
    slots are zero rows (masked to weight 0 by every consumer)."""
    idx = np.asarray(slots, np.int64)

    def leaf(*leaves):
        x = jnp.stack(leaves)
        if size == len(leaves) and np.array_equal(idx, np.arange(size)):
            return x
        return jnp.zeros((size,) + x.shape[1:], x.dtype).at[idx].set(x)

    return jax.tree.map(leaf, *trees)


@dataclass
class ShardedFederationState(FederationState):
    """FederationState whose resident stacks are sharded over a client mesh.

    The decision matrices (presence/sizes/recency/losses) stay host-side
    numpy exactly like the engine's — they are O(K·M) scalars consumed by
    the selection programs, which shard their own inputs — but the
    parameter buckets live shard-major padded on the mesh."""
    mesh: Optional[Mesh] = None
    shard_of: Optional[np.ndarray] = None      # [K] shard id per client row

    @classmethod
    def build_sharded(cls, clients, spec, qbits: int, *, mesh: Mesh,
                      shard_of: Optional[np.ndarray] = None
                      ) -> "ShardedFederationState":
        state = cls.build(clients, spec, qbits, stack=False)
        K = len(state.clients)
        D = mesh.shape[CLIENT_AXIS]
        if shard_of is None:
            shard_of = np.arange(K, dtype=np.int64) % D
        shard_of = np.asarray(shard_of, np.int64)
        if shard_of.shape != (K,) or (K and not
                                      (0 <= shard_of.min() and
                                       shard_of.max() < D)):
            raise ValueError(f"shard_of must map {K} clients into [0, {D})")
        state.mesh = mesh
        state.shard_of = shard_of
        state.store = ShardedStore(state)
        state._stack_population()
        return state

    def _stack_population(self) -> None:
        from repro.core.batched import _fusion_key
        D = self.mesh.shape[CLIENT_AXIS]
        enc_groups: Dict[Tuple, List[Tuple[int, str]]] = {}
        for k, c in enumerate(self.clients):
            for m in c.modality_names:
                key = (tuple(np.asarray(c.train.modalities[m]).shape[1:]),
                       c.spec.num_classes)
                enc_groups.setdefault(key, []).append((k, m))
        for b, key in enumerate(sorted(enc_groups, key=repr)):
            pairs = enc_groups[key]
            slots, size = shard_slots([self.shard_of[k] for k, _ in pairs], D)
            params = shard_rows(_stack_padded(
                [self.clients[k].encoders[m] for k, m in pairs],
                slots, size), self.mesh)
            self.enc_buckets[b] = _ShardedEncBucket(key, pairs, params,
                                                    slots=slots, size=size)
            for (k, m), s in zip(pairs, slots):
                self.enc_slot[(k, m)] = (b, s)
        fus_groups: Dict[Tuple, List[int]] = {}
        for k, c in enumerate(self.clients):
            fus_groups.setdefault(_fusion_key(c), []).append(k)
        for b, key in enumerate(sorted(fus_groups, key=repr)):
            rows = fus_groups[key]
            slots, size = shard_slots([self.shard_of[k] for k in rows], D)
            params = shard_rows(_stack_padded(
                [self.clients[k].fusion for k in rows], slots, size),
                self.mesh)
            self.fusion_buckets[b] = _ShardedFusionBucket(key, rows, params,
                                                          slots=slots,
                                                          size=size)
            for k, s in zip(rows, slots):
                self.fusion_slot[k] = (b, s)

    def write_back(self) -> None:
        # padded slot ids, not enumerate order (the engine's assumption)
        for bucket in self.enc_buckets.values():
            for (k, m), s in zip(bucket.pairs, bucket.slots):
                self.clients[k].encoders[m] = jax.tree.map(
                    lambda v: v[s], bucket.params)
        for bucket in self.fusion_buckets.values():
            for k, s in zip(bucket.rows, bucket.slots):
                self.clients[k].fusion = jax.tree.map(
                    lambda v: v[s], bucket.params)


# ---------------------------------------------------------------------------
# shard_map'ped local learning
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _epoch_program(mesh: Mesh, lr: float):
    """``masked_batched_epoch``'s body under ``shard_map``: each device runs
    the vmapped scan over its own rows — per-row math is lane-independent,
    so results match the engine's whole-bucket vmap."""
    def body(params, xs, ys, ws):
        def client_epoch(p, bx, by, bw):
            def step(pp, xyw):
                x, y, w = xyw
                loss, g = jax.value_and_grad(masked_encoder_loss)(pp, x, y, w)
                return jax.tree.map(lambda a, b: a - lr * b, pp, g), loss
            return jax.lax.scan(step, p, (bx, by, bw))
        return jax.vmap(client_epoch)(params, xs, ys, ws)

    spec = client_spec()
    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(spec, spec, spec, spec),
                             out_specs=(spec, spec)))


@functools.lru_cache(maxsize=None)
def _fused_round_program(mesh: Mesh, lr: float):
    """``fused_encoder_round``'s body under ``shard_map``: each device runs
    all E epochs over its own rows in ONE program, with the resident param
    shard donated (``donate_argnums``) so the bucket updates in place.
    Inputs carry an epoch axis — xs [size, E, S, B, ...] — and the program
    returns (params, final-epoch losses [size, S]), exactly E chained
    :func:`_epoch_program` launches in one dispatch."""
    def body(params, xs, ys, ws):
        def client_round(p, ex, ey, ew):
            def epoch(pp, xyw):
                def step(q, s):
                    x, y, w = s
                    loss, g = jax.value_and_grad(masked_encoder_loss)(
                        q, x, y, w)
                    return jax.tree.map(lambda a, b: a - lr * b, q, g), loss
                return jax.lax.scan(step, pp, xyw)
            pe, losses = jax.lax.scan(epoch, p, (ex, ey, ew))
            return pe, losses[-1]
        return jax.vmap(client_round)(params, xs, ys, ws)

    spec = client_spec()
    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(spec, spec, spec, spec),
                             out_specs=(spec, spec)), donate_argnums=(0,))


def _train_encoder_bucket(state: ShardedFederationState, bucket, plan_of,
                          cfg) -> None:
    """One resident bucket's encoder phase, full padded stack.

    Only clients in ``plan_of`` (this round's available cohort) get real
    sample masks; every other slot — absent client or padding — trains as
    an exact no-op and keeps its params bit-identical.
    ``cfg.train_impl="fused"`` dispatches one donated E-epoch program;
    ``"reference"`` keeps the per-epoch chain."""
    from repro.core.batched import num_steps, padded_perm_indices
    B, E = cfg.batch_size, cfg.local_epochs
    live = []                               # (slot, client, modality, plan)
    for (k, m), s in zip(bucket.pairs, bucket.slots):
        c = state.clients[k]
        p = plan_of.get(c.client_id)
        if p is not None:
            live.append((s, c, m, p))
    if not live:
        return
    if not E:
        for _, c, m, _ in live:
            c.losses[m] = 0.0
        return
    with telemetry.span("train.encoder", clients=len(live),
                        impl=getattr(cfg, "train_impl", "fused")):
        size = bucket.size
        feat = bucket.key[0]
        n_max = max(c.train.num_samples for _, c, _, _ in live)
        steps = max(num_steps(c.train.num_samples, B)
                    for _, c, _, _ in live)
        x = np.zeros((size, n_max) + tuple(feat), np.float32)
        y = np.zeros((size, n_max), np.int32)
        for s, c, m, _ in live:
            x[s] = c.padded_modality(c.train, m, n_max)
            y[s] = c.padded_labels(c.train, n_max)
        perms: List[np.ndarray] = [np.zeros(0, np.int64)] * size
        ns = [0] * size
        for s, c, _, _ in live:
            ns[s] = c.train.num_samples
        gather = np.arange(size)[:, None]
        sharding = jax.sharding.NamedSharding(state.mesh, client_spec())
        params, le = bucket.params, None
        if getattr(cfg, "train_impl", "fused") == "fused":
            idx_w = []
            for e in range(E):
                for s, _, m, p in live:
                    perms[s] = p.encoder_perms[m][e]
                idx_w.append(padded_perm_indices(perms, ns, steps, B))
            idx = np.stack([iw[0] for iw in idx_w], axis=1)  # [size, E, L]
            w = np.stack([iw[1] for iw in idx_w], axis=1)
            xe = x[gather[:, None], idx].reshape(size, E, steps, B,
                                                 *x.shape[2:])
            ye = y[gather[:, None], idx].reshape(size, E, steps, B)
            ws = w.reshape(size, E, steps, B)
            program = _fused_round_program(state.mesh, float(cfg.lr_encoder))
            hostsync.record_dispatch()
            # the resident shard is donated: the bucket updates in place
            # and the old `params` buffers are consumed by the dispatch
            params, le = program(params,
                                 jax.device_put(xe, sharding),
                                 jax.device_put(ye, sharding),
                                 jax.device_put(ws, sharding))
        else:
            program = _epoch_program(state.mesh, float(cfg.lr_encoder))
            for e in range(E):
                for s, _, m, p in live:
                    perms[s] = p.encoder_perms[m][e]
                idx, w = padded_perm_indices(perms, ns, steps, B)
                xe = x[gather, idx].reshape(size, steps, B, *x.shape[2:])
                ye = y[gather, idx].reshape(size, steps, B)
                ws = w.reshape(size, steps, B)
                hostsync.record_dispatch()
                params, le = program(params,
                                     jax.device_put(xe, sharding),
                                     jax.device_put(ye, sharding),
                                     jax.device_put(ws, sharding))
        bucket.params = params
        last = hostsync.fetch(le).astype(np.float64)  # one fetch/bucket
        for s, c, m, _ in live:
            c.losses[m] = float(last[s, :num_steps(c.train.num_samples,
                                                   B)].mean())


def sharded_local_learning(avail, cfg, rng: np.random.Generator,
                           state: ShardedFederationState,
                           cache=None) -> None:
    """Algorithm 1's Local Learning on the sharded population.

    Draws the loop-order permutation plan first (the backends' RNG-parity
    contract), trains every encoder bucket's full padded stack under
    ``shard_map``, then runs Stage-#1 fusion through the shared batched
    path against the sharded store (fusion stacks are tiny; the gathers go
    through :class:`ShardedStore`)."""
    from repro.core.batched import (_fusion_buckets, plan_permutations,
                                    train_population_fusion)
    plans = plan_permutations(avail, cfg.local_epochs, rng)
    plan_of = {p.client.client_id: p for p in plans}
    for p in plans:
        p.client.losses = {}
    for b in sorted(state.enc_buckets):
        _train_encoder_bucket(state, state.enc_buckets[b], plan_of, cfg)
    for idxs in _fusion_buckets(avail, cfg.batch_size):
        train_population_fusion([avail[i] for i in idxs],
                                [plans[i].fusion_perms for i in idxs],
                                epochs=cfg.local_epochs, lr=cfg.lr_fusion,
                                batch_size=cfg.batch_size,
                                store=state.store,
                                train_impl=getattr(cfg, "train_impl",
                                                   "fused"),
                                cache=cache)


# ---------------------------------------------------------------------------
# Eq. 21 as a masked psum
# ---------------------------------------------------------------------------

def _psum_normalized(local, w):
    """Weighted contraction of one shard's rows + the global reduction:
    normalize by the cross-shard weight sum (engine guard: ``max(Σw,
    1e-12)``, so an all-zero shard — or round — yields zeros, not NaN)."""
    wsum = jax.lax.psum(jnp.sum(w), CLIENT_AXIS)
    wn = w / jnp.maximum(wsum, 1e-12)
    part = jax.tree.map(
        lambda x: jnp.einsum("k,k...->...", wn, x.astype(jnp.float32)),
        local)
    return jax.lax.psum(part, CLIENT_AXIS)


@functools.lru_cache(maxsize=None)
def _aggregate_program(mesh: Mesh):
    def body(stacked, w):
        return _psum_normalized(stacked, w.astype(jnp.float32))
    spec = client_spec()
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec),
                             out_specs=P()))


@functools.lru_cache(maxsize=None)
def _aggregate_quantized_program(mesh: Mesh, bits: int):
    """§4.10 uplink fused into the psum — reference impl: each shard
    quantizes its rows (per-row per-tensor ranges — codes are independent
    of shard layout; all-zero padding rows quantize safely under the
    zero-range guard), dequantizes, and contracts, and only the
    [leaf]-shaped partial sums cross shards."""
    def body(stacked, w):
        codes, scales, zeros = quantize_population(stacked, bits=bits)
        deq = jax.tree.map(
            lambda c, s, z: jax.vmap(dequantize_tensor)(c, s, z),
            codes, scales, zeros)
        return _psum_normalized(deq, w.astype(jnp.float32))
    spec = client_spec()
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec),
                             out_specs=P()))


@functools.lru_cache(maxsize=None)
def _aggregate_quantized_fused_program(mesh: Mesh, bits: int):
    """§4.10 uplink fused into the psum — ``repro.kernels.comm`` impl:
    each shard runs the one-pass quantizer (paired min/max ``lax.reduce``,
    bit-identical codes to ``quantize_population``) and contracts its raw
    codes with the affine applied to the reduced sums

        part = einsum(wn·s, codes) + Σ_local wn·z

    so the per-shard ``[rows, ...]`` dequantized stack of the reference
    body never materializes; only [leaf]-shaped partials cross shards (the
    psum adds the locally-weighted zero terms too). Wire packing applies
    at program *boundaries* — inside one shard program nothing leaves the
    device, so a pack/unpack round-trip would be pure overhead."""
    def body(stacked, w):
        w = w.astype(jnp.float32)
        wsum = jax.lax.psum(jnp.sum(w), CLIENT_AXIS)
        wn = w / jnp.maximum(wsum, 1e-12)

        def leaf(x):
            codes, s, z = _quantize_rows_fused(
                x.reshape(x.shape[0], -1), bits)
            part = (jnp.einsum("k,kn->n", wn * s,
                               codes.astype(jnp.float32))
                    + jnp.sum(wn * z))
            return part.reshape(x.shape[1:])

        return jax.lax.psum(jax.tree.map(leaf, stacked), CLIENT_AXIS)
    spec = client_spec()
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, spec),
                             out_specs=P()))


def aggregate_modality_sharded(state: ShardedFederationState,
                               clients, modality: str,
                               sample_counts: Sequence[int],
                               bits: int, *,
                               comm_impl: str = "fused") -> Dict:
    """One modality's Eq. 21 over the resident sharded bucket.

    Instead of gathering the selected rows (a cross-shard reshuffle every
    round), the *whole* bucket contracts under a [size] weight vector that
    is ``num_samples`` on this round's selected uploads and 0 elsewhere —
    unselected, unavailable, and padding rows all contribute exact zero
    terms to the psum. ``comm_impl`` picks the quantized-body flavor (the
    fused one never materializes a per-shard dequantized stack); what
    crosses shards is identical either way — D sets of [leaf]-shaped
    float32 partials — and is what :func:`~repro.core.hostsync.bytes_moved`
    accounts."""
    with telemetry.span("comm.aggregate", modality=modality,
                        clients=len(clients), bits=bits, impl=comm_impl):
        locs = [state.enc_slot[(state.row_of[c.client_id], modality)]
                for c in clients]
        bids = {b for b, _ in locs}
        assert len(bids) == 1, "uploads span shape-family buckets"
        bucket = state.enc_buckets[bids.pop()]
        w = np.zeros(bucket.size, np.float32)
        for (_, s), n in zip(locs, sample_counts):
            w[s] = float(n)
        wdev = jax.device_put(
            w, jax.sharding.NamedSharding(state.mesh, client_spec()))
        part_bytes = sum(
            int(np.prod(l.shape[1:], dtype=np.int64)) * 4
            for l in jax.tree_util.tree_leaves(bucket.params))
        hostsync.record_bytes(int(state.mesh.devices.size) * part_bytes)
        with telemetry.span("comm.reduce"):
            if bits >= 32:
                agg = _aggregate_program(state.mesh)(bucket.params, wdev)
            elif comm_impl == "fused":
                agg = _aggregate_quantized_fused_program(
                    state.mesh, int(bits))(bucket.params, wdev)
            else:
                agg = _aggregate_quantized_program(state.mesh, int(bits))(
                    bucket.params, wdev)
        ref = state.clients[state.row_of[clients[0].client_id]]\
            .encoders[modality]
        return jax.tree.map(lambda a, r: a.astype(r.dtype), agg, ref)


# ---------------------------------------------------------------------------
# shard_map'ped modality selection (Eqs. 12–16)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_modality_program(mesh: Mesh, rows_per_shard: int, M: int,
                              gamma: int, alpha_s: float, alpha_c: float,
                              alpha_r: float):
    """The engine's AOT modality program under ``shard_map``: every device
    ranks its own ``[rows_per_shard, M]`` candidate block (the math is
    row-wise — no collectives), compiled exactly like the engine's (f64,
    backend opt level 0) so outcomes stay bit-identical to numpy."""
    fn = functools.partial(_modality_program, gamma=gamma, alpha_s=alpha_s,
                           alpha_c=alpha_c, alpha_r=alpha_r)
    spec = client_spec()
    mapped = shard_map(fn, mesh=mesh,
                       in_specs=(spec, spec, spec, spec, spec, P()),
                       out_specs=(spec, spec, spec, spec))
    D = mesh.shape[CLIENT_AXIS]
    kp = rows_per_shard * D
    with enable_x64():
        lowered = jax.jit(mapped).lower(
            _f64(kp, M), _f64(kp, M), _f64(kp, M),
            jax.ShapeDtypeStruct((kp, M), jnp.bool_),
            jax.ShapeDtypeStruct((kp, M), jnp.int64), _f64())
        return lowered.compile(compiler_options=_COMPILER_OPTIONS)


def select_modalities_sharded(phi, sizes, recency, presence, name_rank,
                              shard_ids, mesh: Mesh, *, t: int, gamma: int,
                              alpha_s: float, alpha_c: float, alpha_r: float
                              ) -> ModalityDecision:
    """Population top-γ (Eqs. 12–16) with the candidate block sharded over
    the client mesh — outcome-identical to
    ``selection_engine.select_modalities_arrays`` row for row.

    Candidates permute to the shard-major layout (each shard's block padded
    to a shared power-of-two row count, padding rows absent), one
    ``shard_map`` program ranks all blocks, and the same three host fetches
    as the engine bring back mask/order/counts — host syncs stay O(1) in
    mesh size."""
    phi = np.asarray(phi, np.float64)
    n, M = phi.shape
    D = mesh.shape[CLIENT_AXIS]
    # per-shard block = pow2 of the largest shard group, so a run with §4.9
    # availability sees O(log K) distinct shapes (the engine's pow2 rule)
    counts = np.bincount(np.asarray(shard_ids, np.int64), minlength=D) \
        if n else np.zeros(D, np.int64)
    rows = _pow2(int(counts.max()) if n else 1)
    kp = rows * D
    fill = np.zeros(D, np.int64)
    pos = np.zeros(n, np.int64)
    for i, d in enumerate(np.asarray(shard_ids, np.int64)):
        pos[i] = d * rows + fill[d]
        fill[d] += 1
    pphi = np.zeros((kp, M), np.float64)
    psizes = np.zeros((kp, M), np.float64)
    prec = np.zeros((kp, M), np.float64)
    ppres = np.zeros((kp, M), bool)
    pphi[pos] = phi
    psizes[pos] = np.asarray(sizes, np.float64)
    prec[pos] = np.asarray(recency, np.float64)
    ppres[pos] = np.asarray(presence, bool)
    prank = np.broadcast_to(np.asarray(name_rank, np.int64),
                            (kp, M)).copy()
    comp = _sharded_modality_program(mesh, rows, M, int(gamma),
                                     float(alpha_s), float(alpha_c),
                                     float(alpha_r))
    with enable_x64():
        mask, order, cnts, _ = comp(pphi, psizes, prec, ppres, prank,
                                    np.float64(t))
    return ModalityDecision(hostsync.fetch(mask)[pos],
                            hostsync.fetch(order)[pos],
                            hostsync.fetch(cnts)[pos])

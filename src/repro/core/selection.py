"""Joint modality and client selection (§3.2, §3.3) — Eqs. (9)–(20).

Pure-numpy decision logic (runs on the simulation host; the tensors involved
are M- and K-length vectors). The composite priority is

    P_m = α_s · φ̃_m + α_c · (1 − |θ̃_m|) + α_r · T̃_m            (Eq. 13)

with per-criterion min-max normalization (Eq. 12), top-γ modality selection
(Eqs. 14–16), and server-side top-⌈δK⌉ lowest-loss client selection
(Eqs. 17–19). ``joint_select`` composes the two (Eq. 20).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def minmax_normalize(x: np.ndarray) -> np.ndarray:
    """Eq. 12 normalization; a constant vector maps to all-zeros."""
    x = np.asarray(x, np.float64)
    lo, hi = np.min(x), np.max(x)
    if hi - lo < 1e-12:
        return np.zeros_like(x)
    return (x - lo) / (hi - lo)


@dataclass
class RecencyTracker:
    """T_m^k = t − t_m^k − 1 (Eq. 11), per client.

    ``last_upload[m]`` is the round at which modality m was last uploaded
    (−1 = never, so T = t at round t: maximal staleness)."""
    modality_names: Tuple[str, ...]
    last_upload: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        for m in self.modality_names:
            self.last_upload.setdefault(m, -1)

    def recency(self, m: str, t: int) -> int:
        return t - self.last_upload[m] - 1

    def recency_vector(self, names: Sequence[str], t: int) -> np.ndarray:
        return np.array([self.recency(m, t) for m in names], np.float64)

    def mark_uploaded(self, names: Sequence[str], t: int) -> None:
        for m in names:
            self.last_upload[m] = t


def modality_priority(shapley: np.ndarray, sizes: np.ndarray,
                      recency: np.ndarray, t: int,
                      alpha_s: float, alpha_c: float, alpha_r: float
                      ) -> np.ndarray:
    """Composite priority P_m (Eq. 13) from raw criteria.

    shapley — φ_m (absolute values are taken here, Eq. 9)
    sizes   — |θ_m| in bytes (Eq. 10)
    recency — T_m (Eq. 11); normalized by the current round t (Eq. 12)
    """
    phi_n = minmax_normalize(np.abs(shapley))
    size_n = minmax_normalize(sizes)
    rec_n = np.asarray(recency, np.float64) / max(t, 1)
    return alpha_s * phi_n + alpha_c * (1.0 - size_n) + alpha_r * rec_n


def select_top_gamma(priority: np.ndarray, names: Sequence[str],
                     gamma: int) -> List[str]:
    """Top-γ priority modalities (Eqs. 14–15). Deterministic tie-break by
    descending priority then name order (not input order)."""
    gamma = min(gamma, len(names))
    order = sorted(range(len(names)),
                   key=lambda i: (-float(priority[i]), names[i]))
    return [names[i] for i in order[:gamma]]


def select_clients(losses: Dict[int, float], delta: float,
                   *, criterion: str = "low_loss",
                   recency: Optional[Dict[int, int]] = None,
                   loss_weight: float = 1.0,
                   rng: Optional[np.random.Generator] = None) -> List[int]:
    """Server-side client selection (Eqs. 17–19).

    losses   — client id -> scalar loss summarizing its selected encoders
    delta    — participation ratio; selects ⌈δK⌉ clients
    criterion — 'low_loss' (paper's choice) | 'high_loss' | 'random'
                | 'loss_recency' (§4.8 hybrid; needs ``recency`` and
                ``loss_weight`` w: score = w·loss_rank + (1−w)·recency_rank)

    'random' requires an explicit ``rng`` (the caller's round generator):
    a silent shared default would make every "random" run draw the same
    clients, so two nominally independent runs would collide.
    """
    ids = sorted(losses)
    k = len(ids)
    n_sel = max(1, math.ceil(delta * k))
    if criterion == "random":
        if rng is None:
            raise ValueError("criterion='random' needs an explicit rng "
                             "(pass the round's np.random.Generator)")
        return sorted(rng.choice(ids, size=n_sel, replace=False).tolist())
    vals = np.array([losses[i] for i in ids], np.float64)
    if criterion == "low_loss":
        order = np.argsort(vals, kind="stable")
    elif criterion == "high_loss":
        order = np.argsort(-vals, kind="stable")
    elif criterion == "loss_recency":
        rec = np.array([(recency or {}).get(i, 0) for i in ids], np.float64)
        loss_rank = minmax_normalize(vals)          # lower better
        rec_rank = 1.0 - minmax_normalize(rec)      # staler better
        score = loss_weight * loss_rank + (1.0 - loss_weight) * rec_rank
        order = np.argsort(score, kind="stable")
    else:
        raise ValueError(criterion)
    return sorted(int(ids[i]) for i in order[:n_sel])


@dataclass
class SelectionResult:
    """Outcome of one round's joint selection (Eq. 20)."""
    # client id -> modality names that client would upload (top-γ, Eq. 16)
    modality_choices: Dict[int, List[str]]
    # server-selected client ids (Eq. 19)
    selected_clients: List[int]

    @property
    def uploads(self) -> List[Tuple[int, str]]:
        """(client, modality) pairs actually communicated (Θ_γ^δ, Eq. 20)."""
        return [(k, m) for k in self.selected_clients
                for m in self.modality_choices[k]]


def joint_select(per_client_priorities: Dict[int, Tuple[Sequence[str], np.ndarray]],
                 per_client_losses: Dict[int, float],
                 *, gamma: int, delta: float,
                 client_criterion: str = "low_loss",
                 modality_random: bool = False,
                 client_recency: Optional[Dict[int, int]] = None,
                 loss_weight: float = 1.0,
                 rng: Optional[np.random.Generator] = None) -> SelectionResult:
    """Sequential joint selection (§3.3): modalities first, then clients.

    The round rng threads through to every random draw; it is required
    whenever a draw actually happens (``modality_random`` or
    ``client_criterion='random'``)."""
    if modality_random and rng is None:
        raise ValueError("modality_random=True needs an explicit rng "
                         "(pass the round's np.random.Generator)")
    choices: Dict[int, List[str]] = {}
    for cid, (names, prio) in per_client_priorities.items():
        if modality_random:
            g = min(gamma, len(names))
            choices[cid] = sorted(rng.choice(list(names), size=g,
                                             replace=False).tolist())
        else:
            choices[cid] = select_top_gamma(np.asarray(prio), list(names), gamma)
    selected = select_clients(per_client_losses, delta,
                              criterion=client_criterion,
                              recency=client_recency,
                              loss_weight=loss_weight, rng=rng)
    return SelectionResult(choices, selected)

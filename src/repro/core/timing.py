"""Virtual-time models for the asynchronous federation runtime.

The paper's whole premise is communication limitation under heterogeneous
networks (§4.9 availability, Table 7's transmission-time model), yet a
synchronous simulator collapses *when* things happen into a per-round
Bernoulli coin flip. This module provides the three timing ingredients the
event-driven scheduler (``repro.core.scheduler``) composes into a virtual
clock:

- **Compute-time model** (:class:`ComputeModel`): a client's local-learning
  time is its SGD step count (E epochs × ⌈n/B⌉ steps per modality, plus the
  Stage-#1 fusion pass) times a per-step cost scaled by the modality's
  feature volume — i.e. batches × per-step cost from the client's shape
  family, exactly the quantity the batched simulator schedules. Per-client
  straggler multipliers (:func:`sample_straggler_multipliers`) model slow
  devices.
- **Uplink-time model**: exact ledger wire bytes ÷ a per-client sampled
  bandwidth. Heterogeneous links come from
  :meth:`repro.core.aggregation.TransportModel.sample_links` (log-normal
  spread around the IoT/ICI presets); the scheduler charges each upload
  ``link_k.seconds(wire_bytes)``.
- **Availability traces**: per-round boolean masks over the population.
  :class:`BernoulliTrace` reproduces the historical §4.9 coin flip
  draw-for-draw (vectorized ``rng.random(K)`` consumes the generator
  identically to K sequential scalar draws, which the cross-backend parity
  tests pin); :class:`MarkovTrace` is two-state Gilbert churn (on→off with
  ``p_drop``, off→on with ``p_join``), the standard bursty-availability
  model. Deadline-based straggler *dropping* is not a trace — it is the
  scheduler's reporting deadline (``MFedMCConfig.deadline_s``).

Traces are stateful (Markov keeps per-client on/off state), so each run
materializes a fresh one via :func:`resolve_trace`; all backends step the
trace with the shared round generator, preserving RNG parity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.aggregation import ICI_LINK, IOT_UPLINK


# ---------------------------------------------------------------------------
# availability traces (replace the inline §4.9 coin flip)
# ---------------------------------------------------------------------------

@dataclass
class BernoulliTrace:
    """IID per-round availability — the historical §4.9 model.

    ``rate >= 1`` never touches the generator (everyone is available);
    otherwise one uniform per client per round, in client order — exactly
    the draws the pre-runtime inline coin flip made."""
    rate: float = 1.0

    def step(self, rng: np.random.Generator, k: int) -> np.ndarray:
        if self.rate >= 1.0:
            return np.ones(k, bool)
        return rng.random(k) < self.rate

    def describe(self) -> str:
        return f"bernoulli:{self.rate:g}"


@dataclass
class MarkovTrace:
    """Two-state Gilbert on/off churn, independently per client.

    An *on* client drops with ``p_drop``; an *off* client rejoins with
    ``p_join`` (stationary availability p_join / (p_join + p_drop), mean
    off-burst length 1/p_join rounds — bursty churn a Bernoulli rate of the
    same mean cannot express). The first step is the cold start: everyone
    on, no draws; each later step consumes K uniforms in client order."""
    p_drop: float = 0.2
    p_join: float = 0.5
    state: Optional[np.ndarray] = None      # [K] bool; None until first step

    def step(self, rng: np.random.Generator, k: int) -> np.ndarray:
        if self.state is None:
            self.state = np.ones(k, bool)
            return self.state.copy()
        u = rng.random(k)
        self.state = np.where(self.state, u >= self.p_drop, u < self.p_join)
        return self.state.copy()

    def describe(self) -> str:
        return f"markov:{self.p_drop:g},{self.p_join:g}"


TraceLike = Union[None, float, str, BernoulliTrace, MarkovTrace]


def make_trace(spec: TraceLike) -> Union[BernoulliTrace, MarkovTrace]:
    """Build a fresh availability trace from a spec.

    ``None`` → always available; a float → :class:`BernoulliTrace`; strings:
    ``"always"``, ``"bernoulli:RATE"``, ``"markov:P_DROP,P_JOIN"``. Trace
    *objects* contribute only their parameters — the returned trace always
    starts from the cold-start state, so a config holding a `MarkovTrace`
    cannot leak one run's terminal churn state into the next."""
    if spec is None:
        return BernoulliTrace(1.0)
    if isinstance(spec, BernoulliTrace):
        return BernoulliTrace(spec.rate)
    if isinstance(spec, MarkovTrace):
        return MarkovTrace(spec.p_drop, spec.p_join)
    if isinstance(spec, (int, float)):
        return BernoulliTrace(float(spec))
    if isinstance(spec, str):
        name, _, arg = spec.partition(":")
        if name == "always":
            return BernoulliTrace(1.0)
        if name == "bernoulli":
            return BernoulliTrace(float(arg))
        if name == "markov":
            parts = [float(x) for x in arg.split(",")]
            if len(parts) != 2:
                raise ValueError(
                    f"markov trace needs 'markov:p_drop,p_join', got {spec!r}")
            return MarkovTrace(*parts)
        raise ValueError(f"unknown availability trace {spec!r}")
    raise TypeError(f"cannot build a trace from {type(spec).__name__}")


def resolve_trace(cfg) -> Union[BernoulliTrace, MarkovTrace]:
    """The run's availability trace: ``cfg.availability_trace`` if set,
    else the historical Bernoulli ``cfg.availability`` rate."""
    spec = getattr(cfg, "availability_trace", None)
    if spec is None:
        spec = getattr(cfg, "availability", 1.0)
    return make_trace(spec)


# ---------------------------------------------------------------------------
# compute-time model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ComputeModel:
    """Local-learning wall time as step count × per-step cost.

    The per-step cost scales with the modality's per-sample feature volume
    relative to ``ref_elements`` (a [128, 6] IMU window ≈ 768 elements costs
    about ``sec_per_step``; an eye-tracking [128, 2] stream is ~3× cheaper),
    so a client's compute time comes from its *shape family* — the same key
    the batched simulator buckets by. Stage-#1 fusion adds
    ``fusion_factor × sec_per_step`` per step (the fusion MLP consumes [M, C]
    predictions — small next to a trunk forward+backward)."""
    sec_per_step: float = 1e-3
    ref_elements: float = 768.0
    fusion_factor: float = 0.25

    def encoder_step_seconds(self, feature_shape: Sequence[int]) -> float:
        vol = float(np.prod(feature_shape)) if len(tuple(feature_shape)) \
            else 1.0
        return self.sec_per_step * max(vol, 1.0) / self.ref_elements

    def local_seconds(self, client, *, epochs: int, batch_size: int,
                      multiplier: float = 1.0) -> float:
        """One Local Learning phase: E epochs over every owned modality
        encoder plus the Stage-#1 fusion epochs, times the client's
        straggler multiplier."""
        from repro.core.batched import num_steps
        n = client.train.num_samples
        steps = num_steps(n, batch_size)
        total = 0.0
        for m in client.modality_names:
            shape = np.asarray(client.train.modalities[m]).shape[1:]
            total += epochs * steps * self.encoder_step_seconds(shape)
        total += epochs * steps * self.sec_per_step * self.fusion_factor
        return multiplier * total


def sample_straggler_multipliers(rng: np.random.Generator, k: int,
                                 fraction: float = 0.0,
                                 factor: float = 10.0) -> np.ndarray:
    """[K] per-client compute multipliers: ⌈fraction·K⌉ clients run
    ``factor×`` slower (uniformly drawn without replacement), the rest 1×.

    Timing randomness must come from a generator *separate* from the round
    rng — timing draws never perturb training/selection streams, which is
    what keeps the degenerate async config bit-comparable to the sync
    engine."""
    mult = np.ones(k, np.float64)
    if fraction > 0.0 and k > 0:
        n = min(k, int(np.ceil(fraction * k)))
        idx = rng.choice(k, size=n, replace=False)
        mult[idx] = factor
    return mult


LINK_PRESETS = {"iot": IOT_UPLINK, "ici": ICI_LINK}


def resolve_links(cfg, rng: np.random.Generator, k: int) -> list:
    """Per-client uplink transports for a run: the ``cfg.link_preset``
    base model, spread log-normally by ``cfg.link_sigma`` (0 = one shared
    link, the historical Table 7 model)."""
    preset = getattr(cfg, "link_preset", "iot")
    if preset not in LINK_PRESETS:
        raise ValueError(f"unknown link_preset {preset!r}; "
                         f"choose from {sorted(LINK_PRESETS)}")
    base = LINK_PRESETS[preset]
    sigma = getattr(cfg, "link_sigma", 0.0)
    if sigma <= 0.0:
        return [base] * k
    return base.sample_links(rng, k, sigma=sigma)

"""Arrayized federation state — the population's round-persistent tensors.

Pre-refactor, the batched backend restacked and unstacked ``Client`` pytrees
every phase of every round: encoder stacks for training, fresh stacks for
predictions, fusion stacks for Stage-#1/#2, upload stacks for Eq. 21 — each
a flurry of per-client device ops and host dict churn. ``FederationState``
keeps the population **resident**:

- encoders live in per-shape-family stacked pytrees (one ``[G, ...]`` array
  per leaf per bucket); training/prediction/aggregation *gather* rows and
  training/deployment *scatter* them back — device-side index ops, never a
  per-client restack;
- fusion modules live in per-fusion-bucket stacks the same way;
- recency is the ``[K, M]`` last-upload matrix (Eq. 11) updated functionally
  each round (mirrored into the per-client ``RecencyTracker``s so
  checkpointing keeps working);
- per-modality losses, exact wire sizes at the run's uplink precision, the
  presence mask, and the lexicographic name-rank vector are ``[K, M]`` /
  ``[M]`` arrays feeding ``repro.core.selection_engine`` directly.

The **param-store** protocol (:class:`ClientStore` / :class:`StateStore`)
lets ``repro.core.batched`` run one training codepath against either layout:
``ClientStore`` reads/writes ``Client`` objects (Tier 2's historical
behavior, kept as the benchmark baseline), ``StateStore`` gathers/scatters
the resident buckets (``backend="engine"``). ``Client`` objects go stale
during an engine run; :meth:`FederationState.write_back` restores them once
at the end (encoders, fusion, recency already mirrored).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoders as enc
from repro.core.aggregation import stack_uploads
from repro.core.client import Client
from repro.core.selection_engine import lexicographic_rank


class ClientStore:
    """Param store over ``Client`` objects — Tier 2's stacking behavior."""

    def gather_encoders(self, pairs: Sequence[Tuple[Client, str]]):
        return stack_uploads([c.encoders[m] for c, m in pairs])

    def scatter_encoders(self, pairs: Sequence[Tuple[Client, str]],
                         stacked) -> None:
        for j, (c, m) in enumerate(pairs):
            c.encoders[m] = jax.tree.map(lambda v: v[j], stacked)

    def gather_fusion(self, clients: Sequence[Client]):
        return stack_uploads([c.fusion for c in clients])

    def scatter_fusion(self, clients: Sequence[Client], stacked) -> None:
        for j, c in enumerate(clients):
            c.fusion = jax.tree.map(lambda v: v[j], stacked)


@dataclass
class _EncoderBucket:
    """One shape family's resident stack: every (client, modality) pair with
    this (feature shape, class count) occupies one row of each leaf."""
    key: Tuple
    pairs: List[Tuple[int, str]]            # (row, modality name) per slot
    params: Dict                            # pytree, leaves [G, ...]


@dataclass
class _FusionBucket:
    key: Tuple
    rows: List[int]
    params: Dict                            # pytree, leaves [G, ...]


class StateStore(ClientStore):
    """Param store over a :class:`FederationState` — device gather/scatter
    against the resident buckets instead of per-client restacks."""

    def __init__(self, state: "FederationState"):
        self.state = state

    @staticmethod
    def _is_identity(idx: np.ndarray, bucket_size: int) -> bool:
        return len(idx) == bucket_size and \
            np.array_equal(idx, np.arange(bucket_size, dtype=idx.dtype))

    def _encoder_slots(self, pairs):
        st = self.state
        locs = [st.enc_slot[(st.row_of[c.client_id], m)] for c, m in pairs]
        bids = {b for b, _ in locs}
        assert len(bids) == 1, "pairs span shape-family buckets"
        bucket = st.enc_buckets[bids.pop()]
        return bucket, np.array([i for _, i in locs], np.int32)

    def gather_encoders(self, pairs):
        bucket, idx = self._encoder_slots(pairs)
        if self._is_identity(idx, len(bucket.pairs)):
            return bucket.params        # whole bucket, in order: no copy
        return jax.tree.map(lambda v: v[idx], bucket.params)

    def scatter_encoders(self, pairs, stacked):
        bucket, idx = self._encoder_slots(pairs)
        if self._is_identity(idx, len(bucket.pairs)):
            bucket.params = stacked
            return
        bucket.params = jax.tree.map(lambda v, s: v.at[idx].set(s),
                                     bucket.params, stacked)

    def _fusion_slots(self, clients):
        st = self.state
        locs = [st.fusion_slot[st.row_of[c.client_id]] for c in clients]
        bids = {b for b, _ in locs}
        assert len(bids) == 1, "clients span fusion buckets"
        bucket = st.fusion_buckets[bids.pop()]
        return bucket, np.array([i for _, i in locs], np.int32)

    def gather_fusion(self, clients):
        bucket, idx = self._fusion_slots(clients)
        if self._is_identity(idx, len(bucket.rows)):
            return bucket.params
        return jax.tree.map(lambda v: v[idx], bucket.params)

    def scatter_fusion(self, clients, stacked):
        bucket, idx = self._fusion_slots(clients)
        if self._is_identity(idx, len(bucket.rows)):
            bucket.params = stacked
            return
        bucket.params = jax.tree.map(lambda v, s: v.at[idx].set(s),
                                     bucket.params, stacked)


@dataclass
class FederationState:
    """The population's round-persistent arrays (see module docstring)."""
    clients: List[Client]
    modalities: Tuple[str, ...]             # global M axis, name-sorted
    row_of: Dict[int, int]                  # client id -> row
    mod_index: Dict[str, int]               # modality name -> column
    name_rank: np.ndarray                   # [M] lexicographic ranks
    presence: np.ndarray                    # [K, M] bool — owned modalities
    sizes: np.ndarray                       # [K, M] f64 wire bytes @ qbits
    last_upload: np.ndarray                 # [K, M] i64, Eq. 11 (-1 = never)
    losses: np.ndarray                      # [K, M] f64 per-modality ℓ_m^k
    enc_buckets: Dict[int, _EncoderBucket] = field(default_factory=dict)
    enc_slot: Dict[Tuple[int, str], Tuple[int, int]] = field(
        default_factory=dict)               # (row, m) -> (bucket, slot)
    fusion_buckets: Dict[int, _FusionBucket] = field(default_factory=dict)
    fusion_slot: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    store: StateStore = field(init=False)
    # --- virtual-time runtime state (backend="async") ------------------
    # last_upload is Eq. 11 in *cycle* indices; these three mirror it on
    # the scheduler's virtual clock so recency and the selection engine can
    # consume simulated time instead of round counters.
    model_version: np.ndarray = field(init=False)   # [K] i64 global version
    arrival_time: np.ndarray = field(init=False)    # [K] f64 last arrival
    last_upload_time: np.ndarray = field(init=False)  # [K, M] f64 (-inf)

    def __post_init__(self):
        self.store = StateStore(self)
        K, M = self.presence.shape
        self.model_version = np.zeros(K, np.int64)
        self.arrival_time = np.full(K, -np.inf, np.float64)
        self.last_upload_time = np.full((K, M), -np.inf, np.float64)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, clients: Sequence[Client], spec, qbits: int,
              stack: bool = True) -> "FederationState":
        """``stack=False`` builds only the decision-layer arrays (recency,
        sizes, presence, losses) — what the loop/batched backends need —
        and skips making the parameters resident."""
        modalities = tuple(sorted(spec.modality_names))
        mod_index = {m: i for i, m in enumerate(modalities)}
        K, M = len(clients), len(modalities)
        presence = np.zeros((K, M), bool)
        sizes = np.zeros((K, M), np.float64)
        last_upload = np.full((K, M), -1, np.int64)
        losses = np.full((K, M), np.inf, np.float64)
        for k, c in enumerate(clients):
            for m in c.modality_names:
                mi = mod_index[m]
                presence[k, mi] = True
                # Eq. 10's cost criterion: exact compressed wire bytes at
                # the run's uplink precision (shape-only -> constant per run)
                sizes[k, mi] = enc.encoder_bytes(c.encoders[m], qbits)
                last_upload[k, mi] = c.recency.last_upload.get(m, -1)
        state = cls(list(clients), modalities, {c.client_id: k
                    for k, c in enumerate(clients)}, mod_index,
                    lexicographic_rank(modalities), presence, sizes,
                    last_upload, losses)
        if stack:
            state._stack_population()
        return state

    def _stack_population(self) -> None:
        from repro.core.batched import _fusion_key
        enc_groups: Dict[Tuple, List[Tuple[int, str]]] = {}
        for k, c in enumerate(self.clients):
            for m in c.modality_names:
                key = (tuple(np.asarray(c.train.modalities[m]).shape[1:]),
                       c.spec.num_classes)
                enc_groups.setdefault(key, []).append((k, m))
        for b, key in enumerate(sorted(enc_groups, key=repr)):
            pairs = enc_groups[key]
            params = stack_uploads(
                [self.clients[k].encoders[m] for k, m in pairs])
            self.enc_buckets[b] = _EncoderBucket(key, pairs, params)
            for i, (k, m) in enumerate(pairs):
                self.enc_slot[(k, m)] = (b, i)
        fus_groups: Dict[Tuple, List[int]] = {}
        for k, c in enumerate(self.clients):
            fus_groups.setdefault(_fusion_key(c), []).append(k)
        for b, key in enumerate(sorted(fus_groups, key=repr)):
            rows = fus_groups[key]
            params = stack_uploads([self.clients[k].fusion for k in rows])
            self.fusion_buckets[b] = _FusionBucket(key, rows, params)
            for i, k in enumerate(rows):
                self.fusion_slot[k] = (b, i)

    # ------------------------------------------------------------------
    def recency_matrix(self, t: int) -> np.ndarray:
        """T_m^k = t − t_m^k − 1 (Eq. 11) for the whole population."""
        return (t - self.last_upload - 1).astype(np.float64)

    def mark_uploaded(self, upload_mask: np.ndarray, t: int) -> None:
        """Functional Eq. 11 update from this round's [K, M] upload mask."""
        self.last_upload = np.where(upload_mask, t, self.last_upload)

    def client_staleness(self, t: int) -> np.ndarray:
        """[K] rounds since each client's last upload of *any* modality —
        the §4.8 loss_recency criterion's per-client staleness."""
        last = np.where(self.presence, self.last_upload, -1).max(axis=1)
        return (t - 1 - last).astype(np.float64)

    # -- virtual-clock mirrors (backend="async") -----------------------
    def mark_uploaded_time(self, upload_mask: np.ndarray, now: float) -> None:
        """Stamp this flush's completed uploads on the virtual clock and
        refresh the per-client arrival times (the [K] column the async
        runtime's staleness/recency views read)."""
        self.last_upload_time = np.where(upload_mask, now,
                                         self.last_upload_time)
        arrived = upload_mask.any(axis=1)
        self.arrival_time = np.where(arrived, now, self.arrival_time)

    def recency_matrix_time(self, now: float, scale: float,
                            t: int) -> np.ndarray:
        """Eq. 11 on the virtual clock: elapsed seconds since each pair's
        last completed upload, expressed in units of ``scale`` (the mean
        cycle duration so far) so magnitudes stay comparable to the
        round-index recency Eq. 12 normalizes by t. Never-uploaded pairs
        get the round-mode maximum t (= t − (−1) − 1)."""
        rec = (now - self.last_upload_time) / max(scale, 1e-12)
        return np.where(np.isfinite(rec), rec, float(t)).astype(np.float64)

    def client_staleness_time(self, now: float, scale: float,
                              t: int) -> np.ndarray:
        """[K] per-client staleness on the virtual clock (loss_recency's
        time-mode criterion); never-arrived clients get the round-mode
        maximum t."""
        stale = (now - self.arrival_time) / max(scale, 1e-12)
        return np.where(np.isfinite(stale), stale, float(t)).astype(
            np.float64)

    def deploy_global(self, modality: str, rows: Sequence[int],
                      agg: Dict) -> None:
        """Local Deploying: broadcast one aggregated encoder into every
        given row's resident slot (device scatter)."""
        pairs = [(self.clients[k], modality) for k in rows]
        if not pairs:
            return
        n = len(pairs)
        stacked = jax.tree.map(
            lambda g: jnp.broadcast_to(g, (n,) + g.shape), agg)
        self.store.scatter_encoders(pairs, stacked)

    def write_back(self) -> None:
        """Unstack the resident population into the ``Client`` objects —
        once per run, not once per round."""
        for bucket in self.enc_buckets.values():
            for i, (k, m) in enumerate(bucket.pairs):
                self.clients[k].encoders[m] = jax.tree.map(
                    lambda v: v[i], bucket.params)
        for bucket in self.fusion_buckets.values():
            for i, k in enumerate(bucket.rows):
                self.clients[k].fusion = jax.tree.map(
                    lambda v: v[i], bucket.params)

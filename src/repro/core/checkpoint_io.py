"""Federation checkpointing: persist/restore the server's global encoder
bank + per-client recency state so a run can resume mid-federation.

The fusion modules are strictly local (never uploaded) and therefore NOT in
the server checkpoint — exactly the paper's privacy/personalization
boundary; resuming on a new client population re-personalizes from the
restored global encoders.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.checkpoint import load_pytree, save_pytree
from repro.core.client import Client


def save_federation(path: str, server_encoders: Dict[str, Dict],
                    clients: Optional[List[Client]] = None,
                    round_idx: int = 0) -> None:
    meta = {"round": round_idx,
            "modalities": sorted(server_encoders)}
    if clients is not None:
        meta["recency"] = {str(c.client_id): c.recency.last_upload
                           for c in clients}
    save_pytree(path, {"server": server_encoders}, meta=meta)


def load_federation(path: str, clients: Optional[List[Client]] = None
                    ) -> Tuple[Dict[str, Dict], int]:
    """Returns (server_encoders, round_idx); restores client recency and
    deploys the global encoders when ``clients`` is given."""
    flat, meta = load_pytree(path)
    server: Dict[str, Dict] = {}
    for key, arr in flat.items():
        parts = key.split("/")
        if parts[0] != "server":
            continue
        server.setdefault(parts[1], {})[parts[2]] = arr
    if clients is not None:
        rec = (meta or {}).get("recency", {})
        for c in clients:
            saved = rec.get(str(c.client_id))
            if saved:
                c.recency.last_upload.update(
                    {m: int(t) for m, t in saved.items()
                     if m in c.recency.last_upload})
            for m, enc in server.items():
                c.install_global(m, enc)
    return server, int((meta or {}).get("round", 0))

"""Server-side per-modality encoder aggregation (Eq. 21) and the
communication accounting / transport-time models.

Aggregation is sample-weighted FedAvg over the encoders actually received:

    θ_m ← Σ_k (|D_m^k| / Σ_j |D_m^j|) θ_m^k        (Eq. 21)

``aggregate_modality`` is a plain pytree convex combination; the sparse
cross-pod formulation used on the production mesh lives in
``repro.core.distributed``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoders import encoder_param_arrays


def aggregate_modality(encoders: Sequence[Dict],
                       sample_counts: Sequence[int]) -> Dict:
    """Weighted average of encoder pytrees (weights ∝ sample counts)."""
    assert encoders, "aggregate_modality needs at least one upload"
    w = np.asarray(sample_counts, np.float64)
    w = w / w.sum()
    arrays = [encoder_param_arrays(e) for e in encoders]
    return {k: jnp.asarray(sum(wi * a[k] for wi, a in zip(w, arrays)))
            for k in arrays[0]}


# ---------------------------------------------------------------------------
# communication accounting (paper §4.11 time model + datacenter ICI model)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TransportModel:
    """T_comm = bytes × protocol × fec / (bandwidth/8) — Table 7's model."""
    bandwidth_bps: float = 10e6     # 10 Mbps IoT uplink
    protocol_overhead: float = 1.2
    fec_overhead: float = 1.5

    def seconds(self, n_bytes: float) -> float:
        return (n_bytes * self.protocol_overhead * self.fec_overhead
                / (self.bandwidth_bps / 8.0))


IOT_UPLINK = TransportModel()
# datacenter cross-pod ICI: 50 GB/s/link, negligible protocol overhead
ICI_LINK = TransportModel(bandwidth_bps=50e9 * 8, protocol_overhead=1.0,
                          fec_overhead=1.0)


@dataclass
class CommLedger:
    """Cumulative upload accounting for one federation run."""
    uploaded_bytes: float = 0.0
    uploads: int = 0
    rounds: int = 0

    def record(self, n_bytes: float, n_uploads: int = 1) -> None:
        self.uploaded_bytes += n_bytes
        self.uploads += n_uploads

    @property
    def megabytes(self) -> float:
        return self.uploaded_bytes / 1e6

    def seconds(self, transport: TransportModel = IOT_UPLINK) -> float:
        return transport.seconds(self.uploaded_bytes)

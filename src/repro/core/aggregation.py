"""Server-side per-modality encoder aggregation (Eq. 21) and the
communication accounting / transport-time models.

Aggregation is sample-weighted FedAvg over the encoders actually received:

    θ_m ← Σ_k (|D_m^k| / Σ_j |D_m^j|) θ_m^k        (Eq. 21)

The reduction is device-resident: uploads stack on a leading K axis and one
jit'd ``einsum``-weighted contraction produces the aggregate — no per-key
Python loop, no per-leaf host round-trips. ``aggregate_quantized`` consumes
§4.10 quantized payloads (codes + per-client per-tensor scale/zero from
``repro.core.quantize``) directly, fusing dequantization into the same
program. The sparse cross-pod formulation used on the production mesh lives
in ``repro.core.distributed``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoders import encoder_param_arrays


def stack_uploads(encoders: Sequence[Dict]) -> Dict:
    """Stack upload pytrees on a leading K axis (the population layout)."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *encoders)


def pad_uploads_pow2(stacked, weights: jnp.ndarray, n: int):
    """Pad a stacked upload population (and its weight vector) to the next
    power of two with zero-weight slots.

    The jit'd aggregation/quantization programs then see O(log K) distinct
    shapes across a whole run instead of recompiling for every distinct
    upload count; zero weights contribute exactly 0 to the normalized
    reduction. Returns ``(stacked, weights, pad)`` where ``pad`` is the
    number of dummy slots appended (0 = unchanged) — callers that carry
    extra per-upload state (e.g. error-feedback residuals) pad it the same
    way with :func:`pad_axis0`."""
    kpad = 1 << max(n - 1, 0).bit_length()
    pad = kpad - n
    if pad:
        stacked = pad_axis0(stacked, pad)
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad,), weights.dtype)])
    return stacked, weights, pad


def pad_axis0(tree, pad: int):
    """Append ``pad`` zero rows along axis 0 of every leaf."""
    return jax.tree.map(
        lambda v: jnp.concatenate(
            [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)]), tree)


@jax.jit
def aggregate_stacked(stacked, weights: jnp.ndarray):
    """Eq. 21 as one jit'd weighted contraction over stacked ``[K, ...]``
    uploads: every leaf reduces with ``einsum('k,k...->...')`` under
    sum-normalized weights, preserving the leaf dtype."""
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)
    return jax.tree.map(
        lambda x: jnp.einsum("k,k...->...", w,
                             x.astype(jnp.float32)).astype(x.dtype),
        stacked)


@jax.jit
def aggregate_quantized(codes, scales, zeros, weights: jnp.ndarray):
    """Eq. 21 directly over a quantized population payload
    (``repro.core.quantize.quantize_population`` output: codes ``[K, ...]``,
    per-client per-tensor scales/zeros ``[K]``).

    The affine distributes over the weighted mean, so the reduction
    contracts the raw codes and applies scale/zero to the *reduced* sums:

        Σ_k wn_k·(c_k·s_k + z_k) = einsum(wn·s, c) + Σ_k wn_k·z_k

    — one einsum per leaf, no ``[K, ...]`` dequantized stack (the old
    ``vmap(dequantize_tensor)`` materialized one; its output is pinned as a
    regression oracle in ``tests/test_aggregation.py``)."""
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def leaf(c, s, z):
        return (jnp.einsum("k,k...->...", w * s.astype(jnp.float32),
                           c.astype(jnp.float32))
                + jnp.sum(w * z.astype(jnp.float32)))

    return jax.tree.map(leaf, codes, scales, zeros)


def aggregate_modality(encoders: Sequence[Dict],
                       sample_counts: Sequence[int]) -> Dict:
    """Weighted average of encoder pytrees (weights ∝ sample counts)."""
    assert encoders, "aggregate_modality needs at least one upload"
    arrays = [encoder_param_arrays(e) for e in encoders]
    return aggregate_stacked(stack_uploads(arrays),
                             jnp.asarray(sample_counts, jnp.float32))


# ---------------------------------------------------------------------------
# communication accounting (paper §4.11 time model + datacenter ICI model)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TransportModel:
    """T_comm = bytes × protocol × fec / (bandwidth/8) — Table 7's model."""
    bandwidth_bps: float = 10e6     # 10 Mbps IoT uplink
    protocol_overhead: float = 1.2
    fec_overhead: float = 1.5

    def seconds(self, n_bytes: float) -> float:
        return (n_bytes * self.protocol_overhead * self.fec_overhead
                / (self.bandwidth_bps / 8.0))

    def sample_links(self, rng: np.random.Generator, k: int,
                     sigma: float = 0.5) -> list["TransportModel"]:
        """K heterogeneous per-client links: bandwidth drawn log-normally
        around this preset (mean-preserving: ln-mean −σ²/2), protocol/FEC
        overheads shared. σ≈0.5 spans roughly a 4× p10–p90 spread — the
        uplink diversity a single shared link (the historical Table 7
        model) cannot express."""
        mult = rng.lognormal(-0.5 * sigma * sigma, sigma, k)
        return [TransportModel(self.bandwidth_bps * float(m),
                               self.protocol_overhead, self.fec_overhead)
                for m in mult]


IOT_UPLINK = TransportModel()
# datacenter cross-pod ICI: 50 GB/s/link, negligible protocol overhead
ICI_LINK = TransportModel(bandwidth_bps=50e9 * 8, protocol_overhead=1.0,
                          fec_overhead=1.0)


@dataclass
class CommLedger:
    """Cumulative upload accounting for one federation run.

    Byte counts are exact-to-the-wire: callers record what actually ships
    (``repro.core.quantize.tensor_wire_bytes`` semantics — bit-packed code
    buffers in their smallest sufficient dtype plus per-tensor scale/zero
    metadata), and the optional ``modality`` tag keeps a per-modality
    compressed-uplink breakdown."""
    uploaded_bytes: float = 0.0
    uploads: int = 0
    rounds: int = 0
    by_modality: Dict[str, float] = field(default_factory=dict)

    def record(self, n_bytes: float, n_uploads: int = 1,
               modality: Optional[str] = None) -> None:
        self.uploaded_bytes += n_bytes
        self.uploads += n_uploads
        if modality is not None:
            self.by_modality[modality] = \
                self.by_modality.get(modality, 0.0) + n_bytes

    @property
    def megabytes(self) -> float:
        return self.uploaded_bytes / 1e6

    def seconds(self, transport: TransportModel = IOT_UPLINK) -> float:
        return transport.seconds(self.uploaded_bytes)

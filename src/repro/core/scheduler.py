"""Event-driven virtual-time federation runtime (``backend="async"``).

The synchronous backends collapse a round into an instantaneous barrier:
every available client trains, uploads, and aggregates "at once", and §4.9
availability is a per-round coin flip. This module gives the simulator a
clock. Each cycle is simulated as a stream of events on a heap:

    DISPATCH(k)     server hands client k the current global encoders and
                    it starts Local Learning (implicit at the cycle start
                    τ — only completion events need heap scheduling)
    LOCAL_DONE(k)   k finishes E·⌈n/B⌉ SGD steps per owned modality plus
                    Stage-#1 fusion — τ + T_comp(k), where T_comp comes
                    from the client's shape family and its straggler
                    multiplier (``repro.core.timing.ComputeModel``)
    UPLOAD_DONE(k)  k's selected encoders finish transmitting — LOCAL_DONE
                    + exact ledger wire bytes ÷ k's sampled link bandwidth
                    (``TransportModel.sample_links``)

Events pop in deterministic ``(time, kind, client id)`` order. The server
runs **staleness-aware buffered aggregation**: arrivals accumulate in a
buffer that flushes every ``buffer_size`` client arrivals and once at cycle
end. Each flush runs the existing stacked Eq. 21 path
(``aggregate_uploads`` → ``aggregate_stacked`` / ``aggregate_quantized``)
over its buffer with per-upload weight
``n_k · staleness_discount^staleness`` — staleness counts the server
versions (flushes) that landed between the client's dispatch and its
arrival — and merges into the cycle's running weighted mean, so the
cycle's final global encoder is the staleness-discounted Eq. 21 average
over *all* of its arrivals while intermediate versions exist on the
virtual clock between flushes. A finite reporting ``deadline_s`` preempts
the cycle: uploads that would land after the deadline are *dropped* (the
FedAvg-with-reporting-deadline model — the abandoned payload ships no
bytes and marks no recency), and the next cycle dispatches at the
deadline.

**Reduction-to-sync guarantee.** With ``deadline_s=None`` (∞),
``buffer_size=None`` (one flush of all arrivals) and
``staleness_discount=1.0``, every selected upload arrives, lands in a
single flush with weight exactly ``n_k``, and the cycle barrier equals the
synchronous round: the run matches ``backend="engine"`` *exactly* on
uploads, ledger, and selection, and to float tolerance on encoders — the
parity oracle ``tests/test_scheduler.py`` pins. This holds because the
actual numerics never moved: training, joint selection
(``rounds._joint_selection``) and aggregation are the same code the sync
backends run, in the same RNG order; the scheduler only decides *when*
results take effect, and timing randomness (links, stragglers) draws from
a separate generator that never touches the round stream.

Virtual-time state lives in the :class:`~repro.core.federation_state.
FederationState` extensions (``model_version``, ``arrival_time``,
``last_upload_time``); ``recency_unit="time"`` feeds Eq. 11 recency and the
§4.8 loss_recency staleness from that clock (in units of the mean cycle
duration) instead of round indices.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from enum import IntEnum
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.aggregation import CommLedger
from repro.core.client import Client
from repro.core.federation_state import FederationState
from repro.core.timing import (ComputeModel, resolve_links, resolve_trace,
                               sample_straggler_multipliers)


class EventKind(IntEnum):
    """Lifecycle of one client's participation in a cycle. The integer
    values order simultaneous events: a dispatch sorts before a completion
    at the same instant, and a compute completion before an upload."""
    DISPATCH = 0
    LOCAL_DONE = 1
    UPLOAD_DONE = 2


@dataclass(frozen=True)
class Event:
    time: float
    kind: EventKind
    client_id: int

    @property
    def sort_key(self) -> Tuple[float, int, int]:
        return (self.time, int(self.kind), self.client_id)


class EventHeap:
    """Min-heap of :class:`Event` with the deterministic total order
    ``(time, kind, client id)`` — equal-time events always pop in the same
    order, so a simulated run is reproducible bit-for-bit."""

    def __init__(self):
        self._heap: List[Tuple[float, int, int]] = []

    def push(self, time: float, kind: EventKind, client_id: int) -> None:
        heapq.heappush(self._heap, (float(time), int(kind), int(client_id)))

    def pop(self) -> Event:
        time, kind, cid = heapq.heappop(self._heap)
        return Event(time, EventKind(kind), cid)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


# ---------------------------------------------------------------------------
# per-client timing for one cycle
# ---------------------------------------------------------------------------

def client_compute_seconds(c: Client, cfg, compute: ComputeModel,
                           multiplier: float = 1.0) -> float:
    """T_comp(k) for one Local Learning phase under ``cfg``."""
    return compute.local_seconds(c, epochs=cfg.local_epochs,
                                 batch_size=cfg.batch_size,
                                 multiplier=multiplier)


def upload_seconds(state: FederationState, k: int, modalities: List[str],
                   link) -> float:
    """T_up(k): the exact ledger wire bytes of the client's chosen
    modalities at this run's precision, over its sampled link."""
    nbytes = sum(float(state.sizes[k, state.mod_index[m]])
                 for m in modalities)
    return link.seconds(nbytes)


def nominal_cycle_seconds(clients: List[Client], spec, cfg,
                          qbits: Optional[int] = None) -> float:
    """A deadline yardstick: the slowest *nominal* client (straggler
    multiplier 1, base link) through compute + a γ-modality upload. A
    reporting deadline slightly above this admits every healthy client and
    drops only stragglers."""
    from repro.core.timing import LINK_PRESETS
    qb = cfg.quantize_bits if qbits is None else qbits
    state = FederationState.build(clients, spec, qb, stack=False)
    compute = ComputeModel(sec_per_step=cfg.compute_sec_per_step)
    link = LINK_PRESETS[cfg.link_preset]
    worst = 0.0
    for c in clients:
        k = state.row_of[c.client_id]
        tc = client_compute_seconds(c, cfg, compute)
        sizes = sorted((float(state.sizes[k, state.mod_index[m]])
                        for m in c.modality_names), reverse=True)
        tu = link.seconds(sum(sizes[:max(cfg.gamma, 1)]))
        worst = max(worst, tc + tu)
    return worst


# ---------------------------------------------------------------------------
# the async engine
# ---------------------------------------------------------------------------

def run_async_federation(clients: List[Client], spec, cfg, *,
                         verbose: bool = False,
                         server_encoders: Optional[Dict] = None,
                         quantize_bits: Optional[int] = None):
    """Algorithm 1 on the virtual clock (see module docstring).

    Invoked through ``run_federation(backend="async")``; argument semantics
    match it. The returned :class:`~repro.core.rounds.RunHistory` carries
    the virtual-time fields (``sim_time`` per cycle — ``makespan_s`` for
    the run — plus per-cycle ``flushes`` and deadline-``dropped`` ids)."""
    from repro.core.rounds import (RoundRecord, RunHistory, _joint_selection,
                                   aggregate_uploads)
    from repro.core.batched import (PredictionCache, batched_evaluate,
                                    batched_fusion_stage,
                                    batched_local_learning)

    if cfg.recency_unit == "time" and cfg.selection_impl != "engine":
        raise ValueError('recency_unit="time" requires '
                         'selection_impl="engine" (the host reference ranks '
                         'on round-index recency trackers)')
    if cfg.deadline_s is not None and cfg.deadline_s <= 0:
        raise ValueError("deadline_s must be positive (None = no deadline)")
    if cfg.buffer_size is not None and cfg.buffer_size < 1:
        raise ValueError("buffer_size must be >= 1 (None = all arrivals)")

    qbits = cfg.quantize_bits if quantize_bits is None else quantize_bits
    K = len(clients)
    rng = np.random.default_rng(cfg.seed)
    # timing-only randomness (links, straggler assignment) on a separate
    # stream: it must never perturb the training/selection draws the
    # degenerate-parity oracle compares against the sync engine
    timing_rng = np.random.default_rng(np.random.SeedSequence(
        [cfg.seed, 0x71ED]))
    ledger = CommLedger()
    history = RunHistory()
    server_encoders = server_encoders if server_encoders is not None else {}

    state = FederationState.build(clients, spec, qbits, stack=True)
    store = state.store
    trace = resolve_trace(cfg)
    compute = ComputeModel(sec_per_step=cfg.compute_sec_per_step)
    links = resolve_links(cfg, timing_rng, K)
    mult = sample_straggler_multipliers(timing_rng, K,
                                        cfg.straggler_fraction,
                                        cfg.straggler_factor)
    # T_comp is static per run (epochs/batch/shapes don't change): cache it
    t_comp = {c.client_id: client_compute_seconds(
        c, cfg, compute, mult[state.row_of[c.client_id]])
        for c in clients}

    deadline = np.inf if cfg.deadline_s is None else float(cfg.deadline_s)
    clock = 0.0
    server_version = 0
    by_id = {c.client_id: c for c in clients}
    tr = telemetry.get()

    try:
        for t in range(1, cfg.rounds + 1):
          with telemetry.span("round", round=t, backend="async"):
            avail_mask = trace.step(rng, K)
            avail = [c for k, c in enumerate(clients) if avail_mask[k]]
            if not avail:
                with telemetry.span("eval"):
                    acc, loss = batched_evaluate(clients, store=store)
                ledger.rounds = t
                history.records.append(RoundRecord(
                    t, acc, loss, ledger.megabytes, [], {},
                    sim_time=clock))
                if tr is not None:
                    tr.metrics.record_round(
                        round=t, accuracy=float(acc),
                        mean_loss=float(loss),
                        comm_mb=ledger.megabytes, uplink=[],
                        selected=[], choices={}, shapley={},
                        dropped=[], flushes=0, staleness={},
                        sim_time=clock)
                continue

            # -- dispatch: local learning starts at τ_t ------------------
            # (the math runs now, in sync RNG order; its *results* take
            # effect at the scheduled completion events)
            # DISPATCH is implicit at τ_t: every available client receives
            # the current globals and starts local work (only *completion*
            # events go on the heap — a DISPATCH event at the current
            # instant could never reorder anything)
            heap = EventHeap()
            for c in avail:
                # dispatch hands the client the current globals: staleness
                # at flush time is measured against this version
                state.model_version[state.row_of[c.client_id]] = \
                    server_version
            # per-cycle train-split prediction cache (Stage-#1 fills it,
            # Shapley reuses it; dropped before the flushes deploy)
            cache = PredictionCache()
            with telemetry.span("train.local", clients=len(avail)):
                batched_local_learning(avail, cfg, rng, store=store,
                                       cache=cache)
                for c in avail:             # mirror ℓ_m^k into the state
                    k = state.row_of[c.client_id]
                    for m, v in c.losses.items():
                        state.losses[k, state.mod_index[m]] = v

            # -- joint selection (shared with the sync backends) ---------
            recency_matrix = client_staleness = None
            if cfg.recency_unit == "time":
                scale = clock / (t - 1) if t > 1 and clock > 0 else 1.0
                recency_matrix = state.recency_matrix_time(clock, scale, t)
                client_staleness = state.client_staleness_time(
                    clock, scale, t)
            choices, selected, round_shapley = _joint_selection(
                avail, state, cfg, rng, t, qbits, True, store,
                recency_matrix=recency_matrix,
                client_staleness=client_staleness, cache=cache)

            # -- schedule completions ------------------------------------
            for c in avail:
                heap.push(clock + t_comp[c.client_id], EventKind.LOCAL_DONE,
                          c.client_id)
                if tr is not None:      # virtual-time lanes (pid 2)
                    tr.virtual_instant("dispatch", c.client_id, clock,
                                       round=t)
                    tr.virtual_slice("local", c.client_id, clock,
                                     clock + t_comp[c.client_id], round=t)
            for cid in selected:
                k = state.row_of[cid]
                tu = upload_seconds(state, k, choices[cid], links[k])
                heap.push(clock + t_comp[cid] + tu, EventKind.UPLOAD_DONE,
                          cid)
                if tr is not None:
                    tr.virtual_slice("upload", cid, clock + t_comp[cid],
                                     clock + t_comp[cid] + tu, round=t,
                                     modalities=len(choices[cid]))

            # -- drain the heap: buffered flushes under the deadline -----
            cycle_deadline = clock + deadline
            buffer_cap = cfg.buffer_size or len(selected) or 1
            buffer: List[int] = []
            arrived: List[int] = []
            dropped: List[int] = []
            flushes = 0
            last_event = clock      # cohort barrier: compute + uploads
            last_arrival = clock    # last accepted upload (flush stamps)
            # per-cycle running aggregate: modality -> (mean tree, Σw).
            # Each flush merges into it, so the cycle's final global is the
            # staleness-weighted Eq. 21 mean over ALL its arrivals — one
            # flush reproduces aggregate_uploads bit-for-bit (no merge
            # arithmetic ever runs), which the degenerate parity pins.
            cycle_acc: Dict[str, Tuple[Dict, float]] = {}
            stale_log: Dict[int, float] = {}   # cid -> flush weight factor
            uplink_log: List[Dict] = []

            def flush(now: float) -> None:
                nonlocal flushes, server_version
                flushes += 1
                with telemetry.span("comm.flush", arrivals=len(buffer)):
                    if tr is not None:
                        tr.virtual_instant("flush", 0, now,
                                           arrivals=len(buffer), round=t)
                    per_modality: Dict[str, List[Client]] = {}
                    weights: Dict[str, List[float]] = {}
                    upload_mask = np.zeros_like(state.presence)
                    for cid in sorted(buffer):
                        c = by_id[cid]
                        k = state.row_of[cid]
                        stale = server_version - int(state.model_version[k])
                        stale_log[cid] = cfg.staleness_discount ** stale
                        w = (float(c.train.num_samples)
                             * cfg.staleness_discount ** stale)
                        for m in choices[cid]:
                            per_modality.setdefault(m, []).append(c)
                            weights.setdefault(m, []).append(w)
                            upload_mask[k, state.mod_index[m]] = True
                        c.recency.mark_uploaded(choices[cid], t)
                    state.mark_uploaded(upload_mask, t)          # Eq. 11
                    state.mark_uploaded_time(upload_mask, now)   # clock
                    for m, ups in per_modality.items():
                        avg = aggregate_uploads(
                            ups, m, weights[m], qbits,
                            error_feedback=cfg.error_feedback, store=store,
                            comm_impl=cfg.comm_impl)
                        w_f = float(sum(weights[m]))
                        if m in cycle_acc:
                            prev, w_prev = cycle_acc[m]
                            tot = w_prev + w_f
                            avg = jax.tree.map(
                                lambda a, b:
                                    ((w_prev * a.astype(jnp.float32)
                                      + w_f * b.astype(jnp.float32))
                                     / tot).astype(b.dtype), prev, avg)
                            w_f = tot
                        cycle_acc[m] = (avg, w_f)
                        server_encoders[m] = avg
                    server_version += 1
                    buffer.clear()

            with telemetry.span("comm.uplink", clients=len(selected)):
                while heap:
                    ev = heap.pop()
                    last_event = max(last_event,
                                     min(ev.time, cycle_deadline))
                    if ev.kind is not EventKind.UPLOAD_DONE:
                        continue
                    if ev.time > cycle_deadline:
                        dropped.append(ev.client_id)  # preempted
                        if tr is not None:
                            tr.virtual_instant("deadline_drop",
                                               ev.client_id,
                                               cycle_deadline, round=t)
                        continue
                    k = state.row_of[ev.client_id]
                    for m in choices[ev.client_id]:
                        nb = float(state.sizes[k, state.mod_index[m]])
                        ledger.record(nb, modality=m)
                        uplink_log.append({"client": ev.client_id,
                                           "modality": m, "bytes": nb})
                    buffer.append(ev.client_id)
                    arrived.append(ev.client_id)
                    last_arrival = ev.time
                    if len(buffer) >= buffer_cap:
                        flush(ev.time)
                if buffer:
                    # stamp the cycle-end flush at its last accepted
                    # arrival — not at the cohort compute barrier, which a
                    # non-uploading client's LOCAL_DONE can push later
                    flush(last_arrival)
            # the cohort barrier, deadline-clamped event by event above
            # (any dropped event already pinned it to cycle_deadline)
            cycle_end = last_event
            if tr is not None:      # server lane: the whole cycle window
                tr.virtual_slice("cycle", 0, clock, cycle_end, round=t)

            # -- local deploying + Stage #2 ------------------------------
            with telemetry.span("deploy"):
                for m, params in server_encoders.items():
                    rows = [state.row_of[c.client_id] for c in avail
                            if m in c.encoders]
                    state.deploy_global(m, rows, params)
                for c in avail:     # deploy ships the post-flush globals
                    state.model_version[state.row_of[c.client_id]] = \
                        server_version
            with telemetry.span("train.fusion2", clients=len(avail)):
                batched_fusion_stage(avail, cfg, rng, store=store)

            # -- evaluate + record ---------------------------------------
            with telemetry.span("eval"):
                acc, loss = batched_evaluate(clients, store=store)
            clock = max(clock, cycle_end)
            ledger.rounds = t
            uploads = [(cid, m) for cid in selected if cid in arrived
                       for m in choices[cid]]
            shap = {m: float(np.mean(v))
                    for m, v in round_shapley.items()}
            history.records.append(RoundRecord(
                t, acc, loss, ledger.megabytes, uploads, shap,
                sim_time=clock, flushes=flushes, dropped=sorted(dropped)))
            if tr is not None:
                tr.metrics.record_round(
                    round=t, accuracy=float(acc), mean_loss=float(loss),
                    comm_mb=ledger.megabytes, uplink=uplink_log,
                    selected=sorted(int(cid) for cid in selected),
                    choices={int(cid): list(choices[cid])
                             for cid in selected},
                    shapley=shap, dropped=sorted(dropped),
                    flushes=flushes,
                    staleness={int(k): v for k, v in stale_log.items()},
                    sim_time=clock)
            if verbose:
                print(f"[cycle {t:3d}] τ={clock:9.2f}s acc={acc:.4f} "
                      f"loss={loss:.4f} comm={ledger.megabytes:.3f}MB "
                      f"uploads={len(uploads)} flushes={flushes} "
                      f"dropped={len(dropped)}")
            if cfg.comm_budget_mb is not None and \
                    ledger.megabytes >= cfg.comm_budget_mb:
                break
    finally:
        with telemetry.span("write_back"):
            state.write_back()
        if tr is not None:
            tr.metrics.set_run(
                backend="async", rounds=len(history.records),
                ledger_bytes=float(ledger.uploaded_bytes),
                ledger_uploads=int(ledger.uploads),
                ledger_by_modality={m: float(v) for m, v in
                                    ledger.by_modality.items()})
    return history

"""Paper-faithful modality encoders (§4.2), as pure-JAX pytree modules.

- Time-series modalities: a single-layer LSTM (128 hidden units) followed by
  a fully-connected classification layer — exactly the paper's setup.
- Image modalities (DFC23): one 5×5 conv (32 channels) + ReLU + 2×2 max-pool
  + fully-connected layer.

Each encoder maps raw modality measurements to class logits; per §4.2 the
*fusion module* consumes definitive predicted categories (one-hot argmax) by
default, with soft probabilities available as a differentiable option.

All functions are jit-friendly: ``init_encoder`` / ``encoder_forward``
dispatch on the modality kind recorded in the param tree's static structure.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import pytree_wire_bytes

LSTM_HIDDEN = 128
CNN_CHANNELS = 32


def _glorot(rng, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(rng, shape, dtype)


# ---------------------------------------------------------------------------
# LSTM encoder
# ---------------------------------------------------------------------------

def init_lstm_encoder(rng, feat_dim: int, num_classes: int,
                      hidden: int = LSTM_HIDDEN) -> Dict:
    ks = jax.random.split(rng, 4)
    return {
        # fused i|f|g|o gates
        "w_x": _glorot(ks[0], (feat_dim, 4 * hidden)),
        "w_h": _glorot(ks[1], (hidden, 4 * hidden)),
        "b": jnp.zeros((4 * hidden,), jnp.float32)
             .at[hidden:2 * hidden].set(1.0),   # forget-gate bias 1
        "w_fc": _glorot(ks[2], (hidden, num_classes)),
        "b_fc": jnp.zeros((num_classes,), jnp.float32),
    }


def _lstm_forward(params, x):
    """x: [B, T, F] -> logits [B, C] (last hidden state -> FC)."""
    b, t, f = x.shape
    hidden = params["w_h"].shape[0]

    def cell(carry, x_t):
        h, c = carry
        z = x_t @ params["w_x"] + h @ params["w_h"] + params["b"]
        i, fgt, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(fgt) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((b, hidden), x.dtype)
    (h, _), _ = jax.lax.scan(cell, (h0, h0), jnp.moveaxis(x, 1, 0))
    return h @ params["w_fc"] + params["b_fc"]


# ---------------------------------------------------------------------------
# CNN encoder
# ---------------------------------------------------------------------------

def init_cnn_encoder(rng, in_shape: Tuple[int, int, int], num_classes: int,
                     channels: int = CNN_CHANNELS) -> Dict:
    h, w, c = in_shape
    ks = jax.random.split(rng, 2)
    # 'valid' 5x5 conv then 2x2 pool
    ph, pw = (h - 4) // 2, (w - 4) // 2
    return {
        "conv_w": 0.1 * jax.random.normal(ks[0], (5, 5, c, channels)),
        "conv_b": jnp.zeros((channels,), jnp.float32),
        "w_fc": _glorot(ks[1], (ph * pw * channels, num_classes)),
        "b_fc": jnp.zeros((num_classes,), jnp.float32),
    }


def _cnn_forward(params, x):
    """x: [B, H, W, C] -> logits [B, C]."""
    y = jax.lax.conv_general_dilated(
        x, params["conv_w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["conv_b"]
    y = jax.nn.relu(y)
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    return y.reshape(y.shape[0], -1) @ params["w_fc"] + params["b_fc"]


# ---------------------------------------------------------------------------
# unified API
# ---------------------------------------------------------------------------

def init_encoder(rng, feature_shape: Tuple[int, ...], num_classes: int) -> Dict:
    if len(feature_shape) == 3:
        return init_cnn_encoder(rng, feature_shape, num_classes)
    t, f = feature_shape
    return init_lstm_encoder(rng, f, num_classes)


def encoder_forward(params, x):
    """Dispatch on structure: CNN encoders carry 'conv_w'."""
    if "conv_w" in params:
        return _cnn_forward(params, x)
    return _lstm_forward(params, x)


def encoder_param_arrays(params) -> Dict:
    """The numeric leaves (identity now; kept for API stability)."""
    return dict(params)


def encoder_bytes(params, bits: int = 32) -> int:
    """Exact upload size in bytes at the given precision (Eq. 10).

    Delegates to ``repro.core.quantize.tensor_wire_bytes``: full precision
    ships the raw parameter dtype; quantized uplinks ship bit-packed codes
    in their smallest sufficient dtype *plus* the per-tensor scale/zero
    metadata — so 16-bit codes cost 2 bytes/param (not an int32's 4) and
    the ledger no longer undercounts the metadata."""
    return pytree_wire_bytes(encoder_param_arrays(params), bits)


def encoder_num_params(params) -> int:
    return sum(int(np.prod(v.shape))
               for v in encoder_param_arrays(params).values())


# ---------------------------------------------------------------------------
# supervised training step (CE + SGD, paper's recipe)
# ---------------------------------------------------------------------------

def encoder_loss(params, x, y):
    logits = encoder_forward(params, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def masked_encoder_loss(params, x, y, w):
    """Mask-weighted CE over a padded batch: Σ w·ce / max(Σ w, 1).

    On real rows (w = 1) this equals :func:`encoder_loss` of the unpadded
    batch; padded rows (w = 0) contribute neither loss nor gradient, and a
    fully-padded batch yields exactly 0 with zero gradient — a no-op SGD
    step. This is the per-step loss of the ragged-federation fast path."""
    logits = encoder_forward(params, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ce = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.sum(w * ce) / jnp.maximum(jnp.sum(w), 1.0)


@functools.partial(jax.jit, static_argnames=("lr",))
def encoder_sgd_step(params, x, y, lr: float = 0.1):
    loss, grads = jax.value_and_grad(encoder_loss)(params, x, y)
    return jax.tree.map(lambda p, g: p - lr * g, params, grads), loss


@jax.jit
def encoder_eval(params, x, y):
    """Returns (mean CE loss, accuracy)."""
    logits = encoder_forward(params, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


@jax.jit
def encoder_predict(params, x):
    """Definitive predicted categories as one-hot (fusion input, §4.2)."""
    logits = encoder_forward(params, x)
    c = logits.shape[-1]
    return jax.nn.one_hot(jnp.argmax(logits, -1), c, dtype=jnp.float32)


@jax.jit
def encoder_predict_probs(params, x):
    return jax.nn.softmax(encoder_forward(params, x).astype(jnp.float32))

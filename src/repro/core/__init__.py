"""MFedMC — the paper's contribution: decoupled multimodal federated
learning with joint modality and client selection.

Layers:
    encoders.py    — paper-faithful LSTM/CNN modality encoders (θ_m)
    fusion.py      — strictly-local fusion module (ω^k)
    shapley.py     — exact interventional Shapley modality impact (Eq. 8)
    selection.py   — priority + top-γ modality / top-δ client selection
                     (per-client numpy reference)
    selection_engine.py — the same Eqs. 9–20 as device [K, M] programs,
                     bit-identical to the reference on outcomes
    federation_state.py — arrayized population state (resident stacked
                     encoders/fusion, Eq. 11 recency matrix, wire sizes)
                     + the ClientStore/StateStore param-store protocol
    aggregation.py — per-modality weighted FedAvg (Eq. 21) as a stacked
                     device-resident reduction (+ fused quantized form),
                     comm ledger with exact wire accounting
    quantize.py    — §4.10 uplink quantization as a subsystem: jit'd,
                     vmap-able pytree quantizer, bit-packed wire format,
                     exact byte accounting, error-feedback residuals
    client.py      — client state + Algorithm 1 local phases
    rounds.py      — the federation loop with every §4 ablation knob
                     (backend='loop' reference / 'batched' fast path)
    timing.py      — virtual-time models: compute time per shape family,
                     heterogeneous uplinks, availability traces (§4.9
                     Bernoulli + Markov churn)
    scheduler.py   — event-driven async runtime (backend='async'):
                     virtual clock, buffered staleness-aware aggregation,
                     deadline straggler dropping; degenerate config
                     reduces exactly to the sync engine
    batched.py     — padded, mask-weighted vmapped local learning for
                     ragged federations (the simulator's hot-path backend;
                     same [K, M] population layout the mesh shards)
    baselines.py   — FL-FD / MMFed / FedMultimodal / FLASH / Harmony
    distributed.py — the datacenter mapping: clients on the mesh 'data'
                     axis, selective upload as masked sparse all-reduce,
                     single- and multi-modality jit'd rounds
"""
from repro.core.aggregation import (CommLedger, ICI_LINK, IOT_UPLINK,
                                    TransportModel, aggregate_modality,
                                    aggregate_quantized, aggregate_stacked,
                                    stack_uploads)
from repro.core.batched import (batched_evaluate, batched_local_learning,
                                batched_shapley_values,
                                padded_population_batches, plan_permutations)
from repro.core.client import Client, make_client
from repro.core.federation_state import (ClientStore, FederationState,
                                         StateStore)
from repro.core.encoders import (encoder_bytes, encoder_eval,
                                 encoder_forward, encoder_num_params,
                                 encoder_predict, encoder_sgd_step,
                                 init_encoder)
from repro.core.fusion import (fusion_eval, fusion_forward, fusion_sgd_step,
                               init_fusion)
from repro.core.quantize import (dequantize_encoder, dequantize_pytree,
                                 fake_quantize_pytree, pytree_wire_bytes,
                                 quantize_encoder, quantize_population,
                                 quantize_population_with_error_feedback,
                                 quantize_pytree,
                                 quantize_with_error_feedback,
                                 quantized_roundtrip, tensor_wire_bytes,
                                 zero_residual)
from repro.core.rounds import (MFedMCConfig, RoundRecord, RunHistory,
                               aggregate_uploads, build_federation,
                               run_federation, run_mfedmc)
from repro.core.selection import (RecencyTracker, SelectionResult,
                                  joint_select, minmax_normalize,
                                  modality_priority, select_clients,
                                  select_top_gamma)
from repro.core.scheduler import (Event, EventHeap, EventKind,
                                  nominal_cycle_seconds,
                                  run_async_federation)
from repro.core.selection_engine import (EngineDecision, ModalityDecision,
                                         joint_select_arrays,
                                         lexicographic_rank,
                                         select_clients_arrays,
                                         select_modalities_arrays)
from repro.core.shapley import (exact_shapley, exact_shapley_population,
                                sampled_shapley, subset_masks)
from repro.core.timing import (BernoulliTrace, ComputeModel, MarkovTrace,
                               make_trace, resolve_trace,
                               sample_straggler_multipliers)

__all__ = [
    "CommLedger", "ICI_LINK", "IOT_UPLINK", "TransportModel",
    "aggregate_modality", "aggregate_quantized", "aggregate_stacked",
    "aggregate_uploads", "stack_uploads", "batched_evaluate",
    "batched_local_learning", "batched_shapley_values",
    "padded_population_batches", "plan_permutations", "Client",
    "make_client", "encoder_bytes", "encoder_eval", "encoder_forward",
    "encoder_num_params", "encoder_predict", "encoder_sgd_step",
    "init_encoder", "fusion_eval", "fusion_forward", "fusion_sgd_step",
    "init_fusion", "dequantize_encoder", "dequantize_pytree",
    "fake_quantize_pytree", "pytree_wire_bytes", "quantize_encoder",
    "quantize_population", "quantize_population_with_error_feedback",
    "quantize_pytree", "quantize_with_error_feedback",
    "quantized_roundtrip", "tensor_wire_bytes", "zero_residual",
    "MFedMCConfig", "RoundRecord", "RunHistory", "build_federation",
    "run_federation", "run_mfedmc", "RecencyTracker", "SelectionResult",
    "joint_select", "minmax_normalize", "modality_priority",
    "select_clients", "select_top_gamma", "exact_shapley",
    "exact_shapley_population", "sampled_shapley", "subset_masks",
    "ClientStore", "FederationState", "StateStore", "EngineDecision",
    "ModalityDecision", "joint_select_arrays", "lexicographic_rank",
    "select_clients_arrays", "select_modalities_arrays",
    "Event", "EventHeap", "EventKind", "nominal_cycle_seconds",
    "run_async_federation", "BernoulliTrace", "ComputeModel", "MarkovTrace",
    "make_trace", "resolve_trace", "sample_straggler_multipliers",
]

"""Device-resident joint-selection engine — Eqs. (9)–(20) over the ``[K, M]``
population layout.

``repro.core.selection`` is the paper-faithful numpy reference: per-client
vectors, Python ``sorted`` tie-breaks, host-side ranking. This module runs
the *whole population's* joint selection as one compiled program over the
``[K, M]`` matrices the batched simulator and the mesh tier already share:

- |φ| and size min-max normalization (Eq. 12) as masked row-wise reductions
  (each client normalizes over its own candidate modalities only);
- composite priority (Eq. 13) as one fused elementwise program;
- top-γ modality selection (Eqs. 14–16) as a per-row ``lexsort`` on
  ``(-priority, name_rank)``;
- client selection (Eqs. 17–19: low_loss / high_loss / loss_recency) as a
  stable rank over representative losses. The ``random`` criterion and the
  ``random`` modality strategy stay host-side by design — they own the round
  RNG, whose consumption order is the backends' parity contract.

**Bit-identical outcomes, by construction.** Two mechanisms make the engine
reproduce the numpy reference exactly on selection *outcomes* (which pairs
upload), not just to float tolerance:

1. The decision math runs in float64 (a locally-scoped ``enable_x64`` —
   the rest of the simulator stays float32) and is AOT-compiled with
   ``xla_backend_optimization_level=0``, which stops LLVM from contracting
   ``a*b + c`` chains into FMAs. With contraction on, Eq. 13's weighted sum
   differs from numpy by 1 ulp on ~25% of inputs — enough to flip a
   tie-break. The decision programs consume K·M scalars, so the
   deoptimized codegen costs nothing measurable.
2. ``select_top_gamma``'s tie-break (descending priority, then *name*
   order) cannot be reproduced by an index-ordered ``top_k``; the engine
   sorts on precomputed lexicographic name-rank arrays
   (:func:`lexicographic_rank`) instead. Ranks preserve exact name
   comparisons, so equal priorities break ties exactly as the reference's
   ``sorted(..., key=(-priority, name))``.

Rows must be ordered by ascending client id (the reference sorts ids before
ranking); inputs must be finite on present entries. Compiled programs cache
per (padded-K, M, static config); K pads to the next power of two so a run
with §4.9 availability sees O(log K) distinct shapes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import hostsync

# LLVM opt level 0 for the tiny decision programs: no FMA contraction, so
# float64 arithmetic is bit-identical to numpy's (see module docstring).
_COMPILER_OPTIONS = {"xla_backend_optimization_level": 0}

DETERMINISTIC_CLIENT_CRITERIA = ("low_loss", "high_loss", "loss_recency")


def lexicographic_rank(names: Sequence[str]) -> np.ndarray:
    """``rank[i]`` = position of ``names[i]`` in ``sorted(names)``.

    Comparing ranks is exactly comparing names lexicographically, which is
    what the numpy reference's tie-break does — but ranks are device-sortable
    integers while strings are not."""
    order = sorted(range(len(names)), key=lambda i: names[i])
    rank = np.empty(len(names), np.int64)
    for pos, i in enumerate(order):
        rank[i] = pos
    return rank


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# traced decision programs (compiled under x64 at backend-opt-level 0)
# ---------------------------------------------------------------------------

def _masked_rownorm(x, pres):
    """Eq. 12 per row over present entries; constant rows -> zeros.

    Bit-parity with ``selection.minmax_normalize``: same ``(x − lo)/(hi − lo)``
    doubles, same ``< 1e-12`` constant-vector cutoff."""
    lo = jnp.min(jnp.where(pres, x, jnp.inf), axis=-1, keepdims=True)
    hi = jnp.max(jnp.where(pres, x, -jnp.inf), axis=-1, keepdims=True)
    span = hi - lo
    ok = span >= 1e-12
    out = (x - lo) / jnp.where(ok, span, 1.0)
    return jnp.where(ok & pres, out, 0.0)


def _canonical_zero(key):
    """-0.0 -> +0.0: XLA's total-order sort splits signed zeros, Python's
    ``sorted`` does not."""
    return jnp.where(key == 0.0, 0.0, key)


def _modality_program(phi, sizes, recency, presence, name_rank, t,
                      *, gamma: int, alpha_s: float, alpha_c: float,
                      alpha_r: float):
    """Eqs. 12–16 for every client at once.

    phi/sizes/recency: [K, M] float64 (absent entries: any finite filler)
    presence:          [K, M] bool — candidate modalities per client
    name_rank:         [K, M] int — lexicographic rank of each name
    t:                 scalar float64 round index
    Returns (mask [K, M] bool, order [K, M] int — modality indices sorted by
    (priority desc, name), n_choose [K] int, priority [K, M])."""
    pres = presence > 0
    phi_n = _masked_rownorm(jnp.abs(phi), pres)
    size_n = _masked_rownorm(sizes, pres)
    rec_n = recency / jnp.maximum(t, 1.0)
    prio = alpha_s * phi_n + alpha_c * (1.0 - size_n) + alpha_r * rec_n
    key = jnp.where(pres, _canonical_zero(-prio), jnp.inf)
    # primary: -priority ascending; secondary: name rank ascending
    order = jnp.lexsort((name_rank, key), axis=-1)
    rank = jnp.argsort(order, axis=-1, stable=True)     # inverse permutation
    n_choose = jnp.minimum(gamma, jnp.sum(pres, axis=-1))
    mask = pres & (rank < n_choose[:, None])
    return mask, order, n_choose, prio


def _client_program(losses, mod_mask, client_rec, delta, loss_weight,
                    *, criterion: str):
    """Eqs. 17–19 over the candidate population.

    losses:     [K, M] float64 per-modality encoder losses
    mod_mask:   [K, M] bool — this round's modality choices (Eq. 16)
    client_rec: [K] float64 — per-client staleness (loss_recency only)
    Returns (selected [K] bool, representative loss [K])."""
    cand = jnp.any(mod_mask, axis=-1)
    rep = jnp.min(jnp.where(mod_mask, losses, jnp.inf), axis=-1)
    if criterion == "low_loss":
        ckey = rep
    elif criterion == "high_loss":
        ckey = -rep
    elif criterion == "loss_recency":
        loss_rank = _masked_rownorm(rep[None], cand[None])[0]
        rec_rank = 1.0 - _masked_rownorm(client_rec[None], cand[None])[0]
        ckey = loss_weight * loss_rank + (1.0 - loss_weight) * rec_rank
    else:  # pragma: no cover — guarded by the public wrapper
        raise ValueError(criterion)
    ckey = jnp.where(cand, _canonical_zero(ckey), jnp.inf)
    order = jnp.argsort(ckey, stable=True)
    rank = jnp.argsort(order, stable=True)
    n_sel = jnp.maximum(1, jnp.ceil(delta * jnp.sum(cand))).astype(jnp.int64)
    return cand & (rank < n_sel), rep


# ---------------------------------------------------------------------------
# AOT compile cache
# ---------------------------------------------------------------------------

def _f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


@functools.lru_cache(maxsize=None)
def _compiled_modality(K: int, M: int, gamma: int, alpha_s: float,
                       alpha_c: float, alpha_r: float):
    fn = functools.partial(_modality_program, gamma=gamma, alpha_s=alpha_s,
                           alpha_c=alpha_c, alpha_r=alpha_r)
    with enable_x64():
        lowered = jax.jit(fn).lower(
            _f64(K, M), _f64(K, M), _f64(K, M),
            jax.ShapeDtypeStruct((K, M), jnp.bool_),
            jax.ShapeDtypeStruct((K, M), jnp.int64), _f64())
        return lowered.compile(compiler_options=_COMPILER_OPTIONS)


@functools.lru_cache(maxsize=None)
def _compiled_client(K: int, M: int, criterion: str):
    fn = functools.partial(_client_program, criterion=criterion)
    with enable_x64():
        lowered = jax.jit(fn).lower(
            _f64(K, M), jax.ShapeDtypeStruct((K, M), jnp.bool_),
            _f64(K), _f64(), _f64())
        return lowered.compile(compiler_options=_COMPILER_OPTIONS)


def _pad_rows(a: np.ndarray, kp: int, fill) -> np.ndarray:
    if a.shape[0] == kp:
        return a
    out = np.full((kp,) + a.shape[1:], fill, a.dtype)
    out[:a.shape[0]] = a
    return out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

@dataclass
class ModalityDecision:
    """Top-γ outcome (Eqs. 14–16) for a stacked population."""
    mask: np.ndarray      # [K, M] bool — selected (client, modality) pairs
    order: np.ndarray     # [K, M] int — modality idx by (priority desc, name)
    counts: np.ndarray    # [K] int — min(γ, #present) per client

    def choices(self, row: int, names: Sequence[str]) -> List[str]:
        """Client ``row``'s top-γ names in priority order — exactly
        ``selection.select_top_gamma``'s return value."""
        return [names[j] for j in self.order[row, :self.counts[row]]]


@dataclass
class EngineDecision:
    """One round's joint selection (Eq. 20)."""
    modality: ModalityDecision
    client_mask: np.ndarray         # [K] bool (Eq. 19)

    @property
    def upload_mask(self) -> np.ndarray:
        """[K, M] 0/1 — Θ_γ^δ (Eq. 20), the mask every tier consumes."""
        return self.modality.mask & self.client_mask[:, None]


def select_modalities_arrays(phi, sizes, recency, presence, name_rank, *,
                             t: int, gamma: int, alpha_s: float,
                             alpha_c: float, alpha_r: float
                             ) -> ModalityDecision:
    """Population top-γ (Eqs. 12–16); outcome-identical to running
    ``modality_priority`` + ``select_top_gamma`` per client.

    ``name_rank`` is a ``[M]`` (or ``[K, M]``) lexicographic rank array from
    :func:`lexicographic_rank` over the global modality axis."""
    phi = np.asarray(phi, np.float64)
    K, M = phi.shape
    kp = _pow2(K)
    name_rank = np.broadcast_to(np.asarray(name_rank, np.int64), (K, M))
    comp = _compiled_modality(kp, M, int(gamma), float(alpha_s),
                              float(alpha_c), float(alpha_r))
    with enable_x64():      # keep f64/i64 inputs wide at the call boundary
        mask, order, counts, _ = comp(
            _pad_rows(phi, kp, 0.0),
            _pad_rows(np.asarray(sizes, np.float64), kp, 0.0),
            _pad_rows(np.asarray(recency, np.float64), kp, 0.0),
            _pad_rows(np.asarray(presence, bool), kp, False),
            _pad_rows(name_rank, kp, 0), np.float64(t))
    return ModalityDecision(hostsync.fetch(mask)[:K],
                            hostsync.fetch(order)[:K],
                            hostsync.fetch(counts)[:K])


def select_clients_arrays(losses, mod_mask, *, delta: float,
                          criterion: str = "low_loss",
                          client_recency=None,
                          loss_weight: float = 1.0) -> np.ndarray:
    """Server-side top-⌈δ·#candidates⌉ (Eqs. 17–19) over the [K, M] layout;
    outcome-identical to ``selection.select_clients`` on the representative
    losses (min over each client's chosen modalities).

    ``random`` / ``all`` are the caller's job: ``random`` owns the round RNG
    (pass it to ``selection.select_clients``), ``all`` is trivial."""
    if criterion not in DETERMINISTIC_CLIENT_CRITERIA:
        raise ValueError(
            f"criterion {criterion!r} is not device-deterministic; handle "
            "'random' (needs the round rng) and 'all' host-side")
    losses = np.asarray(losses, np.float64)
    K, M = losses.shape
    kp = _pow2(K)
    rec = (np.zeros(K) if client_recency is None
           else np.asarray(client_recency, np.float64))
    comp = _compiled_client(kp, M, criterion)
    with enable_x64():      # keep f64 inputs wide at the call boundary
        sel, _ = comp(_pad_rows(losses, kp, np.inf),
                      _pad_rows(np.asarray(mod_mask, bool), kp, False),
                      _pad_rows(rec, kp, 0.0), np.float64(delta),
                      np.float64(loss_weight))
    return hostsync.fetch(sel)[:K]


def joint_select_arrays(phi, sizes, recency, losses, presence, name_rank, *,
                        t: int, gamma: int, delta: float,
                        alpha_s: float, alpha_c: float, alpha_r: float,
                        client_criterion: str = "low_loss",
                        client_recency=None,
                        loss_weight: float = 1.0) -> EngineDecision:
    """Sequential joint selection (§3.3, Eq. 20): modalities first, then
    clients — the engine counterpart of ``selection.joint_select`` for the
    deterministic strategies."""
    mod = select_modalities_arrays(
        phi, sizes, recency, presence, name_rank, t=t, gamma=gamma,
        alpha_s=alpha_s, alpha_c=alpha_c, alpha_r=alpha_r)
    sel = select_clients_arrays(
        losses, mod.mask, delta=delta, criterion=client_criterion,
        client_recency=client_recency, loss_weight=loss_weight)
    return EngineDecision(mod, sel)

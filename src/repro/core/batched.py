"""Batched local learning — padded, mask-weighted vmapped SGD for ragged
federations.

``run_federation(backend="batched")`` replaces Algorithm 1's per-client
Python loop (Local Learning) with a stacked computation over the *whole*
population, including the paper's defining setting: clients with diverse
modality sets and non-IID sample counts (challenge (i)). There is no ragged
fallback — heterogeneity is first-class:

- **Bucket planner.** (client, modality) pairs bucket by *coarse shape
  family* only — the modality's feature shape, the class count, and the
  schedule length S = ⌈n/B⌉. Modality set, modality name, and exact sample
  count never fragment a batch, so a federation with structurally missing
  modalities and skewed n still packs into a handful of vmapped programs
  (e.g. UCI-HAR's accelerometer and gyroscope encoders share one bucket),
  while keying on S bounds padding waste at one batch per pair.
- **Padded step schedule.** Within a bucket, every client runs the same
  S steps per epoch. Client k's samples fill the first n_k slots of its
  [S, B] schedule (so its full batches and trailing partial batch are
  exactly the loop's); the rest carry an all-zero sample mask. The
  mask-weighted loss Σ w·ce / max(Σ w, 1) reproduces the loop's per-batch
  mean CE on real rows and is identically 0 — with zero gradient, hence a
  no-op SGD update — on fully-padded steps.
- **Presence masks.** Absent modalities are represented by per-(client,
  modality) 0/1 presence masks (``Client.avail_mask`` stacked to [K, M]) —
  the same population layout ``core.distributed`` uses for Eq. 21's masked
  all-reduce — instead of by group membership. Fusion, evaluation, and the
  vmapped exact-Shapley enumeration all consume that [K, M] layout.

RNG parity: the loop backend draws one ``rng.permutation(n)`` per
(client, modality, epoch) and per (client, fusion-epoch), interleaved in
client order. :func:`plan_permutations` precomputes exactly that sequence up
front, and :func:`batched_shapley_values` draws each client's background /
eval subsets in the same client order the loop would, so both backends
consume the shared generator identically — every downstream phase (selection
strategies, availability) sees bit-identical randomness, and round-1
aggregates match the loop backend to float tolerance (the parity tests pin
ragged federations, not just homogeneous ones, at 1e-5).

Parameter storage is pluggable: every phase reads/writes encoder and fusion
pytrees through a *param store* (``repro.core.federation_state``). The
default :class:`~repro.core.federation_state.ClientStore` stacks from and
unstacks to ``Client`` objects each call — Tier 2's historical behavior.
``run_federation(backend="engine")`` passes a
:class:`~repro.core.federation_state.StateStore` instead, so the same
training code gathers/scatters rows of the resident
:class:`~repro.core.federation_state.FederationState` buckets and the round
never restacks the population.

Two trainer implementations share every phase
(``MFedMCConfig.train_impl``, mirroring ``comm_impl``):

- ``"fused"`` (default) — each bucket's E-epoch chain runs as ONE jitted
  program (``repro.kernels.train.fused_encoder_round`` /
  ``fused_fusion_round``) with ``donate_argnums`` on the resident param
  stack: one dispatch and zero param-stack copies per bucket per phase.
- ``"reference"`` — the historical chain: one ``masked_batched_epoch`` /
  ``masked_fusion_epoch`` launch per epoch, params round-tripping through
  the dispatch boundary each time.

Both consume identical schedules and run the identical step body, so they
match bit-for-bit on CPU and selection outcomes never depend on the choice
(``tests/test_train_fused.py`` pins 1e-5 with exact ledger/selection).
Every training-path launch reports through ``hostsync.record_dispatch``,
so benchmarks and the budget manifest meter dispatched-programs-per-round.

A :class:`PredictionCache` dedupes the round's train-split encoder
forwards: Stage-#1 fusion fills it and the Shapley enumeration reuses it
(previously both recomputed the same ``_population_predictions``), for
both trainer impls. The round loop drops the cache when Local Deploying
overwrites encoders, so Stage-#2 and evaluation always see fresh
forwards.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import encoders as enc
from repro.core import hostsync
from repro.core.client import Client
from repro.core.encoders import masked_encoder_loss
from repro.core.fusion import masked_fusion_eval, masked_fusion_loss
from repro.core.shapley import exact_shapley_population
from repro.kernels.train import fused_encoder_round, fused_fusion_round


def _default_store():
    from repro.core.federation_state import ClientStore
    return ClientStore()


TRAIN_IMPLS = ("fused", "reference")


class PredictionCache:
    """Per-round cache of train-split encoder predictions.

    One entry per client: the ``[n_k, M, C]`` prediction block its trained
    encoders produce on its own train split. Stage-#1 fusion training fills
    it; the Shapley enumeration reads it back — one forward per (client,
    round) instead of two — and the round loop constructs a fresh cache
    each round (deploying aggregated encoders invalidates every entry, so
    Stage-#2 and evaluation never consult it). Blocks are keyed by
    ``client_id`` rather than bucket, because fusion *training* buckets
    (keyed on schedule length) group clients differently from the Shapley
    and evaluation buckets."""

    def __init__(self):
        self._blocks: Dict[int, np.ndarray] = {}

    def get(self, client_id: int) -> Optional[np.ndarray]:
        return self._blocks.get(client_id)

    def put(self, client_id: int, block: np.ndarray) -> None:
        self._blocks[client_id] = block

    def __len__(self) -> int:
        return len(self._blocks)


# ---------------------------------------------------------------------------
# permutation planning (loop-order RNG parity)
# ---------------------------------------------------------------------------

@dataclass
class ClientPlan:
    """One client's precomputed shuffles for a round's local learning."""
    client: Client
    encoder_perms: Dict[str, List[np.ndarray]]   # modality -> one perm per epoch
    fusion_perms: List[np.ndarray]               # one perm per fusion epoch


def plan_permutations(clients: Sequence[Client], epochs: int,
                      rng: np.random.Generator) -> List[ClientPlan]:
    """Draw every shuffle the loop backend would draw, in its exact order:
    per client, first the encoder perms (modalities in name order, then
    epochs), then the Stage-#1 fusion perms."""
    plans = []
    for c in clients:
        n = c.train.num_samples
        eperms = {m: [rng.permutation(n) for _ in range(epochs)]
                  for m in c.modality_names}
        fperms = [rng.permutation(n) for _ in range(epochs)]
        plans.append(ClientPlan(c, eperms, fperms))
    return plans


# ---------------------------------------------------------------------------
# padded step schedule (the shared ragged-population layout)
# ---------------------------------------------------------------------------

def num_steps(n: int, batch_size: int) -> int:
    """Steps the loop backend runs for n samples: ⌊n/B⌋ full batches plus a
    trailing partial batch when B does not divide n."""
    return -(-n // batch_size)


def padded_perm_indices(perms: Sequence[np.ndarray], ns: Sequence[int],
                        steps: int, batch_size: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack per-client epoch shuffles into one [K, S·B] gather + mask.

    ``perms[k]`` permutes ``arange(ns[k])``; slots past n_k point at row 0
    and carry zero weight, so padded rows never contribute loss or gradient
    and fully-padded steps are exact no-ops."""
    kg, L = len(perms), steps * batch_size
    idx = np.zeros((kg, L), np.int64)
    w = np.zeros((kg, L), np.float32)
    for k, (p, n) in enumerate(zip(perms, ns)):
        idx[k, :n] = p
        w[k, :n] = 1.0
    return idx, w


def padded_population_batches(arrays: Sequence[Optional[np.ndarray]],
                              labels: Sequence[np.ndarray], batch_size: int,
                              *, steps: Optional[int] = None,
                              feature_shape: Optional[Tuple[int, ...]] = None
                              ) -> Dict[str, np.ndarray]:
    """Ragged per-client samples -> the padded mesh layout shared by Tier 2
    and Tier 3: ``{"x": [K, S, B, ...], "y": [K, S, B], "w": [K, S, B]}``.

    ``arrays[k] = None`` marks an absent (client, modality) pair: its slot
    carries an all-zero sample mask, so the mesh round trains a no-op dummy
    and the pair contributes nothing (its Eq. 21 weight should also be 0).
    When ``steps`` is given, clients with more than S·B samples are
    truncated to the schedule; by default S fits the largest client."""
    ns = [0 if x is None else len(x) for x in arrays]
    S = steps if steps is not None else max(
        num_steps(max(n, 1), batch_size) for n in ns)
    L = S * batch_size
    if feature_shape is not None:
        feat = tuple(feature_shape)
    else:
        ref = next((x for x in arrays if x is not None), None)
        if ref is None:
            raise ValueError("every client's array is None; pass "
                             "feature_shape to shape the dummy slots")
        feat = tuple(np.asarray(ref).shape[1:])
    K = len(arrays)
    x_out = np.zeros((K, L) + feat, np.float32)
    y_out = np.zeros((K, L), np.int32)
    w_out = np.zeros((K, L), np.float32)
    for k, (x, y) in enumerate(zip(arrays, labels)):
        if x is None:
            continue
        n = min(ns[k], L)
        x_out[k, :n] = np.asarray(x)[:n]
        y_out[k, :n] = np.asarray(y)[:n]
        w_out[k, :n] = 1.0
    return {
        "x": x_out.reshape(K, S, batch_size, *feat),
        "y": y_out.reshape(K, S, batch_size),
        "w": w_out.reshape(K, S, batch_size),
    }


# ---------------------------------------------------------------------------
# masked vmapped SGD
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("lr",))
def masked_batched_epoch(params, xs, ys, ws, lr: float):
    """One epoch of independent per-client SGD over a padded step schedule.

    params: pytree with leading K axis; xs: [K, S, B, ...]; ys: [K, S, B];
    ws: [K, S, B] 0/1 sample masks -> (new params, per-step losses [K, S]).
    Fully-padded steps produce zero gradients, i.e. no-op updates."""
    def client_epoch(p, bx, by, bw):
        def step(pp, xyw):
            x, y, w = xyw
            loss, g = jax.value_and_grad(masked_encoder_loss)(pp, x, y, w)
            return jax.tree.map(lambda a, b: a - lr * b, pp, g), loss
        return jax.lax.scan(step, p, (bx, by, bw))

    return jax.vmap(client_epoch)(params, xs, ys, ws)


@functools.partial(jax.jit, static_argnames=("lr",))
def masked_fusion_epoch(params, preds, mask, ys, ws, lr: float):
    """One epoch of per-client fusion SGD over the padded schedule.

    params: pytree with leading K axis; preds: [K, S, B, M, C];
    mask: [K, M] per-client presence; ys, ws: [K, S, B]."""
    def client_epoch(p, bp, mk, by, bw):
        def step(pp, xyw):
            x, y, w = xyw
            loss, g = jax.value_and_grad(masked_fusion_loss)(pp, x, mk, y, w)
            return jax.tree.map(lambda a, b: a - lr * b, pp, g), loss
        return jax.lax.scan(step, p, (bp, by, bw))

    return jax.vmap(client_epoch)(params, preds, mask, ys, ws)


# ---------------------------------------------------------------------------
# bucket planner
# ---------------------------------------------------------------------------

def _shape_family(c: Client, m: str, batch_size: int) -> Tuple:
    """Coarse bucket key for one (client, modality) pair: feature shape,
    class count, and schedule length S = ⌈n/B⌉ — never the modality set,
    the modality name, or the exact sample count. Keying on S (instead of
    padding every pair up to the largest client) bounds the padding waste
    at one batch per pair while keeping buckets coarse: a skewed population
    fragments into at most max(S) schedule groups, not K singletons."""
    return (tuple(np.asarray(c.train.modalities[m]).shape[1:]),
            c.spec.num_classes,
            num_steps(c.train.num_samples, batch_size))


def _fusion_key(c: Client, batch_size: Optional[int] = None) -> Tuple:
    """Fusion modules stack iff their input layout matches; training
    buckets additionally key on the schedule length (see _shape_family)."""
    key = (tuple(c.all_modalities), c.spec.num_classes, c.fusion_input)
    if batch_size is not None:
        key += (num_steps(c.train.num_samples, batch_size),)
    return key


def _fusion_buckets(clients: Sequence[Client],
                    batch_size: Optional[int] = None) -> List[List[int]]:
    groups: Dict[Tuple, List[int]] = {}
    for i, c in enumerate(clients):
        groups.setdefault(_fusion_key(c, batch_size), []).append(i)
    return [groups[k] for k in sorted(groups)]


# ---------------------------------------------------------------------------
# population encoder training
# ---------------------------------------------------------------------------

def train_population_encoders(plans: Sequence[ClientPlan], *, epochs: int,
                              lr: float, batch_size: int, store=None,
                              train_impl: str = "fused") -> None:
    """Local Learning's encoder phase for the whole (client, modality)
    population, bucketed by coarse shape family.

    Mirrors ``Client.train_encoders`` exactly on the real samples: E epochs,
    each a padded [S, B] schedule whose real slots are the loop's ⌊n/B⌋ full
    batches plus trailing partial batch, with per-epoch shuffles from the
    plan; caches the final-epoch mean loss ℓ_m^k per (client, modality).
    ``train_impl="fused"`` runs all E epochs as one donated program per
    bucket; ``"reference"`` dispatches one program per epoch."""
    store = store or _default_store()
    for p in plans:
        p.client.losses = {}
    buckets: Dict[Tuple, List[Tuple[ClientPlan, str]]] = {}
    for p in plans:
        for m in p.client.modality_names:
            buckets.setdefault(_shape_family(p.client, m, batch_size),
                               []).append((p, m))
    for key in sorted(buckets, key=repr):
      pairs = buckets[key]
      with telemetry.span("train.encoder", clients=len(pairs),
                          impl=train_impl):
        clients = [p.client for p, _ in pairs]
        mods = [m for _, m in pairs]
        kg = len(pairs)
        ns = [c.train.num_samples for c in clients]
        n_max = max(ns)
        steps = max(num_steps(n, batch_size) for n in ns)
        stacked = store.gather_encoders(list(zip(clients, mods)))
        x = np.stack([c.padded_modality(c.train, m, n_max)
                      for c, m in zip(clients, mods)])
        y = np.stack([c.padded_labels(c.train, n_max) for c in clients])
        gather = np.arange(kg)[:, None]
        last = np.zeros((kg, steps), np.float64)     # epochs == 0 -> loss 0.0
        valid = np.zeros((kg, steps), bool)
        le = None
        if train_impl == "fused" and epochs:
            idx_w = [padded_perm_indices(
                [p.encoder_perms[m][e] for p, m in pairs], ns, steps,
                batch_size) for e in range(epochs)]
            idx = np.stack([iw[0] for iw in idx_w], axis=1)  # [kg, E, L]
            w = np.stack([iw[1] for iw in idx_w], axis=1)
            xe = x[gather[:, None], idx].reshape(
                kg, epochs, steps, batch_size, *x.shape[2:])
            ye = y[gather[:, None], idx].reshape(
                kg, epochs, steps, batch_size)
            ws = w.reshape(kg, epochs, steps, batch_size)
            valid = ws[:, -1].sum(axis=-1) > 0
            hostsync.record_dispatch()
            # `stacked` is donated: with a resident store this updates the
            # population bucket in place (scatter below re-binds it)
            stacked, le = fused_encoder_round(stacked, jnp.asarray(xe),
                                              jnp.asarray(ye),
                                              jnp.asarray(ws), lr)
        else:
            for e in range(epochs):
                idx, w = padded_perm_indices(
                    [p.encoder_perms[m][e] for p, m in pairs], ns, steps,
                    batch_size)
                xe = x[gather, idx].reshape(kg, steps, batch_size,
                                            *x.shape[2:])
                ye = y[gather, idx].reshape(kg, steps, batch_size)
                ws = w.reshape(kg, steps, batch_size)
                valid = ws.sum(axis=-1) > 0
                hostsync.record_dispatch()
                stacked, le = masked_batched_epoch(stacked, jnp.asarray(xe),
                                                   jnp.asarray(ye),
                                                   jnp.asarray(ws), lr)
        if le is not None:
            # ℓ_m^k is the FINAL epoch's losses: one fetch after the loop,
            # not one blocking sync per epoch
            last = hostsync.fetch(le).astype(np.float64)
        store.scatter_encoders(list(zip(clients, mods)), stacked)
        for j, ((p, m), c) in enumerate(zip(pairs, clients)):
            c.losses[m] = float(last[j, valid[j]].mean()) if epochs else 0.0


# ---------------------------------------------------------------------------
# population predictions + fusion training
# ---------------------------------------------------------------------------

@jax.jit
def _batched_predict(stacked_params, xs):
    return jax.vmap(enc.encoder_predict)(stacked_params, xs)


@jax.jit
def _batched_predict_probs(stacked_params, xs):
    return jax.vmap(enc.encoder_predict_probs)(stacked_params, xs)


def _population_predictions(clients: Sequence[Client], datas, store=None,
                            cache: Optional[PredictionCache] = None
                            ) -> np.ndarray:
    """Stacked ``Client.predictions``: [K, n_pad, M, C] with zero columns at
    absent (client, modality) pairs, padded over the sample axis.

    Encoder forwards batch by shape family across clients, so structurally
    missing modalities cost nothing — they are zeros by construction, exactly
    the loop's convention (padded rows carry garbage predictions and are
    excluded downstream by sample masks). With a ``cache`` (train split
    only — the caller guarantees ``datas`` are the splits the cache was
    built over), clients whose block is already cached skip their forward
    entirely; fresh blocks are stored back, so the second consumer of a
    round's train-split predictions (the Shapley enumeration) dispatches
    zero encoder programs. Rows past a cached client's n_k stay zero where
    an uncached forward leaves padded garbage — both are excluded by the
    sample masks everywhere downstream."""
    store = store or _default_store()
    c0 = clients[0]
    M, C = len(c0.all_modalities), c0.spec.num_classes
    n_pad = max(d.num_samples for d in datas)
    out = np.zeros((len(clients), n_pad, M, C), np.float32)
    hits = set()
    buckets: Dict[Tuple, List[Tuple[int, int, Client, object, str]]] = {}
    for k, (c, d) in enumerate(zip(clients, datas)):
        block = cache.get(c.client_id) if cache is not None else None
        if block is not None:
            n = min(block.shape[0], n_pad)
            out[k, :n] = block[:n]
            hits.add(k)
            continue
        for mi, m in enumerate(c.all_modalities):
            if m in c.encoders and m in d.modalities:
                key = (tuple(np.asarray(d.modalities[m]).shape[1:]), C)
                buckets.setdefault(key, []).append((k, mi, c, d, m))
    fn = (_batched_predict_probs if c0.fusion_input == "probs"
          else _batched_predict)
    for key in sorted(buckets, key=repr):
        entries = buckets[key]
        with telemetry.span("predict", clients=len(entries)):
            stacked = store.gather_encoders(
                [(c, m) for _, _, c, _, m in entries])
            xs = jnp.asarray(np.stack([c.padded_modality(d, m, n_pad)
                                       for _, _, c, d, m in entries]))
            hostsync.record_dispatch()
            pr = hostsync.fetch(fn(stacked, xs))     # [Kg, n_pad, C]
            for j, (k, mi, *_rest) in enumerate(entries):
                out[k, :, mi] = pr[j]
    if cache is not None:
        for k, (c, d) in enumerate(zip(clients, datas)):
            if k not in hits:
                cache.put(c.client_id, out[k, :d.num_samples].copy())
    return out


def train_population_fusion(clients: Sequence[Client],
                            perms: Sequence[Sequence[np.ndarray]], *,
                            epochs: int, lr: float, batch_size: int,
                            store=None, train_impl: str = "fused",
                            cache: Optional[PredictionCache] = None) -> None:
    """Stage-#1/#2 fusion training for one fusion bucket, batched.

    Mirrors ``Client.train_fusion``: predictions computed once with frozen
    encoders (through the round's :class:`PredictionCache` when given, so
    Shapley can reuse them), then E epochs of planned-shuffle minibatch SGD
    over the padded schedule, each client gated by its own [M] presence
    mask — one donated program (``"fused"``) or one launch per epoch
    (``"reference"``)."""
    store = store or _default_store()
    with telemetry.span("train.fusion", clients=len(clients),
                        impl=train_impl):
        preds = _population_predictions(clients,
                                        [c.train for c in clients],
                                        store, cache=cache)
        n_pad = preds.shape[1]
        y = np.stack([c.padded_labels(c.train, n_pad) for c in clients])
        presence = jnp.asarray(np.stack([c.avail_mask() for c in clients]))
        ns = [c.train.num_samples for c in clients]
        steps = max(num_steps(n, batch_size) for n in ns)
        stacked = store.gather_fusion(clients)
        kg = len(clients)
        gather = np.arange(kg)[:, None]
        if train_impl == "fused" and epochs:
            idx_w = [padded_perm_indices([p[e] for p in perms], ns, steps,
                                         batch_size) for e in range(epochs)]
            idx = np.stack([iw[0] for iw in idx_w], axis=1)  # [kg, E, L]
            w = np.stack([iw[1] for iw in idx_w], axis=1)
            pe = preds[gather[:, None], idx].reshape(
                kg, epochs, steps, batch_size, *preds.shape[2:])
            ye = y[gather[:, None], idx].reshape(kg, epochs, steps,
                                                 batch_size)
            ws = w.reshape(kg, epochs, steps, batch_size)
            hostsync.record_dispatch()
            stacked, _ = fused_fusion_round(stacked, jnp.asarray(pe),
                                            presence, jnp.asarray(ye),
                                            jnp.asarray(ws), lr)
        else:
            for e in range(epochs):
                idx, w = padded_perm_indices([p[e] for p in perms], ns,
                                             steps, batch_size)
                pe = preds[gather, idx].reshape(kg, steps, batch_size,
                                                *preds.shape[2:])
                ye = y[gather, idx].reshape(kg, steps, batch_size)
                ws = w.reshape(kg, steps, batch_size)
                hostsync.record_dispatch()
                stacked, _ = masked_fusion_epoch(stacked, jnp.asarray(pe),
                                                 presence, jnp.asarray(ye),
                                                 jnp.asarray(ws), lr)
        store.scatter_fusion(clients, stacked)


# ---------------------------------------------------------------------------
# Algorithm 1 phases, batched
# ---------------------------------------------------------------------------

def batched_local_learning(clients: Sequence[Client], cfg,
                           rng: np.random.Generator, store=None,
                           cache: Optional[PredictionCache] = None) -> None:
    """Algorithm 1's Local Learning phase, batched end-to-end.

    1. plan all shuffles (loop-order RNG parity);
    2. encoder populations train per coarse shape family — ragged clients
       included, no per-client fallback;
    3. Stage-#1 fusion trains per fusion bucket with presence masks,
       filling the round's prediction ``cache`` for Shapley to reuse."""
    store = store or _default_store()
    impl = getattr(cfg, "train_impl", "fused")
    plans = plan_permutations(clients, cfg.local_epochs, rng)
    train_population_encoders(plans, epochs=cfg.local_epochs,
                              lr=cfg.lr_encoder, batch_size=cfg.batch_size,
                              store=store, train_impl=impl)
    for idxs in _fusion_buckets(clients, cfg.batch_size):
        train_population_fusion([clients[i] for i in idxs],
                                [plans[i].fusion_perms for i in idxs],
                                epochs=cfg.local_epochs, lr=cfg.lr_fusion,
                                batch_size=cfg.batch_size, store=store,
                                train_impl=impl, cache=cache)


def batched_fusion_stage(clients: Sequence[Client], cfg,
                         rng: np.random.Generator, store=None) -> None:
    """Stage-#2 fusion fine-tune (Local Deploying), batched.

    Draws the per-client epoch shuffles in client order first — the same
    order the loop backend consumes ``rng`` — then trains fusion buckets
    stacked with presence masks."""
    store = store or _default_store()
    impl = getattr(cfg, "train_impl", "fused")
    perms = [[rng.permutation(c.train.num_samples)
              for _ in range(cfg.local_epochs)] for c in clients]
    for idxs in _fusion_buckets(clients, cfg.batch_size):
        train_population_fusion([clients[i] for i in idxs],
                                [perms[i] for i in idxs],
                                epochs=cfg.local_epochs, lr=cfg.lr_fusion,
                                batch_size=cfg.batch_size, store=store,
                                train_impl=impl)


# ---------------------------------------------------------------------------
# population Shapley + evaluation
# ---------------------------------------------------------------------------

def batched_shapley_values(clients: Sequence[Client], background_size: int,
                           eval_size: int, rng: np.random.Generator,
                           store=None,
                           cache: Optional[PredictionCache] = None
                           ) -> Dict[int, np.ndarray]:
    """Exact interventional Shapley for a whole population: one vmapped 2^M
    enumeration per fusion bucket instead of one per client per round.

    Draws each client's background/eval subsets from ``rng`` in client order
    — exactly the draws ``Client.shapley_values`` makes in the loop backend,
    so both backends leave the generator in the same state. With the
    round's ``cache``, the train-split encoder forwards Stage-#1 already
    ran are reused instead of recomputed. Returns {client_id: φ over that
    client's modality_names}."""
    store = store or _default_store()
    draws = []
    for c in clients:
        n = c.train.num_samples
        bg = np.asarray(rng.choice(n, size=min(background_size, n),
                                   replace=False))
        ev = np.asarray(rng.choice(n, size=min(eval_size, n), replace=False))
        draws.append((bg, ev))
    out: Dict[int, np.ndarray] = {}
    for idxs in _fusion_buckets(clients):
        cs = [clients[i] for i in idxs]
        kg = len(cs)
        M = len(cs[0].all_modalities)
        preds = _population_predictions(cs, [c.train for c in cs], store,
                                        cache=cache)
        n_pad = preds.shape[1]
        g_max = max(len(draws[i][0]) for i in idxs)
        b_max = max(len(draws[i][1]) for i in idxs)
        bg_idx = np.zeros((kg, g_max), np.int64)
        bg_w = np.zeros((kg, g_max), np.float32)
        ev_idx = np.zeros((kg, b_max), np.int64)
        ev_w = np.zeros((kg, b_max), np.float32)
        for j, i in enumerate(idxs):
            bg, ev = draws[i]
            bg_idx[j, :len(bg)] = bg
            bg_w[j, :len(bg)] = 1.0
            ev_idx[j, :len(ev)] = ev
            ev_w[j, :len(ev)] = 1.0
        gather = np.arange(kg)[:, None]
        y = np.stack([c.padded_labels(c.train, n_pad) for c in cs])
        avail = np.stack([c.avail_mask() for c in cs])
        hostsync.record_dispatch()
        phi = hostsync.fetch(exact_shapley_population(
            store.gather_fusion(cs),
            jnp.asarray(preds[gather, ev_idx]),
            jnp.asarray(preds[gather, bg_idx]),
            jnp.asarray(avail), jnp.asarray(y[gather, ev_idx]),
            jnp.asarray(ev_w), jnp.asarray(bg_w), num_modalities=M))
        for j, c in enumerate(cs):
            out[c.client_id] = np.array(
                [phi[j][c.all_modalities.index(m)]
                 for m in c.modality_names])
    return out


@jax.jit
def _batched_fusion_eval(params, preds, mask, y, w):
    return jax.vmap(masked_fusion_eval)(params, preds, mask, y, w)


def batched_evaluate(clients: Sequence[Client],
                     store=None) -> Tuple[float, float]:
    """Sample-weighted (accuracy, loss) over every client's test split — the
    batched replacement for the per-client ``Client.evaluate`` loop, padded
    over test-set sizes and gated by presence masks."""
    store = store or _default_store()
    tot, acc_sum, loss_sum = 0.0, 0.0, 0.0
    for idxs in _fusion_buckets(clients):
        cs = [clients[i] for i in idxs]
        datas = [c.test for c in cs]
        preds = _population_predictions(cs, datas, store)
        n_pad = preds.shape[1]
        y = np.stack([c.padded_labels(d, n_pad) for c, d in zip(cs, datas)])
        w = np.stack([c.sample_mask(d, n_pad) for c, d in zip(cs, datas)])
        presence = np.stack([c.avail_mask() for c in cs])
        hostsync.record_dispatch()
        loss, acc = _batched_fusion_eval(
            store.gather_fusion(cs), jnp.asarray(preds),
            jnp.asarray(presence), jnp.asarray(y), jnp.asarray(w))
        ns = np.array([d.num_samples for d in datas], np.float64)
        tot += float(ns.sum())
        acc_sum += float(hostsync.fetch(acc).astype(np.float64) @ ns)
        loss_sum += float(hostsync.fetch(loss).astype(np.float64) @ ns)
    return acc_sum / max(tot, 1.0), loss_sum / max(tot, 1.0)

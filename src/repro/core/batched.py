"""Batched local learning — the simulator's hot path as vmapped SGD.

``run_federation(backend="batched")`` replaces Algorithm 1's per-client
Python loop (Local Learning) with a stacked computation: clients with the
same *training signature* — modality set, per-modality array shapes (which
include the sample count) — are packed onto a leading K axis and each
modality's encoder population trains with one jit'd ``vmap(scan(sgd_step))``
per epoch. This is exactly the client-stacked layout the mesh engine
(``repro.core.distributed``) shards over the ``data`` axis, so the simulator
fast path and the datacenter round are the same program at different scales.

Clients whose signature nobody else shares (ragged federations: structural
missing modalities, skewed sample counts) fall back to the per-client loop —
semantics are identical either way.

RNG parity: the loop backend draws one ``rng.permutation(n)`` per
(client, modality, epoch) and per (client, fusion-epoch), interleaved in
client order. :func:`plan_permutations` precomputes exactly that sequence up
front, so both backends consume the shared generator identically — every
downstream phase (Shapley subsampling, random strategies, availability) sees
bit-identical randomness, and round-1 aggregates match the loop backend to
float tolerance (the parity test pins this at 1e-5).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoders as enc
from repro.core.client import Client
from repro.core.encoders import encoder_loss
from repro.core.fusion import fusion_loss


@dataclass
class ClientPlan:
    """One client's precomputed shuffles for a round's local learning."""
    client: Client
    encoder_perms: Dict[str, List[np.ndarray]]   # modality -> one perm per epoch
    fusion_perms: List[np.ndarray]               # one perm per fusion epoch


def plan_permutations(clients: Sequence[Client], epochs: int,
                      rng: np.random.Generator) -> List[ClientPlan]:
    """Draw every shuffle the loop backend would draw, in its exact order:
    per client, first the encoder perms (modalities in name order, then
    epochs), then the Stage-#1 fusion perms."""
    plans = []
    for c in clients:
        n = c.train.num_samples
        eperms = {m: [rng.permutation(n) for _ in range(epochs)]
                  for m in c.modality_names}
        fperms = [rng.permutation(n) for _ in range(epochs)]
        plans.append(ClientPlan(c, eperms, fperms))
    return plans


def _signature(c: Client) -> Tuple:
    """Clients pack together iff every modality array has identical shape."""
    return tuple((m, c.train.modalities[m].shape) for m in c.modality_names)


@functools.partial(jax.jit, static_argnames=("lr",))
def batched_epoch(params, xs, ys, lr: float):
    """One epoch of independent per-client SGD over stacked full batches.

    params: pytree with leading K axis; xs: [K, S, B, ...]; ys: [K, S, B]
    -> (new params, per-step losses [K, S])
    """
    def client_epoch(p, bx, by):
        def step(pp, xy):
            x, y = xy
            loss, g = jax.value_and_grad(encoder_loss)(pp, x, y)
            return jax.tree.map(lambda a, b: a - lr * b, pp, g), loss
        return jax.lax.scan(step, p, (bx, by))

    return jax.vmap(client_epoch)(params, xs, ys)


@functools.partial(jax.jit, static_argnames=("lr",))
def batched_step(params, x, y, lr: float):
    """One vmapped SGD step (the epoch's trailing partial batch).

    params: pytree with leading K axis; x: [K, r, ...]; y: [K, r]
    -> (new params, losses [K])
    """
    def one(p, xx, yy):
        loss, g = jax.value_and_grad(encoder_loss)(p, xx, yy)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), loss

    return jax.vmap(one)(params, x, y)


def train_group_encoders(plans: Sequence[ClientPlan], *, epochs: int,
                         lr: float, batch_size: int) -> None:
    """Train one signature-group's encoders batched, per modality.

    Mirrors ``Client.train_encoders`` exactly: E epochs, each a sequence of
    ⌊n/B⌋ full batches plus one trailing partial batch, per-epoch shuffles
    from the plan; caches the final-epoch mean loss ℓ_m^k per client.
    """
    clients = [p.client for p in plans]
    for c in clients:
        c.losses = {}
    for m in clients[0].modality_names:
        stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                               *[c.encoders[m] for c in clients])
        x = np.stack([np.asarray(c.train.modalities[m]) for c in clients])
        y = np.stack([np.asarray(c.train.labels) for c in clients])
        kg, n = x.shape[0], x.shape[1]
        full, rem = divmod(n, batch_size)
        gather = np.arange(kg)[:, None]
        last = np.zeros((kg, 1), np.float64)     # epochs == 0 -> loss 0.0
        for e in range(epochs):
            idx = np.stack([p.encoder_perms[m][e] for p in plans])
            xe, ye = x[gather, idx], y[gather, idx]
            step_losses = []
            if full:
                xf = jnp.asarray(xe[:, :full * batch_size].reshape(
                    kg, full, batch_size, *x.shape[2:]))
                yf = jnp.asarray(ye[:, :full * batch_size].reshape(
                    kg, full, batch_size))
                stacked, lf = batched_epoch(stacked, xf, yf, lr)
                step_losses.append(np.asarray(lf, np.float64))
            if rem:
                xr = jnp.asarray(xe[:, full * batch_size:])
                yr = jnp.asarray(ye[:, full * batch_size:])
                stacked, lp = batched_step(stacked, xr, yr, lr)
                step_losses.append(np.asarray(lp, np.float64)[:, None])
            last = np.concatenate(step_losses, axis=1)
        for k, c in enumerate(clients):
            c.encoders[m] = jax.tree.map(lambda v: v[k], stacked)
            c.losses[m] = float(np.mean(last[k]))


@functools.partial(jax.jit, static_argnames=("lr",))
def batched_fusion_epoch(params, preds, mask, ys, lr: float):
    """One epoch of per-client fusion SGD over stacked full batches.

    params: pytree with leading K axis; preds: [K, S, B, M, C];
    mask: [M] (identical within a signature group); ys: [K, S, B]
    """
    def client_epoch(p, bp, by):
        def step(pp, xy):
            x, y = xy
            loss, g = jax.value_and_grad(fusion_loss)(pp, x, mask, y)
            return jax.tree.map(lambda a, b: a - lr * b, pp, g), loss
        return jax.lax.scan(step, p, (bp, by))

    return jax.vmap(client_epoch)(params, preds, ys)


@functools.partial(jax.jit, static_argnames=("lr",))
def batched_fusion_step(params, preds, mask, y, lr: float):
    def one(p, xx, yy):
        loss, g = jax.value_and_grad(fusion_loss)(p, xx, mask, yy)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), loss

    return jax.vmap(one)(params, preds, y)


@jax.jit
def _batched_predict(stacked_params, xs):
    return jax.vmap(enc.encoder_predict)(stacked_params, xs)


@jax.jit
def _batched_predict_probs(stacked_params, xs):
    return jax.vmap(enc.encoder_predict_probs)(stacked_params, xs)


def _group_predictions(clients: Sequence[Client]) -> np.ndarray:
    """Stacked ``Client.predictions`` for one signature group: [K, n, M, C]
    with zero columns at absent modalities (one-hot predictions are argmax
    outputs, so the vmapped forward matches the per-client one bitwise up
    to logit ties)."""
    c0 = clients[0]
    n = c0.train.num_samples
    nc = c0.spec.num_classes
    cols = []
    for m in c0.all_modalities:
        if m in c0.encoders and m in c0.train.modalities:
            stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                                   *[c.encoders[m] for c in clients])
            xs = jnp.asarray(np.stack(
                [np.asarray(c.train.modalities[m]) for c in clients]))
            fn = (_batched_predict_probs if c0.fusion_input == "probs"
                  else _batched_predict)
            cols.append(np.asarray(fn(stacked, xs)))
        else:
            cols.append(np.zeros((len(clients), n, nc), np.float32))
    return np.stack(cols, axis=2)                        # [K, n, M, C]


def train_group_fusion(clients: Sequence[Client],
                       perms: Sequence[Sequence[np.ndarray]], *,
                       epochs: int, lr: float, batch_size: int) -> None:
    """One signature-group's Stage-#1/#2 fusion training, batched.

    Mirrors ``Client.train_fusion``: predictions computed once with frozen
    encoders, then E epochs of planned-shuffle minibatch SGD.
    """
    preds = _group_predictions(clients)                  # [K, n, M, C]
    y = np.stack([np.asarray(c.train.labels) for c in clients])
    mask = jnp.asarray(clients[0].avail_mask())
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                           *[c.fusion for c in clients])
    kg, n = y.shape
    full, rem = divmod(n, batch_size)
    gather = np.arange(kg)[:, None]
    for e in range(epochs):
        idx = np.stack([p[e] for p in perms])
        pe, ye = preds[gather, idx], y[gather, idx]
        if full:
            pf = jnp.asarray(pe[:, :full * batch_size].reshape(
                kg, full, batch_size, *preds.shape[2:]))
            yf = jnp.asarray(ye[:, :full * batch_size].reshape(
                kg, full, batch_size))
            stacked, _ = batched_fusion_epoch(stacked, pf, mask, yf, lr)
        if rem:
            pr = jnp.asarray(pe[:, full * batch_size:])
            yr = jnp.asarray(ye[:, full * batch_size:])
            stacked, _ = batched_fusion_step(stacked, pr, mask, yr, lr)
    for k, c in enumerate(clients):
        c.fusion = jax.tree.map(lambda v: v[k], stacked)


def _grouped(plans: Sequence[ClientPlan]) -> Dict[Tuple, List[ClientPlan]]:
    groups: Dict[Tuple, List[ClientPlan]] = {}
    for p in plans:
        groups.setdefault(_signature(p.client), []).append(p)
    return groups


def batched_local_learning(clients: Sequence[Client], cfg,
                           rng: np.random.Generator, *,
                           min_group: int = 2) -> None:
    """Algorithm 1's Local Learning phase, batched.

    1. plan all shuffles (loop-order RNG parity);
    2. group clients by training signature; groups of ≥ ``min_group`` train
       encoders stacked, singletons fall back to the per-client loop;
    3. Stage-#1 fusion, batched per group the same way.
    """
    plans = plan_permutations(clients, cfg.local_epochs, rng)
    groups = _grouped(plans)
    for plist in groups.values():
        if len(plist) < min_group:
            for p in plist:
                p.client.train_encoders(cfg.local_epochs, cfg.lr_encoder,
                                        cfg.batch_size, None,
                                        perms=p.encoder_perms)
        else:
            train_group_encoders(plist, epochs=cfg.local_epochs,
                                 lr=cfg.lr_encoder,
                                 batch_size=cfg.batch_size)
    for plist in groups.values():
        if len(plist) < min_group:
            for p in plist:
                p.client.train_fusion(cfg.local_epochs, cfg.lr_fusion,
                                      cfg.batch_size, None,
                                      perms=p.fusion_perms)
        else:
            train_group_fusion([p.client for p in plist],
                               [p.fusion_perms for p in plist],
                               epochs=cfg.local_epochs, lr=cfg.lr_fusion,
                               batch_size=cfg.batch_size)


def batched_fusion_stage(clients: Sequence[Client], cfg,
                         rng: np.random.Generator, *,
                         min_group: int = 2) -> None:
    """Stage-#2 fusion fine-tune (Local Deploying), batched.

    Draws the per-client epoch shuffles in client order first — the same
    order the loop backend consumes ``rng`` — then trains signature groups
    stacked."""
    perms = [[rng.permutation(c.train.num_samples)
              for _ in range(cfg.local_epochs)] for c in clients]
    groups: Dict[Tuple, List[int]] = {}
    for i, c in enumerate(clients):
        groups.setdefault(_signature(c), []).append(i)
    for idxs in groups.values():
        if len(idxs) < min_group:
            for i in idxs:
                clients[i].train_fusion(cfg.local_epochs, cfg.lr_fusion,
                                        cfg.batch_size, None, perms=perms[i])
        else:
            train_group_fusion([clients[i] for i in idxs],
                               [perms[i] for i in idxs],
                               epochs=cfg.local_epochs, lr=cfg.lr_fusion,
                               batch_size=cfg.batch_size)

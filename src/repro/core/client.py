"""Client-side state and local training (Algorithm 1: Local Learning,
Stage-1/Stage-2 fusion training, Shapley evaluation inputs).

A :class:`Client` owns: its local train/test split, one encoder per available
modality, the strictly-local fusion module, a recency tracker, and the cached
per-modality losses the server uses for client selection.

Encoders for every modality are trained in parallel conceptually; on the CPU
simulator they run sequentially but each step is jit-compiled. The fusion
module consumes *definitive predicted categories* (one-hot, §4.2) by default.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoders as enc
from repro.core import fusion as fus
from repro.core import hostsync
from repro.core.selection import RecencyTracker
from repro.core.shapley import exact_shapley
from repro.data.registry import DatasetSpec
from repro.data.synthetic import ClientData


@dataclass
class Client:
    client_id: int
    spec: DatasetSpec
    train: ClientData
    test: ClientData
    encoders: Dict[str, Dict]            # modality -> encoder params
    fusion: Dict                          # fusion MLP params (local only)
    recency: RecencyTracker
    losses: Dict[str, float] = field(default_factory=dict)
    fusion_input: str = "onehot"          # onehot | probs
    # §4.10 error-feedback residuals: modality -> client-held accumulator of
    # the quantization error its low-bit uplinks could not carry (strictly
    # local, like the fusion module; populated only when error feedback is
    # enabled in the round config)
    residuals: Dict[str, Dict] = field(default_factory=dict)

    # ------------------------------------------------------------------
    @property
    def modality_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.encoders))

    @property
    def all_modalities(self) -> Tuple[str, ...]:
        return self.spec.modality_names

    def avail_mask(self) -> np.ndarray:
        return np.array([1.0 if m in self.encoders else 0.0
                         for m in self.all_modalities], np.float32)

    def num_samples(self, modality: str) -> int:
        return self.train.num_samples if modality in self.encoders else 0

    # ------------------------------------------------------------------
    # padded population views — the ragged-federation layout shared by the
    # batched simulator (repro.core.batched) and the mesh engine: clients
    # stack on a leading K axis regardless of modality set or sample count,
    # with zero-padding up to the population width plus 0/1 sample masks.
    def padded_modality(self, data: ClientData, modality: str,
                        n_pad: int) -> np.ndarray:
        """[n_pad, ...] zero-padded view of one modality's samples."""
        x = np.asarray(data.modalities[modality])
        if x.shape[0] == n_pad:
            return x
        out = np.zeros((n_pad,) + x.shape[1:], x.dtype)
        out[:x.shape[0]] = x
        return out

    def padded_labels(self, data: ClientData, n_pad: int) -> np.ndarray:
        """[n_pad] labels, zero-filled past the client's real samples."""
        y = np.asarray(data.labels)
        if y.shape[0] == n_pad:
            return y
        out = np.zeros((n_pad,), y.dtype)
        out[:y.shape[0]] = y
        return out

    def sample_mask(self, data: ClientData, n_pad: int) -> np.ndarray:
        """[n_pad] float32 mask: 1 on real samples, 0 on padding."""
        w = np.zeros((n_pad,), np.float32)
        w[:data.num_samples] = 1.0
        return w

    # ------------------------------------------------------------------
    def _batches(self, data: ClientData, modality: str, batch_size: int,
                 rng: Optional[np.random.Generator], perm=None):
        x = data.modalities[modality]
        y = data.labels
        n = len(y)
        idx = rng.permutation(n) if perm is None else np.asarray(perm)
        for i in range(0, n, batch_size):
            sel = idx[i:i + batch_size]
            if len(sel) == 0:
                continue
            yield jnp.asarray(x[sel]), jnp.asarray(y[sel])

    def train_encoders(self, epochs: int, lr: float, batch_size: int,
                       rng: Optional[np.random.Generator], *,
                       perms: Optional[Dict[str, List[np.ndarray]]] = None
                       ) -> Dict[str, float]:
        """E epochs of SGD per modality encoder (Eq. 6). Returns and caches
        the final-epoch mean loss ℓ_m^k per modality.

        ``perms`` — optional precomputed shuffles, ``{modality: [perm] * E}``
        (the batched backend plans all permutations up front so both backends
        consume the shared generator in the same order); when given, ``rng``
        is not touched."""
        out: Dict[str, float] = {}
        for m in self.modality_names:
            params = self.encoders[m]
            last = 0.0
            for e in range(epochs):
                perm = None if perms is None else perms[m][e]
                losses = []
                for xb, yb in self._batches(self.train, m, batch_size, rng,
                                            perm=perm):
                    params, loss = enc.encoder_sgd_step(params, xb, yb, lr=lr)
                    losses.append(hostsync.fetch_scalar(loss))
                last = float(np.mean(losses)) if losses else 0.0
            self.encoders[m] = params
            out[m] = last
        self.losses = out
        return out

    # ------------------------------------------------------------------
    def predictions(self, data: ClientData) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Stacked per-modality predictions for the fusion module.

        Returns (preds [B, M, C] with zeros at absent modalities,
        labels [B])."""
        c = self.spec.num_classes
        b = data.num_samples
        cols = []
        for m in self.all_modalities:
            if m in self.encoders and m in data.modalities:
                x = jnp.asarray(data.modalities[m])
                if self.fusion_input == "probs":
                    cols.append(enc.encoder_predict_probs(self.encoders[m], x))
                else:
                    cols.append(enc.encoder_predict(self.encoders[m], x))
            else:
                cols.append(jnp.zeros((b, c), jnp.float32))
        return jnp.stack(cols, axis=1), jnp.asarray(data.labels)

    def train_fusion(self, epochs: int, lr: float, batch_size: int,
                     rng: Optional[np.random.Generator], *,
                     perms: Optional[List[np.ndarray]] = None) -> float:
        """Train ω^k with frozen encoders (Stage #1 / Stage #2).

        ``perms`` — optional precomputed shuffles (one per epoch); when
        given, ``rng`` is not touched."""
        preds, y = self.predictions(self.train)
        mask = jnp.asarray(self.avail_mask())
        n = preds.shape[0]
        last = 0.0
        for e in range(epochs):
            idx = rng.permutation(n) if perms is None else np.asarray(perms[e])
            losses = []
            for i in range(0, n, batch_size):
                sel = jnp.asarray(idx[i:i + batch_size])
                self.fusion, loss = fus.fusion_sgd_step(
                    self.fusion, preds[sel], mask, y[sel], lr=lr)
                losses.append(hostsync.fetch_scalar(loss))
            last = float(np.mean(losses)) if losses else 0.0
        return last

    # ------------------------------------------------------------------
    def shapley_values(self, background_size: int = 50,
                       eval_size: int = 32,
                       rng: Optional[np.random.Generator] = None
                       ) -> np.ndarray:
        """Exact interventional Shapley φ per modality (absent → 0)."""
        rng = rng or np.random.default_rng(self.client_id)
        preds, y = self.predictions(self.train)
        n = preds.shape[0]
        bg_idx = jnp.asarray(rng.choice(n, size=min(background_size, n),
                                        replace=False))
        ev_idx = jnp.asarray(rng.choice(n, size=min(eval_size, n),
                                        replace=False))
        phi = exact_shapley(
            self.fusion, preds[ev_idx], preds[bg_idx],
            jnp.asarray(self.avail_mask()), y[ev_idx],
            num_modalities=len(self.all_modalities))
        full = hostsync.fetch(phi)
        # report only over available modalities, in name order
        return np.array([full[self.all_modalities.index(m)]
                         for m in self.modality_names])

    def encoder_sizes(self, bits: int = 32) -> np.ndarray:
        return np.array([enc.encoder_bytes(self.encoders[m], bits)
                         for m in self.modality_names], np.float64)

    # ------------------------------------------------------------------
    def evaluate(self) -> Tuple[float, float, int]:
        """(fusion test loss, fusion test accuracy, n_test)."""
        preds, y = self.predictions(self.test)
        loss, acc = fus.fusion_eval(self.fusion, preds,
                                    jnp.asarray(self.avail_mask()), y)
        return (hostsync.fetch_scalar(loss), hostsync.fetch_scalar(acc),
                int(y.shape[0]))

    def evaluate_encoder(self, modality: str) -> Tuple[float, float]:
        x = jnp.asarray(self.test.modalities[modality])
        y = jnp.asarray(self.test.labels)
        loss, acc = enc.encoder_eval(self.encoders[modality], x, y)
        return float(loss), float(acc)

    def install_global(self, modality: str, params: Dict) -> None:
        """Download + deploy a global encoder (Local Deploying)."""
        if modality in self.encoders:
            self.encoders[modality] = jax.tree.map(jnp.asarray, params)


def make_client(client_id: int, spec: DatasetSpec, data: ClientData,
                *, seed: int = 0, split: float = 0.8,
                fusion_input: str = "onehot") -> Client:
    train, test = data.split(split, seed=seed)
    rng = jax.random.key(seed * 100003 + client_id)
    ks = jax.random.split(rng, len(data.modality_names) + 1)
    encs = {}
    for i, m in enumerate(data.modality_names):
        shape = spec.modality(m).feature_shape(True)
        # actual array shape wins (reduced/full agnostic)
        shape = data.modalities[m].shape[1:]
        encs[m] = enc.init_encoder(ks[i], shape, spec.num_classes)
    fusion = fus.init_fusion(ks[-1], len(spec.modality_names),
                             spec.num_classes)
    return Client(client_id, spec, train, test, encs, fusion,
                  RecencyTracker(tuple(sorted(data.modality_names))),
                  fusion_input=fusion_input)

"""Tables 3–4 — modality-selection weight sweep (α_s, α_c, α_r) × γ,
without client selection (δ = 1), on ActionSense."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.rounds import run_mfedmc

WEIGHTS = [
    (1.0, 0.0, 0.0),
    (0.0, 1.0, 0.0),
    (0.0, 0.0, 1.0),
    (1 / 3, 1 / 3, 1 / 3),
]
WEIGHTS_FULL = WEIGHTS + [(0.0, 0.5, 0.5), (0.5, 0.0, 0.5), (0.5, 0.5, 0.0)]


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    gammas = [1] if fast else [1, 2, 3]
    weights = WEIGHTS if fast else WEIGHTS_FULL
    for gamma in gammas:
        for (a_s, a_c, a_r) in weights:
            cfg = cfg_for(fast, gamma=gamma, delta=1.0,
                          client_strategy="all",
                          alpha_s=a_s, alpha_c=a_c, alpha_r=a_r)
            with Timer() as t:
                h = run_mfedmc("actionsense", "natural", cfg,
                               samples_per_client=n)
            rows.append(Row(
                f"table3/g{gamma}/s{a_s:.2f}_c{a_c:.2f}_r{a_r:.2f}", t.us,
                f"final={h.final_accuracy():.4f};MB={h.comm_mb[-1]:.2f}"))
    return rows

"""Shared benchmark plumbing: budget-scaled configs, timing, CSV emission.

Every benchmark module exposes ``run(fast: bool) -> List[Row]``; ``run.py``
orchestrates. Rows print as ``name,us_per_call,derived`` per the harness
contract: ``us_per_call`` is wall-microseconds for the measured unit and
``derived`` carries the paper-comparable quantity (accuracy, MB, ratio …).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

# the one shared timing implementation (every bench's interleaved
# min-of-reps loop goes through interleaved_min; Timer re-exported for
# one-shot wall windows)
from repro.telemetry.timer import Timer, interleaved_min  # noqa: F401
from repro.core.rounds import MFedMCConfig


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def fast_cfg(**kw) -> MFedMCConfig:
    base = dict(rounds=6, local_epochs=2, background_size=24, eval_size=24,
                seed=0)
    base.update(kw)
    return MFedMCConfig(**base)


def paper_cfg(**kw) -> MFedMCConfig:
    base = dict(rounds=20, local_epochs=5, background_size=50, eval_size=32,
                seed=0)
    base.update(kw)
    return MFedMCConfig(**base)


def cfg_for(fast: bool, **kw) -> MFedMCConfig:
    return fast_cfg(**kw) if fast else paper_cfg(**kw)


def samples_for(fast: bool) -> int:
    return 48 if fast else 96


def phase_breakdown(backend: str = "engine", comm_impl: str = "fused",
                    train_impl: str = "fused",
                    rounds: int = 2) -> Dict[str, Any]:
    """Traced per-phase time/sync/byte/dispatch table of a seeded
    mini-federation run, stamped into BENCH jsons so an artifact explains
    *where* its round budget goes (and records that the trace reconciled
    with the hostsync counters)."""
    from repro import telemetry
    from repro.analysis import budgets as budgets_mod
    from repro.core.rounds import run_federation
    clients, spec = budgets_mod.mini_federation()
    cfg = budgets_mod.federation_config(comm_impl, rounds=rounds,
                                        train_impl=train_impl)
    tracer = telemetry.Tracer()
    with telemetry.install(tracer):
        run_federation(clients, spec, cfg, backend=backend)
    return {
        "backend": backend, "comm_impl": comm_impl,
        "train_impl": train_impl,
        "phases": telemetry.tracer_phase_table(tracer),
        "reconciled": not telemetry.reconcile(tracer),
    }


def lint_stamp(backends, comm_impls) -> Dict[str, Any]:
    """Lint verdict + measured budgets for a BENCH json.

    Runs the static passes over the real round programs of the benched
    backends and re-measures each one's host-sync/byte budget against the
    pinned manifest, so a benchmark artifact records whether the numbers
    it reports came from clean programs."""
    from repro.analysis import budgets as budgets_mod
    from repro.analysis.lint import lint_static
    targets = [(b, ci) for b in backends for ci in comm_impls]
    findings, unknown = lint_static(targets)
    measured: Dict[str, Any] = {}
    pinned = budgets_mod.load_budgets()
    drift = []
    for b in backends:
        measured[b] = {}
        for ci in comm_impls:
            measured[b][ci] = budgets_mod.measure(b, ci)
    drift = budgets_mod.compare(
        {k: v for k, v in measured.items()}, pinned)
    return {
        "passed": not findings and not drift,
        "static_findings": [str(f) for f in findings],
        "budget_findings": [str(f) for f in drift],
        "unknown_primitives": unknown,
        "measured_budgets": measured,
    }

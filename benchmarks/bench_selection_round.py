"""Selection-layer benchmark: loop vs batched (pre-engine path) vs the
device-resident engine backend, with measured host-syncs per round.

    PYTHONPATH=src python -m benchmarks.bench_selection_round \
        [--ks 8,32,128] [--out BENCH_selection_round.json]

One full ``run_federation`` round per path under the paper's strategy
(priority modality selection + low-loss client selection), so the measured
gap covers everything the engine refactor touches: the joint-selection
decision layer (per-client numpy loops vs two [K, M] device programs) and
the population residency (per-phase restack/unstack of Client pytrees vs
gather/scatter on the resident FederationState buckets).

Paths:
- ``loop``    — ``backend="loop"``, ``selection_impl="host"``: the tier-1
  per-client reference.
- ``batched`` — ``backend="batched"``, ``selection_impl="host"``: the
  pre-engine Tier-2 path (vmapped training, host-side per-client selection,
  population restacked every phase).
- ``engine``  — ``backend="engine"``, ``selection_impl="engine"``: resident
  stacked population + device selection engine.

Host-syncs are counted at the device→host boundary by
``repro.core.hostsync`` (per-batch loss scalars, per-bucket loss arrays,
prediction/Shapley/eval fetches, the engine's decision fetches) — the
number the README backend table reports. Writes
``BENCH_selection_round.json``; supports the ``benchmarks.run`` Row
contract.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Tuple

from benchmarks.bench_batched_round import synthetic_federation
from benchmarks.common import (Row, Timer, interleaved_min, lint_stamp,
                               phase_breakdown)
from repro.core import hostsync
from repro.core.rounds import MFedMCConfig, run_federation

PATHS = {
    "loop": dict(backend="loop", selection_impl="host"),
    "batched": dict(backend="batched", selection_impl="host"),
    "engine": dict(backend="engine", selection_impl="engine"),
}


ROUNDS_TIMED = 2


def _cfg(selection_impl: str) -> MFedMCConfig:
    return MFedMCConfig(rounds=ROUNDS_TIMED, local_epochs=2, batch_size=16,
                        seed=0, modality_strategy="priority",
                        client_strategy="low_loss", gamma=1,
                        background_size=24, eval_size=24,
                        selection_impl=selection_impl)


def _one_run(K: int, path: str, n: int) -> Tuple[float, int]:
    spec_of = PATHS[path]
    clients, spec = synthetic_federation(K, n=n)
    with hostsync.measuring() as m, Timer() as t:
        run_federation(clients, spec, _cfg(spec_of["selection_impl"]),
                       backend=spec_of["backend"])
    return t.us / 1e6 / ROUNDS_TIMED, m.syncs // ROUNDS_TIMED


def time_paths(K: int, *, n: int = 48, repeats: int = 1) -> dict:
    """Steady-state wall seconds per round (min over ``repeats``) and
    host-syncs per round, for every path.

    The warm run uses the SAME K (the compiled programs are K-shaped), and
    the measured repeats INTERLEAVE the paths so box-level noise (shared
    CPU, throttling windows) hits every path alike instead of biasing
    whichever ran during the slow window."""
    out = {}
    for path in PATHS:
        _, syncs = _one_run(K, path, n)            # warm/compile + syncs
        out[path] = {"seconds": 0.0, "host_syncs": syncs}

    def timed(args):
        clients, spec, cfg, backend = args
        run_federation(clients, spec, cfg, backend=backend)

    best = interleaved_min(
        {p: timed for p in PATHS},
        prepare={p: (lambda p=p: (*synthetic_federation(K, n=n),
                                  _cfg(PATHS[p]["selection_impl"]),
                                  PATHS[p]["backend"]))
                 for p in PATHS},
        reps=max(repeats, 1))
    for p in PATHS:
        out[p]["seconds"] = best[p] / ROUNDS_TIMED
    return out


def run(fast: bool = True) -> List[Row]:
    ks = [8] if fast else [8, 32]
    rows = []
    for K in ks:
        res = time_paths(K)
        for p, r in res.items():
            rows.append(Row(
                f"selection_round/K{K}/{p}", r["seconds"] * 1e6,
                f"host_syncs={r['host_syncs']}"))
        rows.append(Row(
            f"selection_round/K{K}/engine_vs_batched",
            res["engine"]["seconds"] * 1e6,
            f"speedup={res['batched']['seconds'] / res['engine']['seconds']:.2f}x"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", default="8,32,128",
                    help="comma-separated client counts")
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured repetitions per path (min is reported)")
    ap.add_argument("--out", default="BENCH_selection_round.json")
    args = ap.parse_args(argv)
    ks = [int(k) for k in args.ks.split(",")]

    results = []
    for K in ks:
        t0 = time.time()
        res = time_paths(K, n=args.samples, repeats=args.repeats)
        entry = {"K": K}
        for p, r in res.items():
            entry[f"{p}_s"] = round(r["seconds"], 4)
            entry[f"{p}_host_syncs"] = r["host_syncs"]
        entry["engine_vs_loop"] = round(
            res["loop"]["seconds"] / res["engine"]["seconds"], 3)
        entry["engine_vs_batched"] = round(
            res["batched"]["seconds"] / res["engine"]["seconds"], 3)
        results.append(entry)
        print(f"K={K:4d} "
              f"loop={res['loop']['seconds']:7.2f}s"
              f"/{res['loop']['host_syncs']:5d}sync "
              f"batched={res['batched']['seconds']:7.2f}s"
              f"/{res['batched']['host_syncs']:4d}sync "
              f"engine={res['engine']['seconds']:7.2f}s"
              f"/{res['engine']['host_syncs']:4d}sync "
              f"engine-vs-batched={entry['engine_vs_batched']:5.2f}x "
              f"(total {time.time() - t0:.0f}s)", flush=True)

    payload = {
        "benchmark": "selection_round",
        "config": {
            "dataset_shapes": "ucihar (reduced)",
            "modalities": 2,
            "samples_per_client": args.samples,
            "local_epochs": 2,
            "batch_size": 16,
            "rounds_timed": ROUNDS_TIMED,
            "seconds_are": "per round, min over interleaved repeats",
            "repeats": args.repeats,
            "modality_strategy": "priority",
            "client_strategy": "low_loss",
            "host_syncs": "measured device->host transfers per round "
                          "(repro.core.hostsync)",
        },
        "results": results,
        "lint": lint_stamp(("batched", "engine"), ("fused",)),
        "phase_breakdown": [phase_breakdown("engine")],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Roofline of the REAL federation round programs on the sharded mesh.

    PYTHONPATH=src python -m benchmarks.roofline_federated \
        [--out BENCH_roofline_federated.json]

Historically this bench rooflined a standalone ``make_federated_round``
step that ``run_federation`` never executes. It now meters the exact
lru-cached ``jit(shard_map(...))`` programs the ``backend="sharded"``
round dispatches (via :func:`repro.roofline.sharded_round_programs`):

    epoch                 — vmapped local-SGD epoch over the client axis
    epoch_fused           — all-epochs fused round program (donated
                            resident param stack, ``train_impl="fused"``)
    aggregate_full        — full-precision Eq. 21 psum
    aggregate_q_reference — quantize → dequantized-stack psum (historical)
    aggregate_q_fused     — quantize → einsum-from-codes partial → psum
                            (``repro.kernels.comm`` hot path)

Each program is lowered on a forced-D host mesh (subprocess — the XLA
device-count flag must not leak into the caller), then we parse
collective bytes from the compiled HLO, walk the jaxpr for FLOPs, and
read the compiler's memory analysis. ``main`` records everything in
``BENCH_roofline_federated.json``; ``run`` keeps the Row contract.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import List

from benchmarks.common import Row

D, K, STEPS, BATCH, BITS = 8, 512, 15, 32, 4
FEAT = (16, 8)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(D)d"
import json
import jax, jax.numpy as jnp
from repro.core.encoders import init_encoder
from repro.roofline import (collective_bytes, count_step_flops,
                            quantized_uplink_roofline,
                            sharded_round_programs)
from repro.sharding.partition import client_mesh

K, STEPS, BATCH, BITS = %(K)d, %(STEPS)d, %(BATCH)d, %(BITS)d
FEAT = %(FEAT)r
mesh = client_mesh()
template = jax.eval_shape(lambda: init_encoder(jax.random.key(0), FEAT, 20))
progs = sharded_round_programs(mesh, k=K, steps=STEPS, batch=BATCH,
                               feat=FEAT, template=template, lr=0.1,
                               bits=BITS)
out = {"D": %(D)d, "K": K, "steps": STEPS, "batch": BATCH, "bits": BITS,
       "feat": list(FEAT), "programs": [],
       "uplink": quantized_uplink_roofline(template, K, BITS)}
for name in ("epoch", "epoch_fused", "aggregate_full",
             "aggregate_q_reference", "aggregate_q_fused"):
    prog, args = progs[name]
    with mesh:
        compiled = prog.lower(*args).compile()
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    out["programs"].append({
        "name": name,
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
        "flops_total": count_step_flops(prog, *args),
        "peak_bytes": int(mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes),
    })
print("RESULT_JSON:" + json.dumps(out))
""" % {"D": D, "K": K, "STEPS": STEPS, "BATCH": BATCH, "BITS": BITS,
       "FEAT": FEAT}


def _measure() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=3600)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT_JSON:"):
            return json.loads(line[len("RESULT_JSON:"):])
    raise RuntimeError(f"roofline subprocess failed: {r.stderr[-500:]}")


def run(fast: bool = True) -> List[Row]:
    try:
        res = _measure()
    except RuntimeError as e:
        return [Row("roofline_federated/error", 0.0, str(e)[:200])]
    rows: List[Row] = []
    for entry in res["programs"]:
        per_chip = entry["collective_total"] / res["D"]
        rows.append(Row(
            f"roofline_federated/{entry['name']}", 0.0,
            f"collective_total={entry['collective_total']:.3e}B;"
            f"per_chip={per_chip:.3e}B;"
            f"ici_s={per_chip / 50e9:.3e};"
            f"flops={entry['flops_total']:.3e}"))
    up = res["uplink"]
    rows.append(Row(
        "roofline_federated/uplink_bytes", 0.0,
        f"wire={up['wire_bytes']};fused={up['payload_bytes']['fused']};"
        f"reference={up['payload_bytes']['reference']};"
        f"raw={up['raw_bytes']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_roofline_federated.json")
    args = ap.parse_args(argv)
    res = _measure()
    for entry in res["programs"]:
        print(f"{entry['name']:24s} "
              f"collective={entry['collective_total']:.3e}B "
              f"flops={entry['flops_total']:.3e} "
              f"peak={entry['peak_bytes']:.3e}B", flush=True)
    up = res["uplink"]
    print(f"uplink bytes: wire={up['wire_bytes']} "
          f"fused={up['payload_bytes']['fused']} "
          f"reference={up['payload_bytes']['reference']} "
          f"raw={up['raw_bytes']}")
    payload = {"benchmark": "roofline_federated",
               "config": {
                   "programs": "exact jit(shard_map) programs the sharded "
                               "backend dispatches (repro.roofline."
                               "sharded_round_programs)",
                   "accounting": "collective bytes parsed from compiled HLO; "
                                 "flops from jaxpr walk; peak from compiler "
                                 "memory analysis",
               },
               "results": res}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

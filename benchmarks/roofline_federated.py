"""Roofline of the paper's technique on the production mesh: one MFedMC
round (local SGD epochs + masked Eq.-21 aggregation) for a K-client LSTM
encoder population, lowered on the multi-pod mesh.

Modes compared (§Perf hillclimb #3):
    flat          — cross-(pod×data) masked all-reduce every round
    hierarchical  — per-step within-pod pmean (cheap axis) + per-round
                    cross-pod selective aggregation (expensive axis)

Runs in a subprocess (the 512-device XLA flag must not leak here).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from benchmarks.common import Row

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax, jax.numpy as jnp
from repro.core.distributed import make_federated_round, federated_input_specs
from repro.core.encoders import init_encoder
from repro.launch.mesh import make_production_mesh
from repro.models.model import param_specs
from repro.roofline import collective_bytes, count_step_flops

K, STEPS, BATCH = 512, 15, 32          # 512 clients, E*steps local SGD
FEAT = (16, 8)                          # reduced ActionSense-ish modality
mesh = make_production_mesh(multi_pod=True)
enc_spec = jax.eval_shape(lambda: init_encoder(jax.random.key(0), FEAT, 20))
specs = federated_input_specs(K, STEPS, BATCH, FEAT, enc_spec)
out = []
for mode in ("flat", "hierarchical", "flat_bf16_uplink"):
    rnd = make_federated_round(mesh, local_steps=STEPS, lr=0.1,
                               hierarchical=(mode == "hierarchical"),
                               uplink_dtype=(jnp.bfloat16 if "bf16" in mode
                                             else None))
    with mesh:
        lowered = jax.jit(rnd).lower(specs["params"], specs["batches"],
                                     specs["select"], specs["weight"])
        compiled = lowered.compile()
    coll = collective_bytes(compiled.as_text())
    flops = count_step_flops(rnd, specs["params"], specs["batches"],
                             specs["select"], specs["weight"])
    mem = compiled.memory_analysis()
    out.append({
        "mode": mode,
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
        "flops_total": flops,
        "peak_bytes": int(mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes),
    })
print("RESULT_JSON:" + json.dumps(out))
"""


def run(fast: bool = True) -> List[Row]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=3600)
    rows: List[Row] = []
    for line in r.stdout.splitlines():
        if line.startswith("RESULT_JSON:"):
            for entry in json.loads(line[len("RESULT_JSON:"):]):
                per_chip = entry["collective_total"] / 512
                rows.append(Row(
                    f"roofline_federated/{entry['mode']}", 0.0,
                    f"collective_total={entry['collective_total']:.3e}B;"
                    f"per_chip={per_chip:.3e}B;"
                    f"ici_s={per_chip / 50e9:.3e};"
                    f"flops={entry['flops_total']:.3e}"))
    if not rows:
        rows.append(Row("roofline_federated/error", 0.0,
                        f"stderr={r.stderr[-200:]}"))
    return rows

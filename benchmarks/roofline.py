"""Roofline benchmark (deliverable g): per (arch × shape × mesh) compute /
memory / collective terms from the compiled dry-run.

The full 40-combo sweep takes ~1 h of XLA compile time, so this module
*consumes* the dry-run artifact (``results/dryrun_single.json`` +
``results/dryrun_multi.json`` written by ``repro.launch.dryrun --all
--json …``) when present and otherwise runs a representative 3-combo subset
in a subprocess (the 512-device flag must not leak into this process).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List, Optional

from benchmarks.common import Row

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
SUBSET = [("xlstm-125m", "train_4k"), ("phi3-medium-14b", "decode_32k"),
          ("granite-moe-1b-a400m", "train_4k")]


def _load_results() -> Optional[list]:
    out = []
    for f in ("dryrun_single.json", "dryrun_multi.json",
              "dryrun_all.json"):
        p = os.path.join(RESULTS, f)
        if os.path.exists(p):
            with open(p) as fh:
                out.extend(json.load(fh))
    return out or None


def _run_subset() -> list:
    os.makedirs(RESULTS, exist_ok=True)
    results = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    for arch, shape in SUBSET:
        tmp = os.path.join(RESULTS, f"_roofline_{arch}_{shape}.json")
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--json", tmp]
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=3600)
        if os.path.exists(tmp):
            with open(tmp) as fh:
                results.extend(json.load(fh))
            os.remove(tmp)
        elif r.returncode:
            results.append({"arch": arch, "shape": shape,
                            "status": f"FAILED rc={r.returncode}"})
    return results


def rows_from_results(results: list) -> List[Row]:
    rows = []
    for r in results:
        if r.get("status") != "ok":
            continue
        mesh = "multi" if r.get("multi_pod") else "single"
        name = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
        rows.append(Row(
            name, r.get("compile_s", 0) * 1e6,
            f"compute_s={r['compute_s_term']:.3e};"
            f"memory_s={r['memory_s_term']:.3e};"
            f"collective_s={r['collective_s_term']:.3e};"
            f"dominant={r['dominant']};"
            f"useful_flops={r['useful_flops_ratio']:.3f}"))
    return rows


def run(fast: bool = True) -> List[Row]:
    results = _load_results()
    if results is None:
        results = _run_subset()
    rows = rows_from_results(results)
    if not rows:
        rows.append(Row("roofline/none", 0.0,
                        "no dry-run artifact; run repro.launch.dryrun"))
    return rows

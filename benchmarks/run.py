"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,fig11]

Prints ``name,us_per_call,derived`` CSV rows. ``--full`` uses paper-scale
rounds/epochs (slow on CPU); the default fast mode reproduces every table's
*relative* structure in minutes.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "table2_overall",
    "table3_weights",
    "table5_client_selection",
    "fig5_impact",
    "fig7_noniid",
    "fig8_heterogeneous_network",
    "fig9_longtail",
    "fig10_availability",
    "fig11_quantization",
    "table7_runtime",
    "fig12_shapley_runtime",
    "bench_batched_round",
    "bench_quantized_round",
    "bench_train_step",
    "bench_async_round",
    "roofline",
    "roofline_federated",
    "roofline_flash_decode",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    args = ap.parse_args(argv)

    mods = MODULES
    if args.only:
        want = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(w) for w in want)]

    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(fast=not args.full)
            for row in rows:
                print(row.csv(), flush=True)
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Python-loop vs. batched federation rounds across client counts.

    PYTHONPATH=src python -m benchmarks.bench_batched_round \
        [--full] [--out BENCH_batched_round.json]
    PYTHONPATH=src python -m benchmarks.bench_batched_round --ragged \
        [--out BENCH_ragged_round.json]

Builds a synthetic federation of K clients (two LSTM modalities, UCI-HAR
shapes) and times one full ``run_federation`` round per backend — identical
selection/aggregation phases, so the measured gap is the Local Learning
phase: K·M·E per-batch jit dispatches (loop) vs. E vmapped scans over the
stacked [K, ...] population (batched).

Two scenarios:
- homogeneous (default): every client has both modalities and the same n —
  writes ``BENCH_batched_round.json``;
- ``--ragged``: three distinct modality sets ({acc}, {gyro}, {acc, gyro})
  and sample counts skewed across clients — the paper's heterogeneous
  setting, which runs entirely on the padded mask-weighted batched path —
  writes ``BENCH_ragged_round.json``.

Both support the ``benchmarks.run`` Row contract.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

from benchmarks.common import Row, Timer
from repro.core.client import make_client
from repro.core.rounds import MFedMCConfig, run_federation
from repro.data.registry import get_dataset_spec
from repro.data.synthetic import ClientData


def synthetic_federation(K: int, n: int = 48, seed: int = 0):
    """K homogeneous clients with UCI-HAR-shaped modalities (arbitrary K —
    the registry's fixed client counts don't apply to a scaling bench)."""
    spec = get_dataset_spec("ucihar")
    rng = np.random.default_rng(seed)
    clients = []
    for k in range(K):
        labels = np.tile(np.arange(spec.num_classes),
                         n // spec.num_classes + 1)[:n]
        rng.shuffle(labels)
        mods = {
            m.name: rng.standard_normal(
                (n, *m.feature_shape(True))).astype(np.float32)
            for m in spec.modalities
        }
        data = ClientData(k, mods, labels.astype(np.int32), spec.num_classes)
        clients.append(make_client(k, spec, data, seed=seed))
    return clients, spec


def ragged_federation(K: int, n: int = 48, seed: int = 0, min_n: int = 8):
    """K heterogeneous clients: three distinct modality sets (cycling
    {acc}, {gyro}, {acc, gyro}) and sample counts skewed from n down to
    ~n/4 — the ragged population the padded batched path targets (also
    the federation the loop-vs-batched parity tests pin)."""
    spec = get_dataset_spec("ucihar")
    mods_all = list(spec.modality_names)
    sets = [mods_all[:1], mods_all[1:], mods_all]
    rng = np.random.default_rng(seed)
    clients = []
    for k in range(K):
        nk = max(min_n, int(n * (0.25 + 0.75 * (K - 1 - k) / max(K - 1, 1))))
        labels = np.tile(np.arange(spec.num_classes),
                         nk // spec.num_classes + 1)[:nk]
        rng.shuffle(labels)
        mods = {
            m: rng.standard_normal(
                (nk, *spec.modality(m).feature_shape(True))
            ).astype(np.float32)
            for m in sets[k % len(sets)]
        }
        data = ClientData(k, mods, labels.astype(np.int32), spec.num_classes)
        clients.append(make_client(k, spec, data, seed=seed))
    return clients, spec


def _bench_cfg(**kw) -> MFedMCConfig:
    base = dict(rounds=1, local_epochs=2, batch_size=16, seed=0,
                modality_strategy="random", client_strategy="random",
                gamma=1)
    base.update(kw)
    return MFedMCConfig(**base)


def time_round(K: int, backend: str, *, n: int = 48, warm: bool = True,
               federation=synthetic_federation) -> float:
    """Steady-state wall seconds for one federation round.

    The warm run uses the SAME K: the batched backend's compiled programs
    are shaped [K, ...], so a smaller warm-up would leave the measured run
    paying the XLA compile (the loop backend's per-batch step is
    K-independent and warms either way).
    """
    if warm:
        clients, spec = federation(K, n=n)
        run_federation(clients, spec, _bench_cfg(), backend=backend)
    clients, spec = federation(K, n=n)
    with Timer() as t:
        run_federation(clients, spec, _bench_cfg(), backend=backend)
    return t.us / 1e6


def run(fast: bool = True) -> List[Row]:
    ks = [8, 32] if fast else [8, 32, 128]
    rows = []
    for K in ks:
        loop_s = time_round(K, "loop")
        batched_s = time_round(K, "batched")
        rows.append(Row(f"batched_round/K{K}/loop", loop_s * 1e6,
                        f"round_s={loop_s:.2f}"))
        rows.append(Row(f"batched_round/K{K}/batched", batched_s * 1e6,
                        f"speedup={loop_s / batched_s:.2f}x"))
    K = 8 if fast else 32
    loop_s = time_round(K, "loop", federation=ragged_federation)
    batched_s = time_round(K, "batched", federation=ragged_federation)
    rows.append(Row(f"ragged_round/K{K}/loop", loop_s * 1e6,
                    f"round_s={loop_s:.2f}"))
    rows.append(Row(f"ragged_round/K{K}/batched", batched_s * 1e6,
                    f"speedup={loop_s / batched_s:.2f}x"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run K=128 (several minutes on CPU)")
    ap.add_argument("--ks", default=None,
                    help="comma-separated client counts (overrides --full)")
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--ragged", action="store_true",
                    help="heterogeneous federation: 3 modality sets + "
                         "skewed sample counts (paper's ragged setting)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.ks:
        ks = [int(k) for k in args.ks.split(",")]
    else:
        ks = [8, 32, 128]
    federation = ragged_federation if args.ragged else synthetic_federation
    name = "ragged_round" if args.ragged else "batched_round"
    out = args.out or f"BENCH_{name}.json"

    results = []
    for K in ks:
        t0 = time.time()
        loop_s = time_round(K, "loop", n=args.samples,
                            federation=federation)
        batched_s = time_round(K, "batched", n=args.samples,
                               federation=federation)
        results.append({
            "K": K,
            "loop_s": round(loop_s, 4),
            "batched_s": round(batched_s, 4),
            "speedup": round(loop_s / batched_s, 3),
        })
        print(f"K={K:4d} loop={loop_s:7.2f}s batched={batched_s:7.2f}s "
              f"speedup={loop_s / batched_s:5.2f}x "
              f"(total {time.time() - t0:.0f}s)", flush=True)

    payload = {
        "benchmark": name,
        "config": {
            "dataset_shapes": "ucihar (reduced)",
            "modalities": 2,
            "modality_sets": (3 if args.ragged else 1),
            "samples_per_client": (f"8..{args.samples} (skewed)"
                                   if args.ragged else args.samples),
            "local_epochs": 2,
            "batch_size": 16,
            "rounds_timed": 1,
        },
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Table 7 — end-to-end system time: measured wall-clock training time plus
the paper's modeled transmission time (10 Mbps uplink × 1.2 protocol × 1.5
FEC), per method."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, cfg_for, samples_for
from repro.core.aggregation import IOT_UPLINK
from repro.core.baselines import run_baseline
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    systems = {
        "mfedmc": lambda c: run_mfedmc("actionsense", "natural", c,
                                       samples_per_client=n),
        "flfd": lambda c: run_baseline("flfd", "actionsense", "natural", c,
                                       samples_per_client=n),
        "flash": lambda c: run_baseline("flash", "actionsense", "natural",
                                        c, samples_per_client=n),
    }
    if not fast:
        systems["mmfed"] = lambda c: run_baseline(
            "mmfed", "actionsense", "natural", c, samples_per_client=n)
        systems["harmony"] = lambda c: run_baseline(
            "harmony", "actionsense", "natural", c, samples_per_client=n)
    for name, fn in systems.items():
        cfg = cfg_for(fast)
        t0 = time.perf_counter()
        h = fn(cfg)
        train_s = time.perf_counter() - t0
        comm_s = IOT_UPLINK.seconds(h.comm_mb[-1] * 1e6)
        rows.append(Row(
            f"table7/{name}", train_s * 1e6,
            f"train_s={train_s:.1f};comm_s={comm_s:.1f};"
            f"total_s={train_s + comm_s:.1f};MB={h.comm_mb[-1]:.2f}"))
    return rows

"""Table 7 — end-to-end system time: measured wall-clock training time plus
the paper's modeled transmission time, per method.

Two transmission models:

- ``comm_s`` — the paper's single shared 10 Mbps uplink (× 1.2 protocol ×
  1.5 FEC) over the run's total bytes;
- ``comm_s_hetero`` — per-client links sampled log-normally around the same
  preset (``TransportModel.sample_links``, σ=0.5 ≈ 4× p10–p90 spread).
  Clients upload in parallel, so each round costs the *slowest uploading
  link* its bytes — the synchronous-barrier effect a single shared link
  cannot show (the slow-tail link, not the mean, gates the round).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, cfg_for, samples_for
from repro.core.aggregation import IOT_UPLINK
from repro.core.baselines import run_baseline
from repro.core.rounds import RunHistory, run_mfedmc

LINK_SIGMA = 0.5


def hetero_comm_seconds(h: RunHistory, links: list) -> float:
    """Σ over rounds of the slowest uploading client's transmission time.

    Per-round bytes come from the ledger deltas; a round's bytes split
    evenly over its recorded uploads (full-upload baselines record none —
    then every client ships the same payload and the slowest link gates).
    ``links`` must cover every client id the history records."""
    total, prev = 0.0, 0.0
    K = len(links)
    for r in h.records:
        rb = r.comm_mb * 1e6 - prev
        prev = r.comm_mb * 1e6
        if rb <= 0:
            continue
        if r.uploads:
            share = rb / len(r.uploads)
            per_client: dict = {}
            for cid, _m in r.uploads:
                assert cid < K, f"client {cid} has no sampled link"
                per_client[cid] = per_client.get(cid, 0.0) + share
            total += max(links[cid].seconds(b)
                         for cid, b in per_client.items())
        else:
            total += max(link.seconds(rb / K) for link in links)
    return total


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    systems = {
        "mfedmc": lambda c: run_mfedmc("actionsense", "natural", c,
                                       samples_per_client=n),
        "flfd": lambda c: run_baseline("flfd", "actionsense", "natural", c,
                                       samples_per_client=n),
        "flash": lambda c: run_baseline("flash", "actionsense", "natural",
                                        c, samples_per_client=n),
    }
    if not fast:
        systems["mmfed"] = lambda c: run_baseline(
            "mmfed", "actionsense", "natural", c, samples_per_client=n)
        systems["harmony"] = lambda c: run_baseline(
            "harmony", "actionsense", "natural", c, samples_per_client=n)
    runs = []
    for name, fn in systems.items():
        cfg = cfg_for(fast)
        t0 = time.perf_counter()
        h = fn(cfg)
        runs.append((name, h, time.perf_counter() - t0))
    # one heterogeneous link population shared by every system, sized to
    # the federation every system actually runs (the same partition +
    # min-samples filter run_mfedmc/run_baseline apply), so the comparison
    # varies only the method, not the network draw
    from repro.data.partition import make_federation
    n_clients = len([d for d in make_federation("actionsense", "natural",
                                                seed=0,
                                                samples_per_client=n)
                     if d.num_samples > 1])
    links = IOT_UPLINK.sample_links(np.random.default_rng(0), n_clients,
                                    sigma=LINK_SIGMA)
    for name, h, train_s in runs:
        comm_s = IOT_UPLINK.seconds(h.comm_mb[-1] * 1e6)
        het_s = hetero_comm_seconds(h, links)
        rows.append(Row(
            f"table7/{name}", train_s * 1e6,
            f"train_s={train_s:.1f};comm_s={comm_s:.1f};"
            f"comm_s_hetero={het_s:.1f};"
            f"total_s={train_s + het_s:.1f};MB={h.comm_mb[-1]:.2f}"))
    return rows

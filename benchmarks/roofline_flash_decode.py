"""Context-parallel flash-decode roofline: explicit shard_map partial-softmax
combine vs the XLA-inferred sharded contraction, at long_500k scale
(batch 1, 512k context, one attention layer).

The explicit path's collective is (o, m, l) — O(B·H·d) — independent of
context length; the XLA-inferred path is whatever SPMD picks for the sharded
contraction. Runs on the multi-pod mesh in a subprocess.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from benchmarks.common import Row

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_production_mesh
from repro.models.decode_attention import make_flash_decode, _partial_attention
from repro.roofline import collective_bytes

B, H, KV, T, D = 1, 32, 8, 524288, 128
mesh = make_production_mesh(multi_pod=True)
S = jax.ShapeDtypeStruct
q = S((B, H, D), jnp.bfloat16)
k = S((B, T, KV, D), jnp.bfloat16)
v = S((B, T, KV, D), jnp.bfloat16)
kv_pos = S((T,), jnp.int32)
pos = S((), jnp.int32)
seq_axes = ("pod", "data", "model")
mesh.__enter__()  # ambient mesh for shard_map lowering
out = []

# explicit shard_map flash-decode
fd = make_flash_decode(mesh, seq_axes=seq_axes)
compiled = jax.jit(fd).lower(q, k, v, kv_pos, pos).compile()
coll = collective_bytes(compiled.as_text())
out.append({"mode": "shard_map_flash_decode",
            "collective_total": sum(coll.values()),
            "breakdown": coll})

# XLA-inferred: same math, sharding via constraints only
def xla_path(q, k, v, kv_pos, pos):
    o, m, l = _partial_attention(q, k, v, kv_pos, pos)
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

kv_sh = NamedSharding(mesh, P(None, seq_axes))
compiled2 = jax.jit(
    xla_path,
    in_shardings=(NamedSharding(mesh, P()), kv_sh, kv_sh,
                  NamedSharding(mesh, P(seq_axes)),
                  NamedSharding(mesh, P())),
    out_shardings=NamedSharding(mesh, P())).lower(
        q, k, v, kv_pos, pos).compile()
coll2 = collective_bytes(compiled2.as_text())
out.append({"mode": "xla_inferred",
            "collective_total": sum(coll2.values()),
            "breakdown": coll2})
print("RESULT_JSON:" + json.dumps(out))
"""


def run(fast: bool = True) -> List[Row]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=3600)
    rows: List[Row] = []
    for line in r.stdout.splitlines():
        if line.startswith("RESULT_JSON:"):
            for e in json.loads(line[len("RESULT_JSON:"):]):
                per_chip = e["collective_total"] / 512
                rows.append(Row(
                    f"roofline_flash_decode/{e['mode']}", 0.0,
                    f"collective_total={e['collective_total']:.3e}B;"
                    f"per_chip={per_chip:.3e}B;"
                    f"ici_s={per_chip / 50e9:.3e}"))
    if not rows:
        rows.append(Row("roofline_flash_decode/error", 0.0,
                        f"stderr={r.stderr[-200:]}"))
    return rows

"""Virtual-time async runtime vs synchronous barrier: simulated makespan
and real per-round step time.

    PYTHONPATH=src python -m benchmarks.bench_async_round \
        [--ks 8,32,128] [--rounds 2] [--out BENCH_async_round.json]

For each K and straggler fraction ∈ {0, 0.25} (stragglers run 10× compute),
two virtual-time runs over the same synthetic federation:

- **sync** — ``backend="async"`` in its degenerate config (deadline ∞, one
  flush of all arrivals, no staleness discount). The parity oracle pins
  this to ``backend="engine"`` exactly, so its makespan IS the synchronous
  barrier's: every cycle waits for the slowest client.
- **async** — reporting deadline at 1.5× the nominal (straggler-free)
  cycle time, buffered aggregation every 4 arrivals, staleness discount
  0.9. Stragglers get preempted at the deadline instead of stalling the
  cohort.

The timing model is compute-dominant (``compute_sec_per_step=0.1``: an
edge device at ~100 ms per minibatch SGD step next to a 10 Mbps uplink), so
a 10× compute straggler actually gates the synchronous barrier — the
regime Table 7 and §4.9 describe. ``sim_speedup`` is sync makespan ÷ async
makespan (> 1 when 25% of clients straggle); ``*_wall_s`` is the real
wall-clock per simulated cycle (the scheduler's own overhead: identical
training math, one event heap on top).

Writes ``BENCH_async_round.json``; supports the ``benchmarks.run`` Row
contract.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

from benchmarks.bench_batched_round import synthetic_federation
from benchmarks.common import Row, Timer
from repro.core.rounds import MFedMCConfig, run_federation
from repro.core.scheduler import nominal_cycle_seconds

DEFAULT_ROUNDS = 2
STRAGGLER_FACTOR = 10.0
DEADLINE_MARGIN = 1.5


def _cfg(straggler_fraction: float, rounds: int, **kw) -> MFedMCConfig:
    base = dict(rounds=rounds, local_epochs=2, batch_size=16, seed=0,
                background_size=24, eval_size=24,
                modality_strategy="priority", client_strategy="low_loss",
                compute_sec_per_step=0.1,
                straggler_fraction=straggler_fraction,
                straggler_factor=STRAGGLER_FACTOR)
    base.update(kw)
    return MFedMCConfig(**base)


def _run(K: int, n: int, straggler_fraction: float, rounds: int,
         clients=None, spec=None, **cfg_kw):
    cfg = _cfg(straggler_fraction, rounds, **cfg_kw)
    if clients is None:
        clients, spec = synthetic_federation(K, n=n)
    with Timer() as t:
        h = run_federation(clients, spec, cfg, backend="async")
    return h, t.us / 1e6 / rounds


def bench_point(K: int, straggler_fraction: float, n: int = 48,
                rounds: int = DEFAULT_ROUNDS) -> dict:
    # the deadline admits every nominal client; only stragglers get
    # dropped. nominal_cycle_seconds only reads shapes/step counts, so the
    # sync run reuses the probe federation (still untrained at probe time).
    clients, spec = synthetic_federation(K, n=n)
    nominal = nominal_cycle_seconds(clients, spec,
                                    _cfg(straggler_fraction, rounds))
    h_sync, wall_sync = _run(K, n, straggler_fraction, rounds,
                             clients=clients, spec=spec)
    h_async, wall_async = _run(K, n, straggler_fraction, rounds,
                               deadline_s=DEADLINE_MARGIN * nominal,
                               buffer_size=4, staleness_discount=0.9)
    dropped = sum(len(r.dropped) for r in h_async.records)
    return {
        "K": K,
        "straggler_fraction": straggler_fraction,
        "nominal_cycle_s": round(nominal, 4),
        "sync_makespan_s": round(h_sync.makespan_s, 4),
        "async_makespan_s": round(h_async.makespan_s, 4),
        "sim_speedup": round(h_sync.makespan_s
                             / max(h_async.makespan_s, 1e-12), 3),
        "sync_wall_s": round(wall_sync, 4),
        "async_wall_s": round(wall_async, 4),
        "dropped_total": dropped,
        "sync_final_acc": round(h_sync.final_accuracy(), 4),
        "async_final_acc": round(h_async.final_accuracy(), 4),
    }


def run(fast: bool = True) -> List[Row]:
    ks = [8] if fast else [8, 32]
    rows: List[Row] = []
    for K in ks:
        for frac in (0.0, 0.25):
            e = bench_point(K, frac)
            rows.append(Row(
                f"async_round/K{K}/straggle{int(frac * 100)}",
                e["async_wall_s"] * 1e6,
                f"sim_speedup={e['sim_speedup']};"
                f"sync={e['sync_makespan_s']}s;"
                f"async={e['async_makespan_s']}s"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", default="8,32,128",
                    help="comma-separated client counts")
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--rounds", type=int, default=DEFAULT_ROUNDS,
                    help="simulated cycles per run")
    ap.add_argument("--out", default="BENCH_async_round.json")
    args = ap.parse_args(argv)
    ks = [int(k) for k in args.ks.split(",")]

    results = []
    for K in ks:
        for frac in (0.0, 0.25):
            t0 = time.time()
            e = bench_point(K, frac, n=args.samples, rounds=args.rounds)
            results.append(e)
            print(f"K={K:4d} straggle={frac:.2f} "
                  f"sync={e['sync_makespan_s']:8.2f}s "
                  f"async={e['async_makespan_s']:8.2f}s "
                  f"sim-speedup={e['sim_speedup']:5.2f}x "
                  f"dropped={e['dropped_total']:3d} "
                  f"wall={e['async_wall_s']:.2f}s/round "
                  f"(total {time.time() - t0:.0f}s)", flush=True)

    payload = {
        "benchmark": "async_round",
        "config": {
            "dataset_shapes": "ucihar (reduced)",
            "modalities": 2,
            "samples_per_client": args.samples,
            "local_epochs": 2,
            "batch_size": 16,
            "rounds": args.rounds,
            "compute_sec_per_step": 0.1,
            "straggler_factor": STRAGGLER_FACTOR,
            "deadline": f"{DEADLINE_MARGIN}x nominal cycle",
            "buffer_size": 4,
            "staleness_discount": 0.9,
            "sync_is": "backend='async' degenerate config (== engine "
                       "backend exactly; see tests/test_scheduler.py)",
            "makespans_are": "simulated virtual-clock seconds for the "
                             "whole run; wall_s is real seconds per cycle",
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

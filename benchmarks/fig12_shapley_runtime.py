"""Fig. 12 — Shapley computation overhead: runtime vs number of modalities
and vs background-subsample size, plus estimation error of subsampled
backgrounds against the |D'| = max reference."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core.fusion import init_fusion
from repro.core.shapley import exact_shapley


def _bench(m: int, g: int, b: int = 32, c: int = 8, reps: int = 3):
    rng = np.random.default_rng(0)
    fusion = init_fusion(jax.random.key(0), m, c)
    preds = jnp.asarray(rng.random((b, m, c)), jnp.float32)
    # nested prefixes of one fixed pool so error vs the g=300 reference
    # isolates subsampling (not resampling) noise
    pool = np.random.default_rng(42).random((300, m, c)).astype(np.float32)
    bg = jnp.asarray(pool[:g])
    y = jnp.asarray(rng.integers(0, c, b), jnp.int32)
    avail = jnp.ones((m,), jnp.float32)
    phi = exact_shapley(fusion, preds, bg, avail, y, num_modalities=m)
    phi.block_until_ready()                      # compile outside timing
    t0 = time.perf_counter()
    for _ in range(reps):
        phi = exact_shapley(fusion, preds, bg, avail, y, num_modalities=m)
        phi.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6, np.asarray(phi)


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    # (a) runtime vs number of modalities (2^M subsets, all vectorized)
    for m in ([2, 4, 6] if fast else [2, 3, 4, 5, 6, 8]):
        us, _ = _bench(m, g=50)
        rows.append(Row(f"fig12a/modalities_{m}", us, f"subsets={2**m}"))
    # (b) runtime + error vs background size
    us_ref, phi_ref = _bench(4, g=300)
    for g in ([50, 300] if fast else [25, 50, 100, 200, 300]):
        us, phi = _bench(4, g=g)
        err = float(np.abs(phi - phi_ref).sum()
                    / max(np.abs(phi_ref).sum(), 1e-9))
        rows.append(Row(f"fig12b/background_{g}", us,
                        f"rel_err_vs_300={err:.4f}"))
    return rows

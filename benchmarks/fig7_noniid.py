"""Fig. 7 — class non-IID (Dirichlet β) and modality non-IID (missing rate)
robustness on ActionSense."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    betas = [0.1, 1.0] if fast else [0.1, 0.5, 1.0, 10.0]
    for beta in betas:
        cfg = cfg_for(fast)
        with Timer() as t:
            h = run_mfedmc("actionsense", "class_noniid", cfg, beta=beta,
                           samples_per_client=n)
        rows.append(Row(f"fig7a/dirichlet_b{beta}", t.us,
                        f"final={h.final_accuracy():.4f};"
                        f"MB={h.comm_mb[-1]:.2f}"))
    rates = [0.0, 0.5] if fast else [0.0, 0.2, 0.5, 0.8]
    for rate in rates:
        cfg = cfg_for(fast)
        with Timer() as t:
            h = run_mfedmc("actionsense", "modality_noniid", cfg,
                           missing_rate=rate, samples_per_client=n)
        rows.append(Row(f"fig7b/missing_{int(rate*100)}pct", t.us,
                        f"final={h.final_accuracy():.4f};"
                        f"MB={h.comm_mb[-1]:.2f}"))
    return rows

"""Sharded-population scaling benchmark: round time vs mesh size at fixed
K/device, with measured host-syncs per round.

    PYTHONPATH=src python -m benchmarks.bench_sharded_population \
        [--meshes 1,2,4,8] [--k-per-device 4] [--out BENCH_sharded_population.json]

Forces ``--xla_force_host_platform_device_count=max(meshes)`` *before*
importing jax (the flag is read at backend init), then runs
``backend="sharded"`` at K = D × k_per_device for each mesh size D. With
per-device work held constant, a population-sharded round should stay
near-flat as D grows — modulo the host: forced host devices are threads on
the same CPU, so on a box with fewer cores than D the "devices" timeshare
one socket and the flat-scaling signal compresses into the non-sharded
fractions (host-side data prep, selection bookkeeping). The JSON records
``cpu_count`` so readers can judge the floor; on real multi-chip backends
the same program scales without that caveat.

The second column is the point the tentpole pins: host-syncs per round are
counted at the device→host boundary (``repro.core.hostsync``) and must be
*independent of mesh size* — selection fetches its three decision arrays
and training one loss array per bucket no matter how many shards the
population spans.
"""
from __future__ import annotations

import argparse
import json
import os


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", default="1,2,4,8",
                    help="comma-separated mesh sizes (forced host devices)")
    ap.add_argument("--k-per-device", type=int, default=4)
    ap.add_argument("--samples", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured repetitions (min is reported)")
    ap.add_argument("--out", default="BENCH_sharded_population.json")
    args = ap.parse_args(argv)
    meshes = [int(d) for d in args.meshes.split(",")]

    # must precede the first jax import anywhere in the process
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={max(meshes)}").strip()

    from benchmarks.bench_batched_round import synthetic_federation
    from repro.core import hostsync
    from repro.core.rounds import MFedMCConfig, run_federation
    from repro.telemetry.timer import interleaved_min

    def build(D: int, K: int):
        clients, spec = synthetic_federation(K, n=args.samples)
        cfg = MFedMCConfig(rounds=args.rounds, local_epochs=args.epochs,
                           batch_size=16, seed=0,
                           modality_strategy="priority",
                           client_strategy="low_loss", gamma=1,
                           background_size=16, eval_size=16,
                           mesh_clients=D)
        return clients, spec, cfg

    def warm_and_count(D: int, K: int) -> int:
        clients, spec, cfg = build(D, K)
        with hostsync.measuring() as m:
            run_federation(clients, spec, cfg, backend="sharded")
        return m.as_dict()["host_syncs"] // args.rounds

    results = []
    for D in meshes:
        K = D * args.k_per_device
        syncs = warm_and_count(D, K)                    # warm/compile
        label = f"mesh{D}"
        best = interleaved_min(
            {label: (lambda a: run_federation(a[0], a[1], a[2],
                                              backend="sharded"))},
            prepare={label: (lambda D=D, K=K: build(D, K))},
            reps=max(args.repeats, 1))[label] / args.rounds
        results.append({"mesh": D, "K": K,
                        "seconds_per_round": round(best, 4),
                        "host_syncs_per_round": syncs})
        print(f"mesh={D}  K={K:4d}  {best:7.3f}s/round  "
              f"host_syncs/round={syncs}")

    sync_set = {r["host_syncs_per_round"] for r in results}
    print(f"host-syncs mesh-independent: {len(sync_set) == 1} ({sync_set})")
    payload = {
        "benchmark": "sharded_population",
        "backend": "sharded",
        "k_per_device": args.k_per_device,
        "rounds_timed": args.rounds,
        "cpu_count": os.cpu_count(),
        "host_syncs_mesh_independent": len(sync_set) == 1,
        "note": ("forced host devices share the physical CPU; with "
                 "cpu_count < max mesh the flat-scaling signal is bounded "
                 "by core timesharing (see module docstring)"),
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

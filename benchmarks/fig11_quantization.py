"""Fig. 11 — uplink quantization (32/16/8/4-bit) composed with joint
selection; bytes are exact wire counts (packed codes + metadata)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    for bits in (32, 16, 8, 4):
        cfg = cfg_for(fast, quantize_bits=bits)
        with Timer() as t:
            h = run_mfedmc("ucihar", "iid", cfg, samples_per_client=n)
        rows.append(Row(f"fig11/q{bits}", t.us,
                        f"final={h.final_accuracy():.4f};"
                        f"MB={h.comm_mb[-1]:.3f}"))
    return rows

"""Fig. 9 — long-tail client-size imbalance × loss/recency client-selection
weight blends."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    ifs = [10, 100] if fast else [10, 20, 50, 80, 100]
    blends = [(1.0, "pure_loss"), (0.2, "loss02_rec08")] if fast else \
        [(1.0, "pure_loss"), (0.8, "loss08_rec02"), (0.5, "loss05_rec05"),
         (0.2, "loss02_rec08"), (0.0, "pure_recency")]
    n = samples_for(fast)
    for imf in ifs:
        for w, tag in blends:
            cfg = cfg_for(fast, client_strategy="loss_recency",
                          loss_weight=w)
            with Timer() as t:
                h = run_mfedmc("ucihar", "longtail", cfg,
                               imbalance_factor=imf, max_samples=n)
            rows.append(Row(f"fig9/IF{imf}/{tag}", t.us,
                            f"final={h.final_accuracy():.4f};"
                            f"MB={h.comm_mb[-1]:.2f}"))
    return rows

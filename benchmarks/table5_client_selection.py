"""Tables 5–6 / Fig. 6 — client-selection criterion comparison: lower loss
(paper's choice) vs higher loss vs random, plus the selection-frequency
histogram skew."""
from __future__ import annotations

from collections import Counter
from typing import List

import numpy as np

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.rounds import run_mfedmc


def _selection_skew(history) -> float:
    """Coefficient of variation of per-client selection counts (Fig. 6)."""
    counts = Counter(cid for r in history.records for cid, _ in r.uploads)
    if not counts:
        return 0.0
    v = np.array(list(counts.values()), float)
    return float(v.std() / max(v.mean(), 1e-9))


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    for crit in ["low_loss", "high_loss", "random"]:
        cfg = cfg_for(fast, client_strategy=crit, delta=0.2)
        with Timer() as t:
            h = run_mfedmc("actionsense", "natural", cfg,
                           samples_per_client=n)
        rows.append(Row(
            f"table5/actionsense/{crit}", t.us,
            f"final={h.final_accuracy():.4f};MB={h.comm_mb[-1]:.2f};"
            f"sel_skew={_selection_skew(h):.2f}"))
    if not fast:
        for crit in ["low_loss", "high_loss"]:
            cfg = cfg_for(fast, client_strategy=crit, delta=0.2)
            with Timer() as t:
                h = run_mfedmc("ucihar", "iid", cfg, samples_per_client=n)
            rows.append(Row(
                f"table5/ucihar/{crit}", t.us,
                f"final={h.final_accuracy():.4f};MB={h.comm_mb[-1]:.2f}"))
    return rows

"""Generate EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run JSON artifact.

    PYTHONPATH=src python -m benchmarks.make_tables results/dryrun_all.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def fmt_eng(x: float) -> str:
    return f"{x:.2e}" if x else "0"


def dryrun_table(results: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GiB/chip | fits 16G |"
        " compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in results:
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        status = r.get("status", "?")
        if status == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok "
                f"| {r.get('peak_gib_per_chip', '—')} "
                f"| {'✓' if r.get('fits_hbm_16g') else '✗'} "
                f"| {r.get('compile_s', 0):.0f} |")
        else:
            short = status if len(status) < 60 else status[:57] + "…"
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} "
                         f"| {short} | — | — | — |")
    return "\n".join(lines)


def roofline_table(results: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " useful-FLOPs | bottleneck note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("status") != "ok" or r.get("multi_pod"):
            continue
        note = _note(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_eng(r['compute_s_term'])} "
            f"| {fmt_eng(r['memory_s_term'])} "
            f"| {fmt_eng(r['collective_s_term'])} "
            f"| **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def _note(r: Dict) -> str:
    dom = r["dominant"]
    if dom == "compute":
        return "more chips / fewer remat FLOPs move it"
    if dom == "memory":
        hb = r.get("hbm_breakdown", {})
        big = max(hb, key=hb.get) if hb else "?"
        return f"HBM traffic dominated by {big}"
    return "shrink or overlap the dominant collective"


def main(path: str) -> None:
    with open(path) as f:
        results = json.load(f)
    ok = sum(1 for r in results if r.get("status") == "ok")
    skipped = sum(1 for r in results if "skipped" in str(r.get("status")))
    failed = len(results) - ok - skipped
    print(f"## §Dry-run — {ok} ok / {skipped} skipped / {failed} failed "
          f"of {len(results)}\n")
    print(dryrun_table(results))
    print("\n## §Roofline — single-pod (16×16 = 256 chips)\n")
    print(roofline_table(results))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_all.json")

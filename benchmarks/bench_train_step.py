"""Local-training round: fused all-epochs programs vs per-epoch chain.

    PYTHONPATH=src python -m benchmarks.bench_train_step \
        [--ks 32,128] [--out BENCH_train_step.json]

Times one full ``run_federation`` round per (K, ``train_impl``) pair on
the batched backend — the ONLY knob moving is the trainer: ``"fused"``
collapses each bucket's Local Learning into one donated
``scan(epochs)∘scan(steps)`` program per stage and reuses one cached
train-split encoder forward across Stage-#1 fusion and the Shapley
enumeration, while ``"reference"`` dispatches the historical per-epoch
chain and recomputes that forward. Both trainers run the SAME step body,
so ledgers, selections, and accuracies are identical (asserted here) and
the timing gap is pure dispatch/donation/cache structure.

Timings are strictly interleaved min-of-reps (this host's wall clock
drifts between process phases — only alternating reps are comparable).
Dispatched-programs/round and host syncs come from
``repro.core.hostsync.measuring`` over the same runs; the fused trainer
must show strictly fewer dispatches at every K.

Supports the ``benchmarks.run`` Row contract via :func:`run`.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from benchmarks.bench_batched_round import synthetic_federation
from benchmarks.common import (Row, interleaved_min, lint_stamp,
                               phase_breakdown)
from repro.core import hostsync
from repro.core.rounds import MFedMCConfig, run_federation

IMPLS = ("fused", "reference")
KS = (32, 128)


def _cfg(train_impl: str, **kw) -> MFedMCConfig:
    base = dict(rounds=1, local_epochs=2, batch_size=16, seed=0,
                modality_strategy="random", client_strategy="random",
                gamma=1, quantize_bits=4, train_impl=train_impl)
    base.update(kw)
    return MFedMCConfig(**base)


def time_train_round(K: int, *, n: int = 48, reps: int = 5,
                     backend: str = "batched") -> Dict:
    """One round per trainer impl: steady-state seconds, dispatched
    programs, host syncs — federation construction stays outside the
    timed region; only ``run_federation`` is measured."""
    def once(impl: str):
        clients, spec = synthetic_federation(K, n=n)
        return run_federation(clients, spec, _cfg(impl), backend=backend)

    history = {impl: once(impl) for impl in IMPLS}  # compile both first
    for impl in IMPLS:
        assert (history[impl].records[0].uploads
                == history["fused"].records[0].uploads), \
            "trainer impl must not move selection"
        assert (history[impl].records[0].accuracy
                == history["fused"].records[0].accuracy), \
            "trainer impl must not move accuracy"

    counters = {}
    for impl in IMPLS:
        with hostsync.measuring() as m:
            once(impl)
        counters[impl] = m.as_dict()

    best = interleaved_min(
        {impl: (lambda a: run_federation(a[0], a[1], a[2],
                                         backend=backend))
         for impl in IMPLS},
        prepare={impl: (lambda impl=impl:
                        (*synthetic_federation(K, n=n), _cfg(impl)))
                 for impl in IMPLS},
        reps=reps)

    return {
        "K": K,
        "backend": backend,
        "fused_s": round(best["fused"], 6),
        "reference_s": round(best["reference"], 6),
        "speedup": round(best["reference"] / best["fused"], 3),
        "dispatches": {i: counters[i]["dispatches"] for i in IMPLS},
        "host_syncs": {i: counters[i]["host_syncs"] for i in IMPLS},
    }


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    for K in ((16, 32) if fast else KS):
        r = time_train_round(K, reps=3 if fast else 5)
        rows.append(Row(f"train_step/K{K}/reference",
                        r["reference_s"] * 1e6,
                        f"dispatches={r['dispatches']['reference']}"))
        rows.append(Row(f"train_step/K{K}/fused", r["fused_s"] * 1e6,
                        f"speedup={r['speedup']:.2f}x;"
                        f"dispatches={r['dispatches']['fused']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", default=",".join(str(k) for k in KS),
                    help="comma-separated client counts")
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default="BENCH_train_step.json")
    args = ap.parse_args(argv)

    results = []
    for K in (int(k) for k in args.ks.split(",")):
        t0 = time.time()
        r = time_train_round(K, n=args.samples, reps=args.reps)
        results.append(r)
        d = r["dispatches"]
        print(f"K={K:4d} fused={r['fused_s']:7.3f}s "
              f"ref={r['reference_s']:7.3f}s speedup={r['speedup']:5.2f}x "
              f"dispatches fused={d['fused']} ref={d['reference']} "
              f"(total {time.time() - t0:.0f}s)", flush=True)

    payload = {
        "benchmark": "train_step",
        "config": {
            "dataset_shapes": "ucihar (reduced)",
            "modalities": 2,
            "samples_per_client": args.samples,
            "local_epochs": 2,
            "batch_size": 16,
            "rounds_timed": 1,
            "accounting": "interleaved min-of-reps over run_federation; "
                          "dispatches/host_syncs from repro.core.hostsync "
                          "over the local-training launch path; selection "
                          "and accuracy asserted identical across impls",
        },
        "results": results,
        "lint": lint_stamp(("batched",), ("fused",)),
        "phase_breakdown": [phase_breakdown("batched", "fused", impl)
                            for impl in IMPLS],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 5 — modality-impact dynamics: mean |Shapley| per modality across
communication rounds (the interpretability readout)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    n = samples_for(fast)
    cfg = cfg_for(fast)
    with Timer() as t:
        h = run_mfedmc("actionsense", "natural", cfg, samples_per_client=n)
    rows: List[Row] = []
    mods = sorted({m for r in h.records for m in r.shapley})
    for m in mods:
        series = [r.shapley.get(m, float("nan")) for r in h.records]
        traj = "|".join(f"{v:.4f}" for v in series)
        rows.append(Row(f"fig5/actionsense/{m}", t.us / max(len(mods), 1),
                        f"phi_by_round={traj}"))
    return rows

"""Table 2 / Fig. 4 — overall comparison: MFedMC vs ablations vs SOTA
baselines; (i) accuracy under a communication budget and (ii) overhead to
reach a target accuracy."""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.baselines import run_baseline
from repro.core.rounds import run_mfedmc

BUDGET_MB = 5.0
TARGETS = {"actionsense": 0.5, "ucihar": 0.5}
FAST_TARGETS = {"actionsense": 0.3, "ucihar": 0.4}


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    targets = FAST_TARGETS if fast else TARGETS
    datasets = ["actionsense"] if fast else ["actionsense", "ucihar"]
    n = samples_for(fast)
    for ds in datasets:
        scenario = "natural"
        cfg = cfg_for(fast, comm_budget_mb=BUDGET_MB)
        systems = {
            "mfedmc": lambda c=cfg: run_mfedmc(ds, scenario, c,
                                               samples_per_client=n),
            "wo_modality_sel": lambda c=cfg: run_mfedmc(
                ds, scenario,
                dataclasses.replace(c, modality_strategy="random"),
                samples_per_client=n),
            "wo_client_sel": lambda c=cfg: run_mfedmc(
                ds, scenario, dataclasses.replace(c, client_strategy="all"),
                samples_per_client=n),
            "wo_joint_sel": lambda c=cfg: run_mfedmc(
                ds, scenario,
                dataclasses.replace(c, modality_strategy="random",
                                    client_strategy="random"),
                samples_per_client=n),
            "flfd": lambda c=cfg: run_baseline("flfd", ds, scenario, c,
                                               samples_per_client=n),
            "flash": lambda c=cfg: run_baseline("flash", ds, scenario, c,
                                                samples_per_client=n),
        }
        if not fast:
            systems.update({
                "mmfed": lambda c=cfg: run_baseline(
                    "mmfed", ds, scenario, c, samples_per_client=n),
                "harmony": lambda c=cfg: run_baseline(
                    "harmony", ds, scenario, c, samples_per_client=n),
            })
        for name, fn in systems.items():
            with Timer() as t:
                h = fn()
            acc = h.accuracy_under_budget(BUDGET_MB)
            mb = h.overhead_to_target(targets[ds])
            rows.append(Row(
                f"table2/{ds}/{name}", t.us,
                f"acc@{BUDGET_MB}MB={acc:.4f};MB@{targets[ds]:.0%}="
                f"{mb:.2f};final={h.final_accuracy():.4f}"))
    return rows

"""Fig. 10 — client availability / churn robustness."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.baselines import run_baseline
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    rates = [1.0, 0.5] if fast else [1.0, 0.75, 0.5, 0.25]
    for rate in rates:
        cfg = cfg_for(fast, availability=rate)
        with Timer() as t:
            h = run_mfedmc("actionsense", "natural", cfg,
                           samples_per_client=n)
        rows.append(Row(f"fig10/mfedmc_avail{int(rate*100)}", t.us,
                        f"final={h.final_accuracy():.4f};"
                        f"MB={h.comm_mb[-1]:.2f}"))
    if not fast:
        for rate in (1.0, 0.5):
            cfg = cfg_for(fast, availability=rate)
            with Timer() as t:
                h = run_baseline("mmfed", "actionsense", "natural", cfg,
                                 samples_per_client=n)
            rows.append(Row(f"fig10/mmfed_avail{int(rate*100)}", t.us,
                            f"final={h.final_accuracy():.4f};"
                            f"MB={h.comm_mb[-1]:.2f}"))
    return rows

"""Fig. 10 — client availability / churn robustness.

Three availability regimes over the same federation:

- Bernoulli rates (the paper's §4.9 sweep): IID per-round coin flips;
- Markov on/off churn traces (same stationary availability as the matched
  Bernoulli rate, but bursty: mean off-burst 1/p_join rounds) — run on the
  virtual-time async backend;
- deadline-based straggler dropping: 25% of clients at 10× compute, with a
  reporting deadline that preempts them (``deadline_s``), vs the
  synchronous barrier that waits.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.baselines import run_baseline
from repro.core.rounds import run_mfedmc


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    rates = [1.0, 0.5] if fast else [1.0, 0.75, 0.5, 0.25]
    for rate in rates:
        cfg = cfg_for(fast, availability=rate)
        with Timer() as t:
            h = run_mfedmc("actionsense", "natural", cfg,
                           samples_per_client=n)
        rows.append(Row(f"fig10/mfedmc_avail{int(rate*100)}", t.us,
                        f"final={h.final_accuracy():.4f};"
                        f"MB={h.comm_mb[-1]:.2f}"))

    # Markov churn at the same stationary availability as the Bernoulli
    # rates above: p_join/(p_join+p_drop) = 0.5 and 0.75, but bursty
    # (mean off-burst 1/p_join rounds) — the regime IID flips can't model
    churns = [("markov:0.3,0.3", "stat50"), ("markov:0.2,0.6", "stat75")]
    if fast:
        churns = churns[:1]
    for trace, tag in churns:
        cfg = cfg_for(fast, availability_trace=trace)
        with Timer() as t:
            h = run_mfedmc("actionsense", "natural", cfg,
                           backend="async", samples_per_client=n)
        rows.append(Row(f"fig10/mfedmc_{tag}_churn", t.us,
                        f"final={h.final_accuracy():.4f};"
                        f"MB={h.comm_mb[-1]:.2f};"
                        f"makespan={h.makespan_s:.1f}s"))

    # deadline drops: 25% stragglers at 10x compute; the reporting deadline
    # preempts them while the degenerate config (no deadline) waits.
    # nominal_cycle_seconds only reads shapes/step counts, so the no-
    # deadline run reuses the probe federation (untrained at probe time).
    from repro.core.rounds import build_federation, run_federation
    from repro.core.scheduler import nominal_cycle_seconds
    straggle = dict(straggler_fraction=0.25, straggler_factor=10.0,
                    compute_sec_per_step=0.1)
    cfg_wait = cfg_for(fast, **straggle)
    clients, spec = build_federation("actionsense", "natural", cfg=cfg_wait,
                                     seed=cfg_wait.seed,
                                     samples_per_client=n)
    nominal = nominal_cycle_seconds(clients, spec, cfg_wait)
    with Timer() as t:
        h_wait = run_federation(clients, spec, cfg_wait, backend="async")
    cfg_drop = cfg_for(fast, deadline_s=1.5 * nominal, **straggle)
    with Timer() as t2:
        h_drop = run_mfedmc("actionsense", "natural", cfg_drop,
                            backend="async", samples_per_client=n)
    dropped = sum(len(r.dropped) for r in h_drop.records)
    rows.append(Row("fig10/mfedmc_straggle_wait", t.us,
                    f"final={h_wait.final_accuracy():.4f};"
                    f"makespan={h_wait.makespan_s:.1f}s"))
    rows.append(Row("fig10/mfedmc_straggle_deadline", t2.us,
                    f"final={h_drop.final_accuracy():.4f};"
                    f"makespan={h_drop.makespan_s:.1f}s;"
                    f"dropped={dropped}"))

    if not fast:
        for rate in (1.0, 0.5):
            cfg = cfg_for(fast, availability=rate)
            with Timer() as t:
                h = run_baseline("mmfed", "actionsense", "natural", cfg,
                                 samples_per_client=n)
            rows.append(Row(f"fig10/mmfed_avail{int(rate*100)}", t.us,
                            f"final={h.final_accuracy():.4f};"
                            f"MB={h.comm_mb[-1]:.2f}"))
    return rows

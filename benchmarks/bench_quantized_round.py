"""Quantized federation rounds: loop vs batched across uplink precisions.

    PYTHONPATH=src python -m benchmarks.bench_quantized_round \
        [--ks 16] [--bits 4,8,16,32] [--out BENCH_quantized_round.json]

Builds the same synthetic UCI-HAR-shaped federation as
``bench_batched_round`` and times one full ``run_federation`` round per
(backend, bits) pair with the §4.10 uplink at that precision. Two curves
come out:

- **speedup** — the device-resident communication path (stacked vmapped
  quantization + fused dequantize-and-reduce aggregation) rides the batched
  backend's vmapped local learning; the loop backend pays K·M·E per-batch
  dispatches plus the same shared upload path, so the gap pins the engine
  win at every precision;
- **bytes** — the exact ledger bytes of the round (bit-packed codes in the
  smallest sufficient dtype + per-tensor scale/zero metadata), i.e. the
  compression curve the paper's >20× claim composes with.

It also micro-benchmarks the communication hot path itself
(:func:`time_comm_path`): the REAL ``aggregate_uploads`` programs at
K ∈ {32, 128}, ``comm_impl="fused"`` vs ``"reference"`` timed strictly
interleaved (this host's timings drift ~2× between process phases — only
alternating reps are comparable), with measured bytes-moved from
``repro.core.hostsync`` reported against the
``repro.roofline.quantized_uplink_roofline`` bounds from those same
programs' jaxprs.

Supports the ``benchmarks.run`` Row contract via :func:`run`.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax

from benchmarks.bench_batched_round import synthetic_federation
from benchmarks.common import (Row, Timer, interleaved_min, lint_stamp,
                               phase_breakdown)
from repro.core import hostsync
from repro.core.rounds import MFedMCConfig, aggregate_uploads, run_federation
from repro.roofline import quantized_uplink_roofline

BITS = (4, 8, 16, 32)
COMM_KS = (32, 128)
COMM_BITS = (4, 8, 16)


def _cfg(bits: int, **kw) -> MFedMCConfig:
    base = dict(rounds=1, local_epochs=2, batch_size=16, seed=0,
                modality_strategy="random", client_strategy="random",
                gamma=1, quantize_bits=bits)
    base.update(kw)
    return MFedMCConfig(**base)


def time_quantized_round(K: int, backend: str, bits: int, *, n: int = 48,
                         warm: bool = True):
    """(steady-state wall seconds, ledger MB) for one quantized round."""
    if warm:
        clients, spec = synthetic_federation(K, n=n)
        run_federation(clients, spec, _cfg(bits), backend=backend)
    clients, spec = synthetic_federation(K, n=n)
    with Timer() as t:
        h = run_federation(clients, spec, _cfg(bits), backend=backend)
    return t.us / 1e6, float(h.records[0].comm_mb)


def time_comm_path(K: int, bits: int, *, n: int = 48, reps: int = 7) -> Dict:
    """Micro-bench the REAL ``aggregate_uploads`` hot path, fused vs
    reference, strictly interleaved min-of-reps (this host's wall clock
    drifts between process phases; alternation is the only fair timing),
    with measured bytes-moved and the roofline bounds for the same shapes."""
    clients, spec = synthetic_federation(K, n=n)
    modality = spec.modality_names[0]
    counts = [n] * K

    def once(impl: str):
        out = aggregate_uploads(clients, modality, counts, bits,
                                comm_impl=impl)
        jax.block_until_ready(out)
        return out

    for impl in ("fused", "reference"):  # compile both before any timing
        once(impl)

    bytes_moved = {}
    for impl in ("fused", "reference"):
        with hostsync.measuring() as m:
            once(impl)
        bytes_moved[impl] = m.as_dict()["bytes_moved"]

    best = interleaved_min({impl: (lambda impl=impl: once(impl))
                            for impl in ("fused", "reference")}, reps=reps)

    # K here is a power of two, so pad_uploads_pow2 is the identity and the
    # roofline shapes match the timed program exactly.
    roof = quantized_uplink_roofline(clients[0].encoders[modality], K, bits)
    return {
        "K": K,
        "bits": bits,
        "fused_s": round(best["fused"], 6),
        "reference_s": round(best["reference"], 6),
        "speedup": round(best["reference"] / best["fused"], 3),
        "bytes_moved": bytes_moved,
        "roofline": roof,
    }


def run(fast: bool = True) -> List[Row]:
    K = 8 if fast else 32
    rows: List[Row] = []
    for bits in BITS:
        loop_s, mb = time_quantized_round(K, "loop", bits)
        batched_s, mb_b = time_quantized_round(K, "batched", bits)
        assert mb == mb_b, "ledger must not depend on the backend"
        rows.append(Row(f"quantized_round/K{K}/q{bits}/loop", loop_s * 1e6,
                        f"MB={mb:.4f}"))
        rows.append(Row(f"quantized_round/K{K}/q{bits}/batched",
                        batched_s * 1e6,
                        f"speedup={loop_s / batched_s:.2f}x;MB={mb:.4f}"))
    r = time_comm_path(32 if fast else 128, 4, reps=3 if fast else 7)
    wire = r["roofline"]["wire_bytes"]
    rows.append(Row(f"comm_path/K{r['K']}/q4/reference",
                    r["reference_s"] * 1e6,
                    f"bytes={r['bytes_moved']['reference']}"))
    rows.append(Row(f"comm_path/K{r['K']}/q4/fused", r["fused_s"] * 1e6,
                    f"speedup={r['speedup']:.2f}x;"
                    f"bytes={r['bytes_moved']['fused']};wire={wire}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ks", default="16",
                    help="comma-separated client counts")
    ap.add_argument("--bits", default=",".join(str(b) for b in BITS))
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--out", default="BENCH_quantized_round.json")
    args = ap.parse_args(argv)

    ks = [int(k) for k in args.ks.split(",")]
    bit_list = [int(b) for b in args.bits.split(",")]

    results = []
    for K in ks:
        for bits in bit_list:
            t0 = time.time()
            loop_s, mb = time_quantized_round(K, "loop", bits,
                                              n=args.samples)
            batched_s, mb_b = time_quantized_round(K, "batched", bits,
                                                   n=args.samples)
            assert mb == mb_b, "ledger must not depend on the backend"
            results.append({
                "K": K,
                "bits": bits,
                "loop_s": round(loop_s, 4),
                "batched_s": round(batched_s, 4),
                "speedup": round(loop_s / batched_s, 3),
                "uplink_mb": round(mb, 6),
            })
            print(f"K={K:4d} bits={bits:2d} loop={loop_s:7.2f}s "
                  f"batched={batched_s:7.2f}s "
                  f"speedup={loop_s / batched_s:5.2f}x "
                  f"uplink={mb:8.4f}MB (total {time.time() - t0:.0f}s)",
                  flush=True)

    comm_path = []
    for K in COMM_KS:
        for bits in COMM_BITS:
            r = time_comm_path(K, bits, n=args.samples)
            comm_path.append(r)
            bm = r["bytes_moved"]
            print(f"comm K={K:4d} bits={bits:2d} "
                  f"fused={r['fused_s'] * 1e3:7.2f}ms "
                  f"ref={r['reference_s'] * 1e3:7.2f}ms "
                  f"speedup={r['speedup']:5.2f}x "
                  f"bytes fused={bm['fused']} ref={bm['reference']} "
                  f"wire={r['roofline']['wire_bytes']}", flush=True)

    payload = {
        "benchmark": "quantized_round",
        "config": {
            "dataset_shapes": "ucihar (reduced)",
            "modalities": 2,
            "samples_per_client": args.samples,
            "local_epochs": 2,
            "batch_size": 16,
            "rounds_timed": 1,
            "accounting": "exact wire bytes: bit-packed codes in smallest "
                          "sufficient dtype + 8B scale/zero per tensor",
            "comm_path": "aggregate_uploads fused vs reference, interleaved "
                         "min-of-reps; bytes_moved from repro.core.hostsync; "
                         "roofline from repro.roofline.quantized_uplink_"
                         "roofline on the same padded [K,...] shapes",
        },
        "results": results,
        "comm_path": comm_path,
        "lint": lint_stamp(("batched", "engine"), ("fused", "reference")),
        "phase_breakdown": [phase_breakdown("engine", ci)
                            for ci in ("fused", "reference")],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

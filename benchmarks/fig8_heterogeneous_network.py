"""Fig. 8 — heterogeneous uplink tiers: every client participates under
bandwidth restrictions; end-to-end baselines lock out restricted clients."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, Timer, cfg_for, samples_for
from repro.core.baselines import run_baseline
from repro.core.rounds import run_mfedmc

LIGHT4 = {"eye", "emg_left", "emg_right", "body"}
LIGHT3 = {"eye", "emg_left", "emg_right"}
TIERS = {**{k: LIGHT4 for k in (2, 3, 4)}, **{k: LIGHT3 for k in range(5, 9)}}


def run(fast: bool = True) -> List[Row]:
    rows: List[Row] = []
    n = samples_for(fast)
    cfg = cfg_for(fast, allowed_modalities=TIERS)
    with Timer() as t:
        h = run_mfedmc("actionsense", "natural", cfg, samples_per_client=n)
    rows.append(Row("fig8/mfedmc_tiered", t.us,
                    f"final={h.final_accuracy():.4f};MB={h.comm_mb[-1]:.2f}"))
    # end-to-end baseline: only clients 0-1 can upload full models
    cfg_b = cfg_for(fast)
    with Timer() as t:
        hb = run_baseline("flfd", "actionsense", "natural", cfg_b,
                          samples_per_client=n,
                          allowed_full_upload=[0, 1])
    rows.append(Row("fig8/flfd_clients01_only", t.us,
                    f"final={hb.final_accuracy():.4f};"
                    f"MB={hb.comm_mb[-1]:.2f}"))
    return rows

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_dump_to=/tmp/xla_dump --xla_dump_hlo_as_text "
                           "--xla_dump_hlo_pass_re=buffer")
import jax
from jax.sharding import NamedSharding
import repro.launch.dryrun as dr
from repro.configs import get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step
from repro.models.model import param_specs, input_specs
from repro.optim import adamw
from repro.sharding.partition import param_pspecs, batch_pspec, register_mesh

cfg = get_config("phi3-medium-14b")
shape = get_shape("train_4k")
mesh = make_production_mesh(multi_pod=False)
register_mesh(mesh)
p_specs = param_specs(cfg)
p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(p_specs))
in_specs = input_specs(cfg, shape)
b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspec(shape, cfg, False))
opt = adamw(1e-4)
o_specs = jax.eval_shape(opt.init, p_specs)
o_sh = dr._opt_shardings(p_specs, o_specs, mesh)
step = make_train_step(cfg, opt, shape)
mesh.__enter__()  # ambient mesh for shard_map lowering
compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None), donate_argnums=(0,1)
                   ).lower(p_specs, o_specs, in_specs).compile()
print("temp GiB", compiled.memory_analysis().temp_size_in_bytes/2**30)

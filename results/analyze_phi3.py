import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, jax
from collections import Counter
from repro.launch.dryrun import dryrun_one
import repro.launch.dryrun as dr
from repro.configs import get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step
from repro.models.model import param_specs, input_specs
from repro.optim import adamw
from repro.sharding.partition import param_pspecs, batch_pspec, register_mesh
from jax.sharding import NamedSharding

cfg = get_config("phi3-medium-14b")
shape = get_shape("train_4k")
mesh = make_production_mesh(multi_pod=False)
register_mesh(mesh)
p_specs = param_specs(cfg)
p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(p_specs))
in_specs = input_specs(cfg, shape)
b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspec(shape, cfg, False))
opt = adamw(1e-4)
o_specs = jax.eval_shape(opt.init, p_specs)
o_sh = dr._opt_shardings(p_specs, o_specs, mesh)
step = make_train_step(cfg, opt, shape)
mesh.__enter__()  # ambient mesh for shard_map lowering
lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                  out_shardings=(p_sh, o_sh, None), donate_argnums=(0,1)).lower(p_specs, o_specs, in_specs)
compiled = lowered.compile()
hlo = compiled.as_text()

from repro.roofline.collectives import split_computations, computation_multipliers, _shape_bytes
comps, mult = computation_multipliers(hlo)
rows = []
for cname, lines in comps.items():
    for line in lines:
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?\S+ = ((?:\([^)]*\))|(?:\S+\[[\d,]*\]\S*)) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", line)
        if m:
            tys, kind = m.groups()
            b = sum(_shape_bytes(t.strip()) for t in tys[1:-1].split(",")) if tys.startswith("(") else _shape_bytes(tys)
            rows.append((b*mult.get(cname,1), b, mult.get(cname,1), kind, tys[:60], cname[:30]))
rows.sort(reverse=True)
print("top collectives (weighted_bytes, bytes, trips, kind, type, comp):")
for r in rows[:12]:
    print(f"  {r[0]:.3e} {r[1]:.3e} x{r[2]:<4.0f} {r[3]:<18} {r[4]:<60} {r[5]}")

# biggest temp buffers
print()
mem = compiled.memory_analysis()
print("args GiB", mem.argument_size_in_bytes/2**30, "temp GiB", mem.temp_size_in_bytes/2**30)
# largest tensors in HLO (rough): find biggest shapes
sizes = Counter()
for m in re.finditer(r"(bf16|f32)\[([\d,]+)\]", hlo):
    dims = [int(x) for x in m.group(2).split(",")]
    n = 1
    for d in dims: n *= d
    sizes[(m.group(1), tuple(dims))] += 1
big = sorted(sizes.items(), key=lambda kv: -(kv[0][1] and 1) * (4 if kv[0][0]=='f32' else 2) * __import__('math').prod(kv[0][1]))[:10]
for (dt, dims), cnt in big:
    import math
    print(f"  {dt}{list(dims)} x{cnt} = {math.prod(dims)*(4 if dt=='f32' else 2)/2**30:.2f} GiB each")
